"""Compile-once execution plans: the ModelPlan IR (DESIGN.md §8).

The paper's accelerator decides the mapping of every conv layer onto
SOT-MRAM sub-arrays *once*, ahead of execution, and keeps the mapped
bit-planes resident so power loss never forces recomputation (§II, §IV).
This module is the software analogue: :func:`compile_model` (CNNs) and
:func:`compile_lm` (transformers) run every serve-time decision the
inference stack used to make per call — engine dispatch, weight
pre-quantization, feasibility validation — exactly once, producing a
:class:`ModelPlan` that the whole stack then executes:

* one :class:`LayerPlan` record per layer (op kind, shapes, bits, chosen
  engine + how it was chosen, per-batch-hint engine table);
* the pre-quantized serve params (int8 levels + scales — the MRAM-resident
  C_n(W) analogue) as the plan's payload;
* a dense-GEMM verdict table that :func:`repro.kernels.ops.select_engine`
  consults while the plan is active, so transformer projections dispatch
  by lookup instead of heuristic;
* serialization to disk (JSON metadata + npz levels): a restarted node —
  the power-intermittency story — reloads the plan and skips
  requantization, autotuning, and engine search entirely
  (``pim/intermittent.plan_resume_study`` quantifies the win).

Engine choices resolve in three ways, recorded per layer as
``engine_source``: ``override`` (an explicit ``QuantConfig.engine``,
validated against backend/shape feasibility at compile time — infeasible
combinations raise :class:`PlanError` naming the layer instead of failing
deep inside a ``pallas_call``), ``autotuned`` (candidate engines timed on
the live backend via :func:`repro.kernels.ops.autotune_engine`), or
``heuristic`` (the cost model — the no-autotune default, bit-identical in
choice to the pre-plan per-call dispatch).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prequant import is_fp_layer, prequantize_cnn_params
from repro.core.quant import QuantConfig
from repro.kernels import ops

PLAN_VERSION = 1

# Engines valid for the signed (affine-corrected) transformer serve path —
# the fused/faithful Pallas epilogues implement the unsigned DoReFa
# correction only, mirroring models/layers._signed_engine.
SIGNED_ENGINES = ("planes", "packed", "int8", "f32dot")


class PlanError(ValueError):
    """A plan could not be compiled: an explicit engine override is
    infeasible for the backend/shape, or a serialized plan is invalid.
    The message names the offending layer."""


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's compiled execution record.

    ``engine`` is the verdict at the primary batch hint; ``engines`` holds
    the full ``(batch_hint, engine)`` table (every engine is bit-exact, so
    a hint miss costs performance, never correctness).
    """

    index: int
    name: str
    op: str                 # "conv" | "dense"
    role: str               # first | mid | last
    fp: bool                # full-precision layer (no bitwise engine)
    kh: int
    kw: int
    stride: int
    padding: str
    cin: int
    cout: int
    in_h: int
    in_w: int
    out_h: int
    out_w: int
    k: int                  # GEMM depth (kh*kw*cin for convs)
    a_bits: int
    w_bits: int
    engine: str             # "fp" for fp layers
    engine_source: str      # fp | override | autotuned | heuristic
    engines: tuple          # ((batch_hint, engine), ...)
    pool: bool = False
    fc: bool = False
    # per-image (energy_pj, cycles, bytes_moved) estimate from the compile
    # target's cost model (repro.api.targets) — annotation only, never
    # consulted by execution
    cost: tuple = ()
    # attention layers (op == "attn") carry their resolved realization
    # (full/chunked/banded/flash) here; "" for conv/dense rows
    attn_engine: str = ""

    def engine_at(self, batch: int) -> str:
        """Verdict for ``batch``: exact hint, else the largest hint not
        above it (engine crossovers are monotonic in batch), else the
        smallest hint."""
        table = dict(self.engines)
        if batch in table:
            return table[batch]
        below = [b for b, _ in self.engines if b <= batch]
        return table[max(below)] if below else table[min(dict(self.engines))]


@dataclasses.dataclass
class ModelPlan:
    """A compiled, serializable execution plan for one model + backend."""

    kind: str                       # "cnn" | "lm"
    model: str
    backend: str
    quant: QuantConfig
    batch_hints: tuple
    layers: tuple                   # tuple[LayerPlan, ...]
    params: object = None           # pre-quantized serve pytree (or None)
    dense_table: dict = dataclasses.field(default_factory=dict)
    autotune: dict = dataclasses.field(default_factory=dict)
    # attention dispatch verdicts: attn_plan_key -> engine.  A separate
    # table from dense_table — attention engines (full/chunked/banded/
    # flash) name realizations of the softmax dataflow, not level-GEMM
    # engines, so consumers of dense_table never see them.
    attn_table: dict = dataclasses.field(default_factory=dict)
    version: int = PLAN_VERSION

    # -- identity -----------------------------------------------------------

    def meta(self) -> dict:
        """JSON-ready metadata (everything except the params arrays)."""
        return dict(
            version=self.version, kind=self.kind, model=self.model,
            backend=self.backend, quant=dataclasses.asdict(self.quant),
            batch_hints=list(self.batch_hints),
            layers=[_layer_to_json(lp) for lp in self.layers],
            dense_table=[[list(k), v] for k, v in
                         sorted(self.dense_table.items())],
            attn_table=[[list(k), v] for k, v in
                        sorted(self.attn_table.items())],
            autotune=[[list(k), v[0], v[1]] for k, v in
                      sorted(self.autotune.items(), key=lambda kv: kv[0])],
        )

    def fingerprint(self) -> str:
        """Stable short hash of the plan metadata — program-cache key
        material for :class:`repro.launch.engine.ServeEngine`."""
        blob = json.dumps(self.meta(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    # -- dispatch installation ---------------------------------------------

    def _dispatch_table(self) -> dict:
        """Every verdict this plan installs (dense GEMMs + attention)."""
        return {**self.dense_table, **self.attn_table}

    def install(self) -> "ModelPlan":
        """Install this plan's dense + attention verdicts process-wide
        (long-lived server: one plan, installed once at startup)."""
        ops.install_plan_table(self._dispatch_table())
        return self

    @contextlib.contextmanager
    def activate(self):
        """Scoped install: dense and attention dispatch consult this plan's
        tables while the context is open (covers jit *trace* time — traced
        programs keep the planned engines forever after).  Exit restores
        the PRIOR state of every key this plan touched, so activating on
        top of a process-wide :meth:`install` (or a nested activation)
        never uninstalls the outer plan's verdicts."""
        table = self._dispatch_table()
        prior = {k: ops._PLAN_TABLE[k] for k in table
                 if k in ops._PLAN_TABLE}
        ops.install_plan_table(table)
        try:
            yield self
        finally:
            ops.remove_plan_table({k: None for k in table
                                   if k not in prior})
            if prior:
                ops.install_plan_table(prior)


# ---------------------------------------------------------------------------
# Engine resolution (shared by the CNN and LM compile passes)
# ---------------------------------------------------------------------------

def _resolve_engine(quant: QuantConfig, m: int, k: int, n: int, backend: str,
                    conv, *, strict: bool, autotune: bool,
                    layer_desc: str) -> tuple[str, str]:
    """One layer's engine verdict -> (engine, source)."""
    if quant.engine not in ("auto", "fp"):
        if strict:
            ok, reason = ops.engine_feasible(quant.engine, m, k, n,
                                             quant.a_bits, quant.w_bits,
                                             backend, conv)
            if not ok:
                raise PlanError(
                    f"{layer_desc}: explicit engine {quant.engine!r} is "
                    f"infeasible on backend {backend!r}: {reason}")
        return quant.engine, "override"
    if autotune:
        eng, _ = ops.autotune_engine(m, k, n, quant.a_bits, quant.w_bits,
                                     backend, conv)
        return eng, "autotuned"
    # the PURE cost model, never select_engine: a compiling plan must not
    # absorb verdicts from whatever other plan happens to be installed or
    # autotune state happens to be cached — 'heuristic' plans are
    # deterministic functions of (spec, quant, shape, backend) only
    return (ops.cost_model_engine(m, k, n, quant.a_bits, quant.w_bits,
                                  backend, conv), "heuristic")


# ---------------------------------------------------------------------------
# CNN compile pass
# ---------------------------------------------------------------------------

def _plan_cnn_layers(spec, quant: QuantConfig, *, batches, img_hw, backend,
                     strict: bool, autotune: bool):
    """Structural pass: trace the forward's shape evolution and resolve one
    engine per (layer, batch hint).  Mirrors ``models/cnn.cnn_forward``
    exactly (fc resize, SAME/VALID policy, 2x2 pools)."""
    from repro.core.conv_lowering import _out_hw

    layers = []
    in_h, in_w = img_hw
    for i, s in enumerate(spec):
        pad = "VALID" if (s.fc or s.k == 1) else "SAME"
        if s.fc and s.k > 1 and in_h != s.k:
            in_h = in_w = s.k       # cnn_forward resizes to (k, k)
        out_h, out_w = _out_hw(in_h, in_w, s.k, s.k, s.stride, pad)
        kdim = s.k * s.k * s.cin
        name = f"{'fc' if s.fc else 'conv'}{i}"
        fp = is_fp_layer(s, quant)
        if fp:
            engines = tuple((b, "fp") for b in batches)
            source = "fp"
        else:
            resolved = []
            for b in batches:
                conv = ops.ConvShape(in_h, in_w, s.k, s.k, s.stride, pad,
                                     batch=b)
                eng, source = _resolve_engine(
                    quant, b * out_h * out_w, kdim, s.cout, backend, conv,
                    strict=strict, autotune=autotune,
                    layer_desc=f"layer {i} ({name}, {s.k}x{s.k} "
                               f"cin={s.cin} cout={s.cout} batch={b})")
                resolved.append((b, eng))
            engines = tuple(resolved)
        layers.append(LayerPlan(
            index=i, name=name, op="conv", role=s.role, fp=fp,
            kh=s.k, kw=s.k, stride=s.stride, padding=pad,
            cin=s.cin, cout=s.cout, in_h=in_h, in_w=in_w,
            out_h=out_h, out_w=out_w, k=kdim,
            a_bits=quant.a_bits, w_bits=quant.w_bits,
            engine=engines[0][1], engine_source=source, engines=engines,
            pool=s.pool, fc=s.fc))
        in_h, in_w = out_h, out_w
        if s.pool:
            # floor at 1: a pooled 1x1 map (LeNet's pooled-FC stage, which
            # exists only as a mapper/cost model) must not collapse the
            # downstream walk to zero extent (matches pim/mapper.layer_work)
            in_h, in_w = max(in_h // 2, 1), max(in_w // 2, 1)
    return tuple(layers)


def _annotate_costs(layers: tuple, backend: str) -> tuple:
    """Attach the compile target's per-layer (energy_pj, cycles,
    bytes_moved) roofline estimate (repro.api.targets) to each LayerPlan.
    Pure and deterministic — part of the plan's fingerprint."""
    from repro.api.targets import LayerGeometry, target_for_backend
    from repro.pim.mapper import effective_bits

    t = target_for_backend(backend)
    out = []
    for lp in layers:
        ab, wb = effective_bits(lp)
        c = t.cost(LayerGeometry(lp.out_h * lp.out_w, lp.k, lp.cout), ab, wb)
        out.append(dataclasses.replace(
            lp, cost=(c.energy_pj, c.cycles, c.bytes_moved)))
    return tuple(out)


def _is_prequantized(params) -> bool:
    return any(isinstance(p, dict) and "w_lv" in p for p in params)


def compile_model(params, spec, quant: QuantConfig, *, backend=None,
                  batch_hints=(1,), img_hw=40, autotune: bool = False,
                  model: str = "cnn", verify: bool = True) -> ModelPlan:
    """Compile a CNN serve plan: validate/resolve engines for every layer at
    every batch hint, pre-quantize the weights once, collect any autotune
    measurements.  ``params=None`` produces a structure-only plan (engine
    table inspection, golden tests).  Explicit ``quant.engine`` overrides
    that are infeasible on ``backend`` raise :class:`PlanError` here — at
    compile time, naming the layer — instead of failing inside a kernel.

    ``verify=True`` (default) runs the static plan prover
    (:func:`repro.analysis.verify_plan`, DESIGN.md §12) over the result —
    bit-range exactness, int32 overflow, feasibility, table and cost
    invariants — raising :class:`repro.analysis.PlanVerificationError`
    (a :class:`PlanError`) on any violation.  ``verify=False`` is the
    escape hatch for deliberately out-of-contract plans.
    """
    backend = backend or jax.default_backend()
    if isinstance(img_hw, int):
        img_hw = (img_hw, img_hw)
    batch_hints = tuple(int(b) for b in batch_hints) or (1,)
    layers = _annotate_costs(
        _plan_cnn_layers(tuple(spec), quant, batches=batch_hints,
                         img_hw=tuple(img_hw), backend=backend,
                         strict=True, autotune=autotune), backend)
    serve_params = None
    if params is not None:
        serve_params = (params if _is_prequantized(params)
                        else prequantize_cnn_params(params, spec, quant))
    tuned = {}
    if autotune:  # heuristic plans carry no measurements (determinism)
        for lp in layers:
            if lp.fp:
                continue
            for b, _ in lp.engines:
                key = ops.autotune_key(
                    b * lp.out_h * lp.out_w, lp.k, lp.cout, lp.a_bits,
                    lp.w_bits, backend,
                    ops.ConvShape(lp.in_h, lp.in_w, lp.kh, lp.kw,
                                  lp.stride, lp.padding, batch=b))
                if key in ops._AUTOTUNE_CACHE:
                    tuned[key] = ops._AUTOTUNE_CACHE[key]
    plan = ModelPlan(kind="cnn", model=model, backend=backend, quant=quant,
                     batch_hints=batch_hints, layers=layers,
                     params=serve_params, autotune=tuned)
    if verify:
        from repro.analysis.prover import assert_plan_verified

        assert_plan_verified(plan)
    return plan


# Structural layers for the compat path (`cnn_forward(mode="serve")` without
# an explicit plan): cached per (spec, quant, shape, backend).  The dispatch
# epoch stays in the key as a safety valve — heuristic resolution is pure
# today, but any future verdict source must not serve stale cached layers.
@functools.lru_cache(maxsize=512)
def _cached_cnn_layers(spec_t, quant, batch, img_hw, backend, _epoch):
    return _plan_cnn_layers(spec_t, quant, batches=(batch,), img_hw=img_hw,
                            backend=backend, strict=False, autotune=False)


def cnn_serve_layers(spec, quant: QuantConfig, *, batch: int, img_hw,
                     backend=None):
    """Per-call plan for the legacy ``cnn_forward`` entry point: identical
    engine choices to the pre-plan per-layer dispatch (permissive about
    explicit overrides — the correctness suites force interpret-mode Pallas
    engines on CPU through this path)."""
    backend = backend or jax.default_backend()
    return _cached_cnn_layers(tuple(spec), quant, int(batch),
                              (int(img_hw[0]), int(img_hw[1])), backend,
                              ops.dispatch_epoch())


# ---------------------------------------------------------------------------
# CNN execution — the single serve dataflow (no per-layer branching)
# ---------------------------------------------------------------------------

def _layer_weights(p: dict, lp: LayerPlan):
    """Uniform weight access: plan params carry pre-quantized levels; float
    checkpoints prequantize at trace time (once per compiled program)."""
    if "w_lv" in p:
        return p["w_lv"], p["s_w"], p["z_w"]
    from repro.core.prequant import prequantize_conv_weight

    return prequantize_conv_weight(p["w"], lp.w_bits)


def execute_cnn_layers(layers, params, x, quant: QuantConfig):
    """Run the compiled layer sequence.  x (B,H,W,C) in [0,1] -> logits."""
    from repro.core.conv_lowering import conv2d_float, quant_conv2d_pre
    from repro.models.cnn import _norm_act

    h = x
    last = len(layers) - 1
    for lp, p in zip(layers, params):
        if lp.fc and lp.kh > 1 and h.shape[1] != lp.kh:
            h = jax.image.resize(h, (h.shape[0], lp.kh, lp.kw, h.shape[3]),
                                 "linear")
        if lp.fp:
            h = conv2d_float(h, p["w"], stride=lp.stride, padding=lp.padding)
        else:
            w_lv, s_w, z_w = _layer_weights(p, lp)
            h = quant_conv2d_pre(
                h, w_lv, s_w, z_w, kh=lp.kh, kw=lp.kw, stride=lp.stride,
                padding=lp.padding, a_bits=lp.a_bits, w_bits=lp.w_bits,
                engine=lp.engine)
        h = h + p["b"]
        if lp.index < last:
            h = _norm_act(h, p["g"], p["beta"], quant, lp.role, "serve")
        if lp.pool:
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    return jnp.mean(h, axis=(1, 2))


def plan_energy_pj(plan: ModelPlan) -> float:
    """Modeled energy of one forward through the plan, in pJ — the sum of
    the per-layer roofline cost annotations.  This is the currency of the
    resilience degrade policy's energy budget
    (:class:`repro.resilience.degrade.DegradePolicy`): per-sample, so a
    dispatch of padded batch B spends ``B * plan_energy_pj(plan)``.
    Layers compiled without annotations contribute zero."""
    return float(sum(lp.cost[0] for lp in plan.layers if lp.cost))


def plan_cost_on(plan: ModelPlan, target) -> dict:
    """Re-price one forward pass of a compiled CNN plan on any PIM
    :class:`repro.api.targets.HardwareTarget` (name or instance).

    The plan's own per-layer ``cost`` annotations are priced against the
    compile-time target; a fleet of heterogeneous nodes needs the *same*
    plan priced on *different* accelerators without recompiling.  This is
    the Table-II-pinned arithmetic (same works, same ``accel_cost``, same
    fitted energy scale as ``pim/accelsim``), so the absolutes agree
    bit-for-bit with ``CompiledModel.simulate``; it is the per-frame
    ``(energy_uj, latency_us)`` currency of ``repro.fleet.sim``.
    """
    from repro.api.targets import PIMTarget, get_target
    from repro.pim.mapper import works_from_layers

    if plan.kind != "cnn":
        raise PlanError(f"plan_cost_on prices CNN plans (the paper's "
                        f"frame-per-inference scope); this plan is "
                        f"{plan.kind!r}")
    t = get_target(target) if isinstance(target, str) else target
    if not isinstance(t, PIMTarget):
        raise PlanError(
            f"plan_cost_on prices PIM targets (got {t.name!r}); compute "
            f"targets carry their cost in the plan's own annotations — "
            f"sum lp.cost or use CompiledModel.simulate")
    report = dict(t.report(works_from_layers(plan.layers)))
    report["target"] = t.name
    return report


def layers_for_batch(plan: ModelPlan, batch: int):
    """The plan's layer sequence with engines re-pinned for ``batch`` (see
    :meth:`LayerPlan.engine_at` for the hint-miss policy)."""
    return tuple(dataclasses.replace(lp, engine=lp.engine_at(batch))
                 for lp in plan.layers)


def plan_forward(plan: ModelPlan, x, params=None):
    """Execute a compiled CNN plan.  ``params`` defaults to the plan's own
    serve params; pass them explicitly when they arrive as jit arguments
    (e.g. device-put replicas inside the serving engine)."""
    if plan.kind != "cnn":
        raise PlanError(f"plan_forward executes CNN plans, got {plan.kind!r}")
    params = plan.params if params is None else params
    if params is None:
        raise PlanError("structure-only plan (compiled with params=None) "
                        "cannot execute")
    return execute_cnn_layers(layers_for_batch(plan, int(x.shape[0])),
                              params, x, plan.quant)


# ---------------------------------------------------------------------------
# LM compile pass
# ---------------------------------------------------------------------------

def compile_lm(params, cfg, *, backend=None, batch_hints=(1,),
               prompt_len: int = 16, autotune: bool = False,
               page_size: int | None = None, kv_pages: int | None = None,
               verify: bool = True) -> ModelPlan:
    """Compile a transformer serve plan: pre-quantize every projection once
    and resolve one engine verdict per distinct (K, N) GEMM shape into the
    plan's dense table (consulted by ``select_engine`` while the plan is
    active).  Verdicts are ``m``-free — one entry covers prefill and every
    decode step (see :func:`repro.kernels.ops.dense_plan_key`).

    ``page_size``/``kv_pages`` declare the paged-KV serve geometry of the
    continuous-batching engine (``launch/engine.ContinuousLMEngine``:
    ``kv_pages`` = page-table width = per-request page budget): the plan
    then carries a ``paged`` attention verdict for the decode-step shape,
    and the prover's PV108 check proves the page-indexed gather feasible
    (int32 addressing, VMEM-bounded grid step) before the engine ever
    dispatches it.

    ``verify=True`` (default) runs the static plan prover over the result
    (see :func:`compile_model`); ``verify=False`` bypasses it.
    """
    from repro.models.layers import PREQUANT_KEYS, prequantize_params

    backend = backend or jax.default_backend()
    quant = cfg.quant
    batch_hints = tuple(int(b) for b in batch_hints) or (1,)
    quantized = not (quant.engine == "fp" or quant.w_bits >= 32)
    serve_params = prequantize_params(params, cfg) if quantized else params

    layers, table = [], {}
    if quantized:
        from repro.api.targets import LayerGeometry, target_for_backend

        cost_target = target_for_backend(backend)
        shapes: dict[tuple, str] = {}
        for kind, tree in sorted(params["blocks"].items()):
            for sub, sv in sorted(tree.items()):
                if not isinstance(sv, dict):
                    continue
                for kname, v in sorted(sv.items()):
                    if kname in PREQUANT_KEYS:
                        shapes.setdefault(
                            (int(v.shape[-2]), int(v.shape[-1])),
                            f"{kind}.{sub}.{kname}")
        for i, ((K, N), name) in enumerate(sorted(shapes.items())):
            m = batch_hints[0] * prompt_len
            eng, source = _resolve_engine(
                quant, m, K, N, backend, None, strict=True,
                autotune=autotune, layer_desc=f"projection {name} (K={K}, "
                                              f"N={N})")
            if eng not in SIGNED_ENGINES:
                # fused/faithful epilogues are unsigned-only; the signed
                # serve path realizes the same accumulation on int8
                # (mirrors models/layers._signed_engine)
                eng = "int8"
            table[ops.dense_plan_key(K, N, quant.a_bits, quant.w_bits,
                                     backend)] = eng
            c = cost_target.cost(LayerGeometry(m, K, N), quant.a_bits,
                                 quant.w_bits)
            layers.append(LayerPlan(
                index=i, name=name, op="dense", role="mid", fp=False,
                kh=0, kw=0, stride=1, padding="", cin=K, cout=N,
                in_h=0, in_w=0, out_h=0, out_w=0, k=K,
                a_bits=quant.a_bits, w_bits=quant.w_bits, engine=eng,
                engine_source=source,
                engines=tuple((b, eng) for b in batch_hints),
                cost=(c.energy_pj, c.cycles, c.bytes_moved)))
    # attention realization: one verdict per distinct window geometry
    # (global-attention kinds share one; attn_local brings the window).
    # Resolved on the PURE target decision procedure, mirroring the dense
    # heuristic path — a compiling plan must not absorb another installed
    # plan's verdicts.
    attn_table = _plan_lm_attention(params, cfg, quant, backend,
                                    batch_hints, prompt_len, layers,
                                    page_size=page_size, kv_pages=kv_pages)
    tuned = {}
    if autotune:  # heuristic plans carry no measurements (determinism)
        tuned = {k: v for k, v in ops._AUTOTUNE_CACHE.items()
                 if k[0] == "dense" and any(k[2:4] == (lp.k, lp.cout)
                                            for lp in layers)}
    plan = ModelPlan(kind="lm", model=getattr(cfg, "name", "lm"),
                     backend=backend, quant=quant, batch_hints=batch_hints,
                     layers=tuple(layers), params=serve_params,
                     dense_table=table, attn_table=attn_table,
                     autotune=tuned)
    if verify:
        from repro.analysis.prover import assert_plan_verified

        assert_plan_verified(plan)
    return plan


def _plan_lm_attention(params, cfg, quant: QuantConfig, backend: str,
                       batch_hints: tuple, prompt_len: int,
                       layers: list, page_size: int | None = None,
                       kv_pages: int | None = None) -> dict:
    """Resolve and record the attention engine per window geometry.

    Appends one ``op="attn"`` :class:`LayerPlan` row per verdict to
    ``layers`` and returns the :func:`repro.kernels.ops.attn_plan_key`
    table the plan installs for dispatch.  With ``page_size``/``kv_pages``
    set, one extra row records the paged decode-step verdict (10-tuple
    key; see :func:`repro.kernels.ops.attn_plan_key`).
    """
    from repro.api.targets import target_for_backend
    from repro.models.layers import attn_quantized

    cost_target = target_for_backend(backend)
    attn_table: dict = {}
    seen: set = set()
    for kind in sorted(params["blocks"]):
        if kind not in ("attn", "moe", "attn_local"):
            continue
        window = cfg.window if kind == "attn_local" else None
        if window in seen:
            continue
        seen.add(window)
        attn = ops.AttnShape(
            seq_q=prompt_len, seq_kv=prompt_len, heads=cfg.n_heads,
            head_dim=cfg.hd, causal=bool(cfg.causal), window=window,
            batch=batch_hints[0],
            quantized=attn_quantized(quant, "serve"),
            banded_ok=bool(getattr(cfg, "banded_attn", False)))
        eng = cost_target.select_attn_engine(attn)
        if (getattr(cfg, "full_attn_analysis", False)
                and eng in ("chunked", "flash")):
            eng = "full"  # the analysis contract pins materialized logits
        attn_table[ops.attn_plan_key(attn, backend)] = eng
        c = cost_target.attn_cost(attn)
        layers.append(LayerPlan(
            index=len(layers), name=f"attn[{kind}]", op="attn", role="mid",
            fp=not attn.quantized, kh=0, kw=0, stride=1, padding="",
            cin=cfg.d_model, cout=cfg.d_model, in_h=0, in_w=0,
            out_h=0, out_w=0, k=cfg.hd, a_bits=quant.a_bits,
            w_bits=quant.w_bits, engine=eng, engine_source="heuristic",
            engines=tuple((b, eng) for b in batch_hints),
            cost=(c.energy_pj, c.cycles, c.bytes_moved), attn_engine=eng))
    if page_size is not None:
        if not kv_pages or kv_pages < 1:
            raise ValueError(f"page_size={page_size} needs kv_pages >= 1 "
                             f"(per-request page budget), got {kv_pages}")
        # the continuous engine's decode-step geometry: one query token per
        # slot against a page-table extent of kv_pages pages.  batch is the
        # slot count (the largest co-resident decode batch)
        attn = ops.AttnShape(
            seq_q=1, seq_kv=page_size * kv_pages, heads=cfg.n_heads,
            head_dim=cfg.hd, causal=bool(cfg.causal), window=None,
            batch=max(batch_hints),
            quantized=attn_quantized(quant, "serve"),
            page_size=page_size)
        eng = cost_target.select_attn_engine(attn)
        attn_table[ops.attn_plan_key(attn, backend)] = eng
        c = cost_target.attn_cost(attn)
        layers.append(LayerPlan(
            index=len(layers), name=f"attn[paged {kv_pages}x{page_size}]",
            op="attn", role="mid", fp=not attn.quantized, kh=0, kw=0,
            stride=1, padding="", cin=cfg.d_model, cout=cfg.d_model,
            in_h=0, in_w=0, out_h=0, out_w=0, k=cfg.hd,
            a_bits=quant.a_bits, w_bits=quant.w_bits, engine=eng,
            engine_source="heuristic",
            engines=tuple((b, eng) for b in batch_hints),
            cost=(c.energy_pj, c.cycles, c.bytes_moved), attn_engine=eng))
    return attn_table


# ---------------------------------------------------------------------------
# Serialization: JSON metadata + npz weight levels
# ---------------------------------------------------------------------------

def _layer_to_json(lp: LayerPlan) -> dict:
    d = dataclasses.asdict(lp)
    d["engines"] = [list(e) for e in lp.engines]
    d["cost"] = list(lp.cost)
    return d


def _layer_from_json(d: dict) -> LayerPlan:
    d = dict(d)
    d["engines"] = tuple((int(b), str(e)) for b, e in d["engines"])
    d["cost"] = tuple(float(c) for c in d.get("cost", ()))
    return LayerPlan(**d)


def _skeletonize(tree, prefix: str, out: dict):
    """Nested dict/list pytree -> JSON skeleton + flat {path: ndarray}."""
    if isinstance(tree, dict):
        return {k: _skeletonize(v, f"{prefix}/{k}", out)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_skeletonize(v, f"{prefix}/{i}", out)
                for i, v in enumerate(tree)]
    out[prefix] = np.asarray(tree)
    return {"__leaf__": prefix}


def _reconstitute(skel, npz):
    if isinstance(skel, dict):
        if set(skel) == {"__leaf__"}:
            return jnp.asarray(npz[skel["__leaf__"]])
        return {k: _reconstitute(v, npz) for k, v in skel.items()}
    if isinstance(skel, list):
        return [_reconstitute(v, npz) for v in skel]
    raise PlanError(f"invalid params skeleton node: {skel!r}")


def _plan_base(path: str) -> str:
    return path[:-5] if path.endswith(".json") else path


def plan_exists(path: str) -> bool:
    """Is a serialized plan present at ``path`` (with or without .json)?"""
    return os.path.exists(_plan_base(path) + ".json")


def check_plan_matches(plan: ModelPlan, *, quant: QuantConfig | None = None,
                       model: str | None = None,
                       backend: str | None = None) -> ModelPlan:
    """Guard a reloaded plan against the caller's live configuration.

    A plan compiled under a different quant config would silently decode
    its stored integer levels with the wrong bit widths (garbage outputs,
    no shape error) — so mismatches raise :class:`PlanError` telling the
    operator to recompile, instead of serving wrong numbers.
    """
    if quant is not None and plan.quant != quant:
        raise PlanError(
            f"plan was compiled for quant {plan.quant.tag()!r} "
            f"(engine={plan.quant.engine!r}) but the current config is "
            f"{quant.tag()!r} (engine={quant.engine!r}) — delete the plan "
            "file or point --plan-cache elsewhere to recompile")
    if model is not None and plan.model != model:
        raise PlanError(f"plan was compiled for model {plan.model!r}, "
                        f"current model is {model!r} — recompile")
    if backend is not None and plan.backend != backend:
        raise PlanError(f"plan was compiled for backend {plan.backend!r}, "
                        f"live backend is {backend!r} — recompile")
    return plan


def save_plan(plan: ModelPlan, path: str) -> str:
    """Write ``<path>.json`` (metadata) + ``<path>.npz`` (weight levels).

    Returns the JSON path.  The pair is self-contained: a fresh process
    reloads it and serves without touching the original checkpoint,
    requantizing, or re-running autotune.
    """
    base = _plan_base(path)
    os.makedirs(os.path.dirname(os.path.abspath(base)), exist_ok=True)
    meta = plan.meta()
    if plan.params is not None:
        arrays: dict[str, np.ndarray] = {}
        meta["params_skel"] = _skeletonize(plan.params, "p", arrays)
        np.savez(base + ".npz", **arrays)
        meta["params_npz"] = os.path.basename(base) + ".npz"
    else:
        meta["params_skel"] = None
        meta["params_npz"] = None
    with open(base + ".json", "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    return base + ".json"


def load_plan(path: str) -> ModelPlan:
    """Reload a serialized plan — the intermittency-resume fast path.

    Restores the autotune verdicts into the process-wide cache (so even
    plan *recompiles* skip measurement) and rebuilds the serve params from
    the npz levels; nothing is requantized.
    """
    base = _plan_base(path)
    with open(base + ".json") as f:
        meta = json.load(f)
    if meta.get("version") != PLAN_VERSION:
        raise PlanError(f"plan version {meta.get('version')!r} != "
                        f"{PLAN_VERSION} (recompile the plan)")
    params = None
    if meta.get("params_skel") is not None:
        npz_path = os.path.join(os.path.dirname(os.path.abspath(base)),
                                meta["params_npz"])
        with np.load(npz_path) as npz:
            params = _reconstitute(meta["params_skel"], npz)
    dense_table = {tuple(k): v for k, v in meta["dense_table"]}
    attn_table = {tuple(k): v for k, v in meta.get("attn_table", [])}
    autotune = {tuple(k): (eng, times)
                for k, eng, times in meta.get("autotune", [])}
    if autotune:
        ops._AUTOTUNE_CACHE.update(autotune)
        ops._DISPATCH_EPOCH[0] += 1
    return ModelPlan(
        kind=meta["kind"], model=meta["model"], backend=meta["backend"],
        quant=QuantConfig(**meta["quant"]),
        batch_hints=tuple(meta["batch_hints"]),
        layers=tuple(_layer_from_json(d) for d in meta["layers"]),
        params=params, dense_table=dense_table, attn_table=attn_table,
        autotune=autotune, version=meta["version"])
