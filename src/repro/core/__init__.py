# The paper's primary contribution: DoReFa quantization + AND-Accumulation
# bit-wise GEMM/conv engine + compressor/NV-FA models. Sibling subpackages
# hold the substrates (models/, train/, distributed/, pim/, ...).
from .quant import (
    QuantConfig,
    PAPER_CONFIGS,
    FP32,
    W1A1,
    W1A4,
    W1A8,
    W2A2,
    quantize_weight,
    quantize_activation,
    quantize_gradient,
    weight_levels,
    activation_levels,
)
from .and_accum import bitgemm, quant_dense_forward, reference_float
from .conv_lowering import quant_conv2d, conv2d_float, im2col
from . import bitplane, compressor
