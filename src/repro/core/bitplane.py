"""Bit-plane decomposition & uint32 lane packing (paper Fig. 3).

The paper stores ``C_m(I)`` / ``C_n(W)`` — the m-th/n-th bit of every
element — as physical SOT-MRAM sub-array rows so that one row-parallel AND
computes all products of one plane pair.  The TPU analogue keeps each plane
packed 32 bits per ``uint32`` lane along the contraction axis: one VPU AND
processes 32 "cells" per lane per cycle, and ``lax.population_count``
replaces the sense-amp + compressor readout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 32  # bits packed per uint32 word


def decompose(levels: jax.Array, bits: int) -> jax.Array:
    """Integer levels -> bit planes, shape (bits, *levels.shape), {0,1} int32.

    plane[b] == C_b(levels): the b-th significance bit of every element.
    """
    levels = levels.astype(jnp.int32)
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * levels.ndim)
    return (jax.lax.shift_right_logical(levels[None], shifts) & 1).astype(jnp.int32)


def compose(planes: jax.Array) -> jax.Array:
    """Inverse of :func:`decompose` — planes (bits, ...) -> integer levels."""
    bits = planes.shape[0]
    weights = (jnp.int32(1) << jnp.arange(bits, dtype=jnp.int32)).reshape(
        (bits,) + (1,) * (planes.ndim - 1)
    )
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def pad_to_lane(x: jax.Array, axis: int = -1) -> jax.Array:
    """Zero-pad ``axis`` to a multiple of 32 (zeros AND to 0: exact)."""
    k = x.shape[axis]
    pad = (-k) % LANE
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis if axis >= 0 else x.ndim + axis] = (0, pad)
    return jnp.pad(x, cfg)


def pack_bits(plane: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a {0,1} plane 32-per-word along ``axis`` -> uint32.

    Shape (..., K, ...) -> (..., K/32, ...). K must be a multiple of 32
    (use :func:`pad_to_lane` first).
    """
    axis = axis if axis >= 0 else plane.ndim + axis
    k = plane.shape[axis]
    assert k % LANE == 0, f"K={k} not a multiple of {LANE}"
    new_shape = plane.shape[:axis] + (k // LANE, LANE) + plane.shape[axis + 1 :]
    x = plane.astype(jnp.uint32).reshape(new_shape)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32)).reshape(
        (1,) * (axis + 1) + (LANE,) + (1,) * (plane.ndim - axis - 1)
    )
    return jnp.sum(x * weights, axis=axis + 1, dtype=jnp.uint32)


def unpack_bits(packed: jax.Array, axis: int = -1, k: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_bits`; optionally truncate to original K."""
    axis = axis if axis >= 0 else packed.ndim + axis
    shifts = jnp.arange(LANE, dtype=jnp.uint32).reshape(
        (1,) * (axis + 1) + (LANE,) + (1,) * (packed.ndim - axis - 1)
    )
    bits = (jax.lax.shift_right_logical(jnp.expand_dims(packed, axis + 1), shifts) & 1)
    out_shape = packed.shape[:axis] + (packed.shape[axis] * LANE,) + packed.shape[axis + 1 :]
    out = bits.reshape(out_shape).astype(jnp.int32)
    if k is not None:
        out = jax.lax.slice_in_dim(out, 0, k, axis=axis)
    return out


def popcount(x: jax.Array) -> jax.Array:
    """Population count of uint32 words -> int32 (the paper's CMP unit)."""
    return jax.lax.population_count(x).astype(jnp.int32)


def decompose_packed(levels: jax.Array, bits: int, axis: int = -1) -> jax.Array:
    """levels -> (bits, ...) planes packed uint32 along ``axis`` (padded)."""
    planes = decompose(pad_to_lane(levels, axis), bits)
    return pack_bits(planes, axis=(axis if axis < 0 else axis + 1))
