"""Lower 2-D convolution onto the AND-Accumulation GEMM (paper §II-A).

The paper maps a convolution kernel sweep onto sub-array rows; the GEMM
identity behind that mapping is im2col:  conv(I, W) == patches(I) @ W' with
patches (B*OH*OW, kh*kw*Cin) and W' (kh*kw*Cin, Cout).  We reuse the same
identity so every conv layer runs on the bit-wise engine.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .and_accum import quant_dense_forward


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, padding: str = "SAME"):
    """x (B,H,W,C) -> patches (B,OH,OW,kh*kw*C)."""
    b, h, w, c = x.shape
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
        ph = max((oh - 1) * stride + kh - h, 0)
        pw = max((ow - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)))
    else:
        oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2),  # NCHW
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # (B, C*kh*kw, OH, OW)
    patches = patches.transpose(0, 2, 3, 1)  # (B,OH,OW,C*kh*kw)
    return patches


@partial(jax.jit, static_argnames=("stride", "padding", "a_bits", "w_bits", "engine"))
def quant_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    a_bits: int = 4,
    w_bits: int = 1,
    engine: str = "int8",
) -> jax.Array:
    """Bit-wise conv. x (B,H,W,Cin) in [0,1]; w (kh,kw,Cin,Cout) float."""
    kh, kw, cin, cout = w.shape
    patches = im2col(x, kh, kw, stride, padding)
    b, oh, ow, kdim = patches.shape
    # conv_general_dilated_patches emits channel-major (C, kh, kw) features;
    # align the weight layout to match before flattening to the GEMM axis.
    w2 = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    out = quant_dense_forward(
        patches.reshape(-1, kdim), w2, a_bits=a_bits, w_bits=w_bits, engine=engine
    )
    return out.reshape(b, oh, ow, cout)


def conv2d_float(x, w, *, stride: int = 1, padding: str = "SAME"):
    """fp oracle conv for the lowering tests (and fp first/last layers)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
