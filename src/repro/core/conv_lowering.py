"""Lower 2-D convolution onto the AND-Accumulation GEMM (paper §II-A).

The paper maps a convolution kernel sweep onto sub-array rows; the GEMM
identity behind that mapping is im2col:  conv(I, W) == patches(I) @ W' with
patches (B*OH*OW, kh*kw*Cin) and W' (kh*kw*Cin, Cout).  We reuse the same
identity so every conv layer runs on the bit-wise engine.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .and_accum import quant_dense_forward


def _out_hw(h: int, w: int, kh: int, kw: int, stride: int, padding: str):
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - kh) // stride + 1, (w - kw) // stride + 1


def pad_split(h: int, w: int, kh: int, kw: int, stride: int, padding: str):
    """((top, bottom), (left, right)) zero-pad — the SAME split SINGLE SOURCE.

    Every conv lowering (both im2col variants here, both implicit-GEMM
    realizations in ``kernels/conv_implicit.py``) must place padding via
    this function: the bit-identity contract between the patch-GEMM and
    implicit engines holds only while they agree on where the zeros go.
    """
    if padding == "VALID":
        return (0, 0), (0, 0)
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    ph = max((oh - 1) * stride + kh - h, 0)
    pw = max((ow - 1) * stride + kw - w, 0)
    return (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)


def im2col_sliced(x: jax.Array, kh: int, kw: int, stride: int = 1,
                  padding: str = "SAME") -> jax.Array:
    """Dtype-agnostic im2col via static strided slices (serve path).

    ``conv_general_dilated_patches`` only materializes *float* patches; the
    pre-quantized serve path extracts patches from the integer activation
    levels instead (int8, 4x less HBM traffic than f32 patches, for
    a_bits <= 7; int32 at 8 bits).  Feature layout is (kh, kw, C)-major,
    matching ``w.reshape(kh*kw*cin, cout)``.
    """
    b, h, w, c = x.shape
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0),) + pad_split(h, w, kh, kw, stride, padding)
                    + ((0, 0),))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(x[:, dy: dy + (oh - 1) * stride + 1: stride,
                          dx: dx + (ow - 1) * stride + 1: stride, :])
    return jnp.concatenate(cols, axis=-1)  # (B, OH, OW, kh*kw*C)


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1, padding: str = "SAME"):
    """x (B,H,W,C) -> patches (B,OH,OW,kh*kw*C)."""
    b, h, w, c = x.shape
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0),) + pad_split(h, w, kh, kw, stride, padding)
                    + ((0, 0),))
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2),  # NCHW
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # (B, C*kh*kw, OH, OW)
    patches = patches.transpose(0, 2, 3, 1)  # (B,OH,OW,C*kh*kw)
    return patches


@partial(jax.jit, static_argnames=("stride", "padding", "a_bits", "w_bits", "engine"))
def quant_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    a_bits: int = 4,
    w_bits: int = 1,
    engine: str | None = None,
) -> jax.Array:
    """Bit-wise conv. x (B,H,W,Cin) in [0,1]; w (kh,kw,Cin,Cout) float.

    Re-quantizes the float weights on every call — the seed serve path, kept
    as the training-checkpoint entry point and the benchmark baseline.  Use
    :func:`quant_conv2d_pre` with prequantized weights at serve time.
    ``engine=None`` dispatches via :func:`repro.kernels.ops.select_engine`.
    """
    from repro.kernels import ops  # deferred: kernels layer sits above core

    kh, kw, cin, cout = w.shape
    patches = im2col(x, kh, kw, stride, padding)
    b, oh, ow, kdim = patches.shape
    # conv_general_dilated_patches emits channel-major (C, kh, kw) features;
    # align the weight layout to match before flattening to the GEMM axis.
    w2 = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    if engine is None:
        engine = ops.select_engine(b * oh * ow, kdim, cout, a_bits, w_bits)
    if engine in ("fused", "faithful"):  # Pallas serve paths
        from .prequant import level_dtype
        from .quant import activation_levels, weight_levels

        w_lv, s_w, z_w = weight_levels(w2, w_bits)
        w_lv = w_lv.astype(level_dtype(w_bits))
        # quantize once up front (the fused kernel would otherwise re-run
        # the clip/round per N-tile revisit of each A tile)
        p_lv = activation_levels(patches.reshape(-1, kdim), a_bits)[0]
        out = ops.quant_dense_serve(p_lv.astype(level_dtype(a_bits)), w_lv,
                                    s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                                    engine=engine)
        out = out.astype(x.dtype)
    else:
        out = quant_dense_forward(
            patches.reshape(-1, kdim), w2, a_bits=a_bits, w_bits=w_bits,
            engine=engine)
    return out.reshape(b, oh, ow, cout)


@partial(jax.jit, static_argnames=("kh", "kw", "stride", "padding", "a_bits",
                                   "w_bits", "engine"))
def quant_conv2d_pre(
    x: jax.Array,
    w_lv: jax.Array,   # (kh*kw*cin, cout) pre-quantized int8 levels
    s_w: jax.Array,
    z_w: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    a_bits: int = 4,
    w_bits: int = 1,
    engine: str | None = None,
) -> jax.Array:
    """Fused serve conv on PRE-QUANTIZED weights (DESIGN.md §2.3).

    Differences vs :func:`quant_conv2d`, in dataflow order:
      * no per-call ``weight_levels`` — the int8 levels + (s_w, z_w) come
        from the checkpoint (the MRAM-resident C_n(W) analogue);
      * activations are quantized ONCE on the (B,H,W,C) image *before*
        patch extraction — kh*kw times less quantization work;
      * the conv dispatches via :func:`repro.kernels.ops.quant_conv_serve`:
        the ``implicit`` engine (auto-picked for deep-K spatial convs)
        extracts patches in-register — nothing kh*kw-amplified ever
        touches HBM — while the GEMM engines lower through
        ``im2col_sliced`` integer patches (int8, 4x less traffic than f32
        patches, for a_bits <= 7; int32 at 8 bits).

    Bit-identical to ``quant_conv2d(..., engine=<same>)``: quantization is
    elementwise so it commutes with patch extraction, zero padding maps to
    level 0 either way, and the integer GEMM is order-invariant.

    On the plan-compiled serve path (``repro.core.plan``, DESIGN.md §8)
    ``engine`` always arrives PINNED from the layer's :class:`LayerPlan` —
    the ``engine=None`` per-call dispatch survives only for direct kernel
    use and the benchmark baselines.
    """
    from repro.kernels import ops  # deferred: kernels layer sits above core
    from .prequant import level_dtype
    from .quant import activation_levels

    x_lv = activation_levels(x, a_bits)[0].astype(level_dtype(a_bits))
    out = ops.quant_conv_serve(x_lv, w_lv, s_w, z_w, kh=kh, kw=kw,
                               stride=stride, padding=padding,
                               a_bits=a_bits, w_bits=w_bits, engine=engine)
    return out.astype(x.dtype)


def conv2d_float(x, w, *, stride: int = 1, padding: str = "SAME"):
    """fp oracle conv for the lowering tests (and fp first/last layers)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
