"""Fixed-size block-pool KV allocator for the paged serve path.

The paper's resilience argument (arxiv 1904.07864 §IV) is that forward
progress survives power loss when state is retained at *fine granularity*;
the serving analogue is KV state held in fixed-size pages that requests
acquire on admission and release on retirement — no contiguous re-padding
(``launch/serve.grow_cache``) and no defragmentation, ever.  A request's
KV occupancy is a *page table* (an ordered list of page indices); freeing
is O(pages) list surgery, and a freed page is reusable immediately because
the device-side position buffer (``ppos``) is reset to -1 at the next
admission (stale positions would otherwise unmask a prior tenant's keys).

This module is pure host-side bookkeeping (no jax): the device pools and
the programs that read them live in ``models/transformer.py`` /
``kernels/attn_flash.py``; the continuous-batching scheduler that drives
both is ``launch/engine.ContinuousLMEngine``.

Reserved index: ``null_page == num_pages`` — one extra, never-allocated
page at the end of the device pools whose ``ppos`` stays -1 forever.  Table
rows pad to a fixed width with it, so gathering a padded row always lands
on masked slots.  Device-side writes never target it (invalid rows scatter
to index ``num_pages + 1``, out of bounds, with ``mode="drop"``).
"""
from __future__ import annotations

from collections import deque


class PoolExhausted(RuntimeError):
    """No free pages: admission control must defer (or shed) the request."""


def pages_needed(total_tokens: int, page_size: int) -> int:
    """Pages covering ``total_tokens`` KV positions (ragged final page)."""
    if total_tokens <= 0:
        return 0
    return -(-total_tokens // page_size)


class PagePool:
    """Free-list allocator over ``num_pages`` fixed-size KV pages.

    FIFO reuse (freed pages re-allocate in release order) keeps the
    allocation sequence a pure function of the request schedule — the
    deterministic-replay property the resilience checkpoints rely on.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need at least one page and one slot per page, "
                             f"got num_pages={num_pages}, page_size={page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.null_page = num_pages          # reserved: masked padding target
        self._free: deque[int] = deque(range(num_pages))
        self._owned: set[int] = set()
        # capacity accounting
        self.allocs = 0
        self.frees = 0
        self.high_water = 0

    # -- capacity -----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def can_fit(self, total_tokens: int) -> bool:
        """Could ``total_tokens`` of KV be admitted right now?"""
        return pages_needed(total_tokens, self.page_size) <= self.free_pages

    def capacity_tokens(self) -> int:
        """Upper bound on one request's KV extent (the whole pool)."""
        return self.num_pages * self.page_size

    # -- alloc / free -------------------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` pages; raises :class:`PoolExhausted` (allocating
        nothing) when fewer than ``n`` are free."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise PoolExhausted(
                f"{n} page(s) requested, {len(self._free)} free "
                f"(pool: {self.num_pages} x {self.page_size} tokens)")
        pages = [self._free.popleft() for _ in range(n)]
        self._owned.update(pages)
        self.allocs += n
        self.high_water = max(self.high_water, self.used_pages)
        return pages

    def free(self, pages) -> None:
        """Return pages to the pool.  Double-free and foreign indices are
        programming errors (they would alias two requests' KV) — raise."""
        for p in pages:
            if p not in self._owned:
                raise ValueError(f"page {p} is not currently allocated "
                                 "(double free, or foreign index)")
        for p in pages:
            self._owned.discard(p)
            self._free.append(p)
            self.frees += 1

    def stats(self) -> dict:
        return dict(num_pages=self.num_pages, page_size=self.page_size,
                    used_pages=self.used_pages, free_pages=self.free_pages,
                    high_water=self.high_water, allocs=self.allocs,
                    frees=self.frees)

    # -- checkpointing --------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-serializable allocator state.  The free list is saved *in
        order*: FIFO reuse order is part of the deterministic-replay
        contract, so a restored pool must hand out the same pages the
        original would have."""
        return dict(num_pages=self.num_pages, page_size=self.page_size,
                    free=list(self._free), owned=sorted(self._owned),
                    allocs=self.allocs, frees=self.frees,
                    high_water=self.high_water)

    def restore(self, snap: dict) -> None:
        """Overwrite this pool's state with a :meth:`snapshot`.  Geometry
        must match — a checkpoint from a differently-sized pool would alias
        page indices."""
        if (snap["num_pages"] != self.num_pages
                or snap["page_size"] != self.page_size):
            raise ValueError(
                f"pool geometry mismatch: snapshot is "
                f"{snap['num_pages']}x{snap['page_size']}, pool is "
                f"{self.num_pages}x{self.page_size}")
        self._free = deque(int(p) for p in snap["free"])
        self._owned = {int(p) for p in snap["owned"]}
        self.allocs = int(snap["allocs"])
        self.frees = int(snap["frees"])
        self.high_water = int(snap["high_water"])
