"""Serve-time weight pre-quantization — the TPU analogue of MRAM residency.

The paper's engine never re-derives the weight bit-planes: C_n(W) is written
into the SOT-MRAM sub-array once and stays resident across every inference
(that residency is also what makes the design power-intermittency resilient —
the planes are non-volatile).  The seed serve path instead re-ran
``weight_levels`` on the float weights for every layer of every forward
call.  This module quantizes all conv/FC weights ONCE at model load into
int8 levels + per-layer ``(s_w, z_w)``, stored in the params pytree in the
exact GEMM layout the serve kernels consume.

Since the ModelPlan IR (``repro.core.plan``, DESIGN.md §8) this module is
a PLAN-CONSTRUCTION step, not a call-time decision: ``compile_model`` /
``compile_lm`` invoke ``prequantize_cnn_params`` (CNN) or
:func:`repro.models.layers.prequantize_params` (transformer) exactly once
per plan, and the resulting levels serialize with the plan (npz) so a
restarted node never requantizes.  (The PR-4
``models/cnn.prepare_serve_params`` deprecation shim over this module was
removed in PR 5 — compile a plan, or call ``prequantize_cnn_params``
directly in tests.)
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from .quant import QuantConfig, weight_levels


def level_dtype(bits: int):
    """Narrowest signed dtype holding unsigned ``bits``-wide levels."""
    return jnp.int8 if (1 << bits) - 1 <= 127 else jnp.int32


def prequantize_conv_weight(w, w_bits: int):
    """(kh, kw, cin, cout) float -> ((kh*kw*cin, cout) levels, s_w, z_w).

    The flattened axis is (kh, kw, cin)-major — the layout
    :func:`repro.core.conv_lowering.im2col_sliced` emits, so serve-time
    GEMMs consume the stored levels with zero per-call relayout.
    """
    lv, s_w, z_w = weight_levels(w, w_bits)
    return lv.reshape(-1, w.shape[-1]).astype(level_dtype(w_bits)), s_w, z_w


def is_fp_layer(spec_entry, quant: QuantConfig) -> bool:
    return quant.engine == "fp" or quant.w_bits >= 32 or (
        spec_entry.role in ("first", "last") and quant.first_last_fp)


def prequantize_cnn_params(params, spec: Sequence, quant: QuantConfig):
    """Per-layer serve params: quantized layers swap the float ``w`` for
    ``{w_lv, s_w, z_w}`` (bias/norm params unchanged); fp layers pass
    through untouched."""
    out = []
    for p, s in zip(params, spec):
        if is_fp_layer(s, quant):
            out.append(dict(p))
            continue
        w_lv, s_w, z_w = prequantize_conv_weight(p["w"], quant.w_bits)
        q = {k: v for k, v in p.items() if k != "w"}
        q.update(w_lv=w_lv, s_w=s_w, z_w=z_w)
        out.append(q)
    return out


def serve_weight_bytes(params) -> int:
    """Weight bytes the serve path reads per forward (traffic accounting)."""
    total = 0
    for p in params:
        if "w_lv" in p:
            total += p["w_lv"].size * p["w_lv"].dtype.itemsize
        elif "w" in p:
            total += p["w"].size * p["w"].dtype.itemsize
    return total
