"""4:2 compressor-tree model (paper §II-B1, Fig. 5) + ASR/NV-FA cycle math.

The TPU port does not *execute* compressors (the MXU adder tree subsumes
them — see DESIGN.md §2), but the PIM simulator needs their cycle/energy
structure to reproduce the paper's Fig. 9/10 comparisons, where the win
over IMCE comes precisely from replacing a serial counter with this tree.
"""
from __future__ import annotations

import dataclasses
import math


def compressor_outputs(x1: int, x2: int, x3: int, x4: int, cin: int):
    """Golden 4:2 compressor truth function (paper Eq. 2).

    Returns (sum, carry, cout) with x1+x2+x3+x4+cin == sum + 2*(carry+cout).
    """
    xor4 = x1 ^ x2 ^ x3 ^ x4
    s = xor4 ^ cin
    carry = (xor4 & cin) | ((1 - xor4) & x4)
    cout = ((x1 ^ x2) & x3) | ((1 - (x1 ^ x2)) & x1)
    return s, carry, cout


def compress_vector(bits: list[int]) -> int:
    """Count ones via a 4:2 compressor tree (CMP) — used as a golden model."""
    return sum(bits)


def tree_depth(n_inputs: int) -> int:
    """Levels of 4:2 compressors to reduce n partial products to 2."""
    levels = 0
    n = n_inputs
    while n > 2:
        n = math.ceil(n / 2)  # each 4:2 level halves the operand count
        levels += 1
    return levels


def serial_counter_cycles(n_inputs: int) -> int:
    """IMCE-style serial bitcount: one shift+add cycle per input bit."""
    return n_inputs


def compressor_cycles(n_inputs: int) -> int:
    """Paper's claim: the in-memory 4:2 compressor counts a sub-array row's
    ones in one pass (one XOR/XNOR memory update + tree settle) instead of
    n serial cycles.  We charge 1 cycle for the in-memory XOR write-back
    plus the (pipelined) tree latency amortized to O(1) per row.
    """
    return 1 + tree_depth(n_inputs) // max(tree_depth(n_inputs), 1)


def asr_shift_cycles(m_bits: int, n_bits: int) -> int:
    """Adaptive shift register: shifts up to m+n-2, realized MUX-parallel."""
    return 1  # MUX-select, single cycle (paper Fig. 6)


@dataclasses.dataclass(frozen=True)
class NVFATiming:
    """NV-FA restore window (paper §II-B3): power loss during the final
    shift/add loses only the in-flight adds, ~ (m+n) FA delays of 58ps."""

    fa_delay_ps: float = 58.0

    def vulnerable_window_ps(self, m_bits: int, n_bits: int) -> float:
        return (m_bits + n_bits) * self.fa_delay_ps
