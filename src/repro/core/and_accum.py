"""AND-Accumulation bit-wise GEMM — the paper's Eq. (1), TPU-adapted.

    I * W = sum_m sum_n 2^(m+n) CMP(AND(C_n(W), C_m(I)))

Three engines, all *integer-exact* and validated against each other:

``planes``  Paper-faithful dataflow in jnp: explicit bit-plane AND,
            popcount via summation (the CMP compressor tree), parallel
            shift realized as the 2^(m+n) static weighting.
``packed``  Same dataflow with planes packed 32/uint32 lane and
            ``lax.population_count`` — the VPU realization; this is the
            dataflow the Pallas kernel in ``repro.kernels.bitgemm`` tiles
            into VMEM.
``int8``    Beyond-paper TPU mapping: a {0,1}-plane dot-product *is* an
            integer matmul, so the MXU's systolic adder tree subsumes the
            4:2 compressor tree.  For bits <= 7 all plane-pair sums are
            folded into a single int8 x int8 -> int32 matmul on the levels
            themselves (the 2^(m+n) shifts distribute:
            sum_mn 2^(m+n) P_m(A)P_n(W) == levels_A . levels_W).

Signed/affine correction: with a = s_a * A (A uint levels) and
w = s_w * (W - z_w), the float GEMM is recovered as
    a @ w = s_a*s_w * (A @ W) - s_a*s_w*z_w * rowsum(A)
(rowsum(A) is one extra popcount pass in hardware — the paper's EPU
handles it; here it is a cheap reduction).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import bitplane


def bitgemm_planes(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Paper-faithful Eq. (1). a_lv (M,K) uint levels, w_lv (K,N) -> int32 (M,N)."""
    pa = bitplane.decompose(a_lv, a_bits)  # (m, M, K)
    pw = bitplane.decompose(w_lv, w_bits)  # (n, K, N)
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for m in range(a_bits):
        for n in range(w_bits):
            # AND of {0,1} planes == elementwise product; CMP == sum over K.
            cmp = jnp.einsum(
                "mk,kn->mn", pa[m], pw[n], preferred_element_type=jnp.int32
            )
            out = out + (cmp << (m + n))  # parallel shift (ASR analogue)
    return out


def bitgemm_packed(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """uint32-packed AND + popcount (VPU dataflow). Exact, O(M*N*K/32) lanes."""
    pa = bitplane.decompose_packed(a_lv, a_bits, axis=-1)          # (m, M, Kw)
    pw = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)        # (n, N, Kw)
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for m in range(a_bits):
        for n in range(w_bits):
            anded = pa[m][:, None, :] & pw[n][None, :, :]          # (M,N,Kw)
            cmp = jnp.sum(bitplane.popcount(anded), axis=-1)
            out = out + (cmp << (m + n))
    return out


def _nibble_split(lv: jax.Array, bits: int):
    """Split integer levels into <=7-bit groups: lv == sum_i grp_i << sh_i.

    int8 MXU operands must stay < 128; W1A8 (the paper's best-accuracy
    point) therefore splits its 8-bit activations into two nibbles — two
    int8 matmuls instead of 8 plane matmuls, still exact.
    """
    if bits <= 7:
        return [(lv, 0)]
    groups, shift = [], 0
    while shift < bits:
        g = min(4, bits - shift)
        groups.append(((jax.lax.shift_right_logical(lv, shift) & ((1 << g) - 1)), shift))
        shift += g
    return groups


def bitgemm_int8(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """MXU mapping: int8 matmul(s) on the integer levels (nibble-split >7b)."""
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for ga, sa in _nibble_split(a_lv, a_bits):
        for gw, sw in _nibble_split(w_lv, w_bits):
            d = jnp.dot(ga.astype(jnp.int8), gw.astype(jnp.int8),
                        preferred_element_type=jnp.int32)
            out = out + (d << (sa + sw))
    return out


def bitgemm_int8_planewise(a_lv, w_lv, a_bits, w_bits):
    """MXU mapping, plane-pair granularity (the literal Eq. (1) on MXU)."""
    pa = bitplane.decompose(a_lv, a_bits).astype(jnp.int8)
    pw = bitplane.decompose(w_lv, w_bits).astype(jnp.int8)
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for m in range(a_bits):
        for n in range(w_bits):
            out = out + (jnp.dot(pa[m], pw[n], preferred_element_type=jnp.int32) << (m + n))
    return out


def f32dot_exact(k: int, a_bits: int, w_bits: int) -> bool:
    """Exactness bound for :func:`bitgemm_f32dot`: every partial sum is an
    integer inside the fp32 mantissa."""
    return ((1 << a_bits) - 1) * ((1 << w_bits) - 1) * max(k, 1) < (1 << 24)


def bitgemm_f32dot(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Float-unit realization of the level GEMM — exact while
    ``a_max * w_max * K < 2^24``.  On CPU/GPU backends XLA lowers integer
    matmuls to scalar loops, so routing the exact computation through the
    float GEMM is the fast path.  The bound is enforced here (shape and
    bit-widths are static), so an explicit ``engine="f32dot"`` cannot
    silently round; HIGHEST precision keeps TPU/GPU matmul units from
    truncating the f32 inputs.
    """
    # defense-in-depth: plan-dispatched calls arrive with this already
    # proven statically (repro.analysis prover, PV101) — only direct
    # un-planned calls can trip it
    if not f32dot_exact(a_lv.shape[-1], a_bits, w_bits):
        raise ValueError(
            f"f32dot engine inexact for a_bits={a_bits}, w_bits={w_bits}, "
            f"K={a_lv.shape[-1]} (accumulator exceeds the fp32 mantissa); "
            "use engine='int8'")
    d = jnp.dot(a_lv.astype(jnp.float32), w_lv.astype(jnp.float32),
                precision=jax.lax.Precision.HIGHEST)
    return d.astype(jnp.int32)


_ENGINES = {
    "planes": bitgemm_planes,
    "packed": bitgemm_packed,
    "int8": bitgemm_int8,
    "int8_planewise": bitgemm_int8_planewise,
    "f32dot": bitgemm_f32dot,
}


@partial(jax.jit, static_argnames=("a_bits", "w_bits", "engine"))
def bitgemm(a_lv, w_lv, a_bits: int, w_bits: int, engine: str = "int8") -> jax.Array:
    """Integer-level GEMM dispatch. All engines are bit-exact equal
    (``f32dot`` raises when its mantissa bound would make it inexact)."""
    return _ENGINES[engine](a_lv, w_lv, a_bits, w_bits)


def quant_dense_forward(
    a: jax.Array,
    w: jax.Array,
    a_bits: int,
    w_bits: int,
    engine: str = "int8",
) -> jax.Array:
    """Float-in/float-out quantized dense using the integer engine.

    ``a`` (..., K) activations (pre-clipped to [0,1] by the caller's
    activation function, as in DoReFa), ``w`` (K, N) weights.  Returns the
    AND-Accumulation GEMM result de-quantized to float.  Bit-exact w.r.t.
    quantize->float-matmul because every intermediate is an exact int32.
    """
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    from .quant import activation_levels, weight_levels  # local to avoid cycle

    a_lv, s_a = activation_levels(a2, a_bits)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    acc = _ENGINES[engine](a_lv, w_lv, a_bits, w_bits)
    out = dequant_epilogue(acc, a_lv, s_w, z_w, a_bits, a.dtype)  # EPU pass
    return out.reshape(lead + (w.shape[-1],))


def dequant_epilogue(acc, a_lv, s_w, z_w, a_bits: int, out_dtype=jnp.float32):
    """Affine-correction + dequant for the unsigned (DoReFa) level GEMM:
    ``out = s_a*s_w*acc − s_a*s_w*z_w*rowsum(A)``.  Single source of truth —
    the fused Pallas kernel mirrors this expression, and the bit-identity
    tests rely on every unfused path sharing it."""
    s_a = jnp.asarray(1.0 / ((1 << a_bits) - 1), out_dtype)
    acc = acc.astype(out_dtype)
    rowsum = jnp.sum(a_lv, axis=-1, dtype=jnp.int32).astype(out_dtype)
    return (s_a * s_w) * acc - (s_a * s_w * z_w) * rowsum[:, None]


def quant_dense_pre_levels(
    a_lv: jax.Array, w_lv: jax.Array, s_w, z_w, a_bits: int, w_bits: int,
    engine: str = "int8", out_dtype=jnp.float32,
) -> jax.Array:
    """Unsigned (DoReFa) dense on PRE-QUANTIZED operands: integer activation
    levels in, int8 weight levels + (s_w, z_w) from the checkpoint in.

    The serve-side core of :func:`quant_dense_forward` with every per-call
    re-quantization removed; same epilogue expression, so outputs are
    bit-identical to the re-quantizing path.
    """
    acc = _ENGINES[engine](a_lv.astype(jnp.int32), w_lv.astype(jnp.int32),
                           a_bits, w_bits)
    return dequant_epilogue(acc, a_lv, s_w, z_w, a_bits, out_dtype)


def quant_dense_forward_pre(
    a: jax.Array, w_lv: jax.Array, s_w, z_w, a_bits: int, w_bits: int,
    engine: str = "int8",
) -> jax.Array:
    """Unsigned quantized dense with pre-quantized weights (float acts in)."""
    from .quant import activation_levels

    lead = a.shape[:-1]
    a_lv, _ = activation_levels(a.reshape((-1, a.shape[-1])), a_bits)
    out = quant_dense_pre_levels(a_lv, w_lv, s_w, z_w, a_bits, w_bits,
                                 engine=engine)
    return out.reshape(lead + (w_lv.shape[-1],)).astype(a.dtype)


def quant_dense_forward_signed(
    a: jax.Array, w: jax.Array, a_bits: int, w_bits: int, engine: str = "int8",
    a_scale_mode: str = "tensor",
) -> jax.Array:
    """Signed-activation quantized dense (transformers): full affine correction.

    a = s_a*(A - z_a), w = s_w*(W - z_w)  =>
    a@w = s_a s_w [A@W - z_w*rowsum(A) - z_a*colsum(W) + K*z_a*z_w]
    All four terms exact int32; only the final scaling is float.

    ``a_scale_mode='row'`` uses a per-row activation absmax (s_a becomes
    (M, 1)) — the correction algebra is unchanged because z_a stays the
    constant 2^(b-1); see ``core.quant.activation_levels_signed_row``.
    """
    from .quant import (activation_levels_signed,
                        activation_levels_signed_row, weight_levels)

    lead = a.shape[:-1]
    K = a.shape[-1]
    a2 = a.reshape((-1, K))
    lv_fn = (activation_levels_signed_row if a_scale_mode == "row"
             else activation_levels_signed)
    a_lv, s_a, z_a = lv_fn(a2, a_bits)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    acc = _ENGINES[engine](a_lv, w_lv, a_bits, w_bits).astype(jnp.float32)
    rowsum = jnp.sum(a_lv, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(w_lv, axis=0, dtype=jnp.int32).astype(jnp.float32)
    out = acc - z_w * rowsum[:, None] - z_a * colsum[None, :] + K * z_a * z_w
    out = (s_a * s_w) * out
    return out.reshape(lead + (w.shape[-1],)).astype(a.dtype)


def quant_dense_forward_signed_pre(
    a: jax.Array, w_lv: jax.Array, s_w, z_w, a_bits: int, w_bits: int,
    engine: str = "int8", a_scale: "float | str | None" = None,
) -> jax.Array:
    """Signed quantized dense with PRE-QUANTIZED weights (int8 levels stored
    in the checkpoint — the TPU analogue of keeping C_n(W) resident in the
    SOT-MRAM sub-array).  4x less weight HBM traffic than fp32 at serve.

    ``a_scale`` selects the activation-scale source: a float installs a
    static (offline-calibrated) scale, the string ``'row'`` a per-row
    dynamic absmax (batch-independent numerics for continuous batching),
    and ``None`` the default per-tensor dynamic absmax."""
    from .quant import activation_levels_signed, activation_levels_signed_row

    lead = a.shape[:-1]
    K = a.shape[-1]
    a2 = a.reshape((-1, K))
    if a_scale == "row":
        a_lv, s_a, z_a = activation_levels_signed_row(a2, a_bits)
    elif a_scale is not None:
        # static (offline-calibrated) activation scale: no dynamic absmax
        # reduction (and no cross-shard all-reduce) on the serve path
        n = (1 << a_bits) - 1
        z_a = jnp.asarray(float(1 << (a_bits - 1)), jnp.float32)
        s_a = jnp.asarray(a_scale, jnp.float32)
        a_lv = jnp.clip(jnp.round(a2.astype(jnp.float32) / s_a) + z_a,
                        0, n).astype(jnp.int32)
    else:
        a_lv, s_a, z_a = activation_levels_signed(a2, a_bits)
    acc = _ENGINES[engine](a_lv, w_lv.astype(jnp.int32), a_bits, w_bits
                           ).astype(jnp.float32)
    rowsum = jnp.sum(a_lv, axis=-1, dtype=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(w_lv.astype(jnp.int32), axis=0,
                     dtype=jnp.int32).astype(jnp.float32)
    out = acc - z_w * rowsum[:, None] - z_a * colsum[None, :] + K * z_a * z_w
    out = (s_a * s_w) * out
    return out.reshape(lead + (w_lv.shape[-1],)).astype(a.dtype)


def reference_float(a, w, a_bits, w_bits):
    """Quantize-dequantize float matmul — the semantic oracle for the above."""
    from .quant import activation_levels, weight_levels

    a_lv, s_a = activation_levels(a.reshape((-1, a.shape[-1])), a_bits)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    aq = a_lv.astype(jnp.float32) * s_a
    wq = (w_lv.astype(jnp.float32) - z_w) * s_w
    return (aq @ wq).reshape(a.shape[:-1] + (w.shape[-1],))
