"""DoReFa-style low-bitwidth quantizers (paper §II, refs [2]).

The paper quantizes weights/activations to {1,2,4,8}-bit with 8-bit
gradients, keeping first/last layers full precision. We implement the
DoReFa forms with straight-through estimators plus the *integer-level*
views (`levels`, `scale`, `zero_point`) consumed by the AND-Accumulation
engine in :mod:`repro.core.and_accum`.

Closed-form computation complexity (paper Table I, cols 3-4):
  inference = w_bits * a_bits          (bit-plane pairs per MAC)
  training  = w_bits * a_bits + w_bits * g_bits
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Bit-width configuration, e.g. the paper's W:I = 1:4 with 8-bit grads."""

    w_bits: int = 1
    a_bits: int = 4
    g_bits: int = 8
    # Paper (and DoReFa / XNOR-Net) keep first & last layers full precision.
    first_last_fp: bool = True
    # Engine selection: 'auto' (backend/shape dispatch via
    # repro.kernels.ops.select_engine — fused Pallas on TPU, exact float or
    # int8 GEMM elsewhere), 'planes' (paper-faithful AND+popcount), 'packed'
    # (uint32-packed AND+popcount), 'int8' (MXU-mapped, beyond-paper),
    # 'f32dot' (exact float-unit GEMM), 'fp' (no bitwise engine;
    # quantize-dequantize only).
    engine: str = "auto"
    # Dynamic activation-scale granularity on the signed serve path:
    # 'tensor' (one absmax over the whole dispatched batch — the default,
    # matching the paper's per-tensor DoReFa levels) or 'row' (one absmax
    # per GEMM row).  'row' makes every sample's numerics independent of
    # its batchmates — required by the continuous-batching engine, whose
    # slots hold unrelated in-flight requests (a shared absmax would let
    # one request perturb another's integer levels).  Ignored when a
    # static calibrated scale is installed (models.layers.set_static_act_scale).
    act_scale_mode: str = "tensor"

    @property
    def inference_complexity(self) -> int:
        return self.w_bits * self.a_bits

    @property
    def training_complexity(self) -> int:
        return self.w_bits * self.a_bits + self.w_bits * self.g_bits

    def tag(self) -> str:
        return f"w{self.w_bits}a{self.a_bits}g{self.g_bits}"


FP32 = QuantConfig(w_bits=32, a_bits=32, g_bits=32, engine="fp")
# The paper's four evaluated points (Table I).
W1A1 = QuantConfig(1, 1, 8)
W1A4 = QuantConfig(1, 4, 8)
W1A8 = QuantConfig(1, 8, 8)
W2A2 = QuantConfig(2, 2, 8)
PAPER_CONFIGS = {"w32a32": FP32, "w1a1": W1A1, "w1a4": W1A4, "w1a8": W1A8, "w2a2": W2A2}


def _ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Straight-through estimator: forward q, backward identity."""
    return x + jax.lax.stop_gradient(q - x)


def quantize_k(x: jax.Array, bits: int) -> jax.Array:
    """DoReFa quantize_k: x in [0,1] -> k-bit levels in [0,1] (STE)."""
    n = (1 << bits) - 1
    q = jnp.round(x * n) / n
    return _ste(x, q)


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array, bits: int) -> jax.Array:
    """DoReFa weight quantizer (float output, STE).

    1-bit:  sign(w) * E[|w|]            (XNOR-Net style scaled binarization)
    k-bit:  2 * quantize_k(tanh(w) / (2 max|tanh(w)|) + 1/2) - 1
    """
    if bits >= 32:
        return w
    if bits == 1:
        alpha = jnp.mean(jnp.abs(w))
        q = jnp.where(w >= 0, alpha, -alpha)
        return _ste(w, q)
    t = jnp.tanh(w)
    t = t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5
    return 2.0 * quantize_k(t, bits) - 1.0


def weight_levels(w: jax.Array, bits: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Integer-level view of the quantized weight: w_q = scale*(levels - zp).

    levels is uint in [0, 2^bits - 1]; gradients do not flow through this
    view (it feeds the integer engine; STE is applied by the caller on the
    float view).
    """
    n = (1 << bits) - 1
    if bits == 1:
        alpha = jnp.mean(jnp.abs(w))
        levels = (w >= 0).astype(jnp.int32)  # {0,1}
        scale = 2.0 * alpha
        zp = 0.5  # w_q = 2a*(b - 1/2) = a*sign
        return levels, scale, jnp.asarray(zp, w.dtype)
    t = jnp.tanh(w)
    t = t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5  # in [0,1]
    levels = jnp.clip(jnp.round(t * n), 0, n).astype(jnp.int32)
    # w_q = 2*levels/n - 1 = (2/n)*(levels - n/2)
    scale = jnp.asarray(2.0 / n, w.dtype)
    zp = jnp.asarray(n / 2.0, w.dtype)
    return levels, scale, zp


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def quantize_activation(a: jax.Array, bits: int) -> jax.Array:
    """DoReFa activation quantizer: clip to [0,1] then k-bit (STE)."""
    if bits >= 32:
        return a
    return quantize_k(jnp.clip(a, 0.0, 1.0), bits)


def activation_levels(a: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    """Integer-level view: a_q = levels / (2^bits - 1), levels uint."""
    n = (1 << bits) - 1
    levels = jnp.clip(jnp.round(jnp.clip(a, 0.0, 1.0) * n), 0, n).astype(jnp.int32)
    return levels, jnp.asarray(1.0 / n, a.dtype)


def activation_levels_signed(a: jax.Array, bits: int):
    """Affine (signed) integer-level view for transformer activations.

    The paper's CNN activations are bounded [0,1] (DoReFa); transformer
    activations are signed, so we use the affine form a_q = s*(levels - z)
    with z = 2^(b-1) and dynamic per-tensor absmax scaling.  The unsigned
    bit-plane AND-Accumulation engine is unchanged — signedness is a
    zero-point correction handled by one extra reduction (DESIGN.md §4).

    Returns (levels uint in [0, 2^b-1], scale, zero_point).
    """
    n = (1 << bits) - 1
    z = float(1 << (bits - 1))
    s = jnp.max(jnp.abs(a)) / z + 1e-12
    levels = jnp.clip(jnp.round(a / s) + z, 0, n).astype(jnp.int32)
    return levels, s.astype(a.dtype), jnp.asarray(z, a.dtype)


def activation_levels_signed_row(a: jax.Array, bits: int):
    """Per-ROW variant of :func:`activation_levels_signed`.

    a is (M, K); the scale is a per-row absmax, shape (M, 1), so row m's
    levels depend on row m alone.  This is the batch-independence form the
    continuous-batching serve path requires (``QuantConfig.act_scale_mode
    == 'row'``): a decode slot's integer levels — and therefore its output
    bits — cannot change when unrelated requests join or leave the batch.
    The zero point is the same constant 2^(b-1).
    """
    n = (1 << bits) - 1
    z = float(1 << (bits - 1))
    s = jnp.max(jnp.abs(a), axis=-1, keepdims=True) / z + 1e-12
    levels = jnp.clip(jnp.round(a / s) + z, 0, n).astype(jnp.int32)
    return levels, s.astype(a.dtype), jnp.asarray(z, a.dtype)


def fake_quant_act_signed(a: jax.Array, bits: int) -> jax.Array:
    """STE float view of :func:`activation_levels_signed`."""
    if bits >= 32:
        return a
    n = (1 << bits) - 1
    z = float(1 << (bits - 1))
    s = jax.lax.stop_gradient(jnp.max(jnp.abs(a))) / z + 1e-12
    q = (jnp.clip(jnp.round(a / s) + z, 0, n) - z) * s
    return _ste(a, q)


# ---------------------------------------------------------------------------
# Gradients (DoReFa Eq. 12: stochastic k-bit gradient quantization)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_gradient(x: jax.Array, bits: int, key: Optional[jax.Array] = None):
    """Identity forward; backward quantizes the incoming gradient to k bits."""
    return x


def _qg_fwd(x, bits, key=None):
    return x, key


def _qg_bwd(bits, key, g):
    if bits >= 32:
        return (g, None)
    n = (1 << bits) - 1
    mx = 2.0 * jnp.max(jnp.abs(g)) + 1e-12
    gn = g / mx + 0.5  # in [0,1]
    if key is not None:
        noise = (jax.random.uniform(key, g.shape, g.dtype) - 0.5) / n
        gn = gn + noise
    q = jnp.clip(jnp.round(gn * n), 0, n) / n
    return (mx * (q - 0.5), None)


quantize_gradient.defvjp(_qg_fwd, _qg_bwd)


def fake_quant_dense_weight(w: jax.Array, cfg: QuantConfig, is_first_last: bool = False):
    if cfg.engine == "fp" or (is_first_last and cfg.first_last_fp):
        return w
    return quantize_weight(w, cfg.w_bits)


def fake_quant_act(a: jax.Array, cfg: QuantConfig, is_first_last: bool = False):
    if cfg.engine == "fp" or (is_first_last and cfg.first_last_fp):
        return a
    return quantize_activation(a, cfg.a_bits)


def model_storage_bits(n_params: int, n_acts: int, w_bits: int, a_bits: int) -> int:
    """Fig. 8 storage model: parameter bits + activation buffer bits."""
    return n_params * w_bits + n_acts * a_bits
