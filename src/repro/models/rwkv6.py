"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay linear
attention + channel mix.  Attention-free; O(1) decode state.

Paper-technique applicability (DESIGN.md §Arch-applicability): the R/K/V/G/O
and channel-mix projections run through :func:`qdense` (AND-Accumulation
engine when quantized); the decay LoRA and the recurrence itself are
non-GEMM fp dynamics and stay fp.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import dense_init, norm_init, qdense, rms_norm

N_LORA = 5  # w, k, v, r, g


def init_rwkv_block(key, cfg, plan):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    r = cfg.lora_rank
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    p["ln1"], a["ln1"] = norm_init(d, cfg.param_dtype)
    p["ln2"], a["ln2"] = norm_init(d, cfg.param_dtype)
    # token-shift ddlerp
    p["mu_base"] = jnp.zeros((d,), cfg.param_dtype); a["mu_base"] = ("embed",)
    p["mus"] = jnp.zeros((N_LORA, d), cfg.param_dtype); a["mus"] = (None, "embed")
    p["lora_A"] = jax.random.normal(ks[0], (d, N_LORA, r), cfg.param_dtype) * 0.01
    a["lora_A"] = ("embed", None, None)
    p["lora_B"] = jax.random.normal(ks[1], (N_LORA, r, d), cfg.param_dtype) * 0.01
    a["lora_B"] = (None, None, "embed")
    # decay base
    p["lam"] = jnp.full((d,), -2.0, cfg.param_dtype); a["lam"] = ("embed",)
    p["u"] = jnp.zeros((H, hd), cfg.param_dtype); a["u"] = ("heads", None)
    for nm, kk in zip(("wr", "wk", "wv", "wg"), ks[2:6]):
        p[nm], a[nm] = dense_init(kk, d, d, ("embed", "heads"), cfg.param_dtype)
    p["wo"], a["wo"] = dense_init(ks[6], d, d, ("heads", "embed"), cfg.param_dtype)
    p["ln_x"] = jnp.ones((H, hd), cfg.param_dtype); a["ln_x"] = ("heads", None)
    # channel mix
    p["cm_mu_k"] = jnp.zeros((d,), cfg.param_dtype); a["cm_mu_k"] = ("embed",)
    p["cm_mu_r"] = jnp.zeros((d,), cfg.param_dtype); a["cm_mu_r"] = ("embed",)
    p["cm_wk"], a["cm_wk"] = dense_init(ks[7], d, cfg.d_ff, ("embed", "mlp"), cfg.param_dtype)
    p["cm_wv"], a["cm_wv"] = dense_init(ks[8], cfg.d_ff, d, ("mlp", "embed"), cfg.param_dtype)
    p["cm_wr"], a["cm_wr"] = dense_init(ks[9], d, d, ("embed", "heads"), cfg.param_dtype)
    return p, a


def _shift(x, last):
    """Token shift: x_{t-1} with carry-in `last` (B,d) (zeros at t=0 train)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Linear-attention recurrence.

    r,k,w (B,S,H,K); v (B,S,H,V); u (H,K); s0 (B,H,K,V).
    o_t = r_t . (S + u*k_t (x) v_t);  S <- diag(w_t) S + k_t (x) v_t
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,K)/(B,H,V)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s) + (
            jnp.sum(r_t * u[None] * k_t, axis=-1, keepdims=True) * v_t
        )
        s = w_t[..., None] * s + kv
        return s, o

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, os_ = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
    return jnp.moveaxis(os_, 0, 1), s  # (B,S,H,V), final state


def rwkv_block_fwd(p, x, cfg, plan, *, mode: str, state=None):
    """x (B,S,d). state: dict(tm_x, cm_x (B,d), s (B,H,K,V)) or None (train).

    Returns (out, new_state).
    """
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    if state is None:
        state = dict(
            tm_x=jnp.zeros((B, d), x.dtype),
            cm_x=jnp.zeros((B, d), x.dtype),
            s=jnp.zeros((B, H, hd, hd), jnp.float32),
        )
    # ---- time mix ----
    h = rms_norm(x, p["ln1"])
    prev = _shift(h, state["tm_x"])
    dx = prev - h
    xxx = h + dx * p["mu_base"].astype(h.dtype)
    sel = jnp.tanh(jnp.einsum("bsd,dnr->bsnr", xxx, p["lora_A"].astype(h.dtype)))
    sel = jnp.einsum("bsnr,nrd->bsnd", sel, p["lora_B"].astype(h.dtype))
    mixed = h[:, :, None, :] + dx[:, :, None, :] * (
        p["mus"].astype(h.dtype)[None, None] + sel
    )  # (B,S,5,d)
    xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(N_LORA))
    w = jnp.exp(-jnp.exp(p["lam"].astype(jnp.float32) + xw.astype(jnp.float32)))
    r = qdense(xr, p["wr"], cfg.quant).reshape(B, S, H, hd)
    k = qdense(xk, p["wk"], cfg.quant).reshape(B, S, H, hd)
    v = qdense(xv, p["wv"], cfg.quant).reshape(B, S, H, hd)
    g = jax.nn.silu(qdense(xg, p["wg"], cfg.quant))
    wh = w.reshape(B, S, H, hd)
    o, s_new = _wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        wh, p["u"].astype(jnp.float32), state["s"]
    )
    # per-head group norm
    o = o - jnp.mean(o, axis=-1, keepdims=True)
    o = o * jax.lax.rsqrt(jnp.var(o, axis=-1) + 1e-6)[..., None]
    o = (o * p["ln_x"].astype(jnp.float32)[None, None]).astype(x.dtype)
    o = qdense((o.reshape(B, S, d) * g), p["wo"], cfg.quant)
    x = x + o
    new_tm = h[:, -1, :]
    # ---- channel mix ----
    h2 = rms_norm(x, p["ln2"])
    prev2 = _shift(h2, state["cm_x"])
    dx2 = prev2 - h2
    xk2 = h2 + dx2 * p["cm_mu_k"].astype(h2.dtype)
    xr2 = h2 + dx2 * p["cm_mu_r"].astype(h2.dtype)
    kk = jnp.square(jax.nn.relu(qdense(xk2, p["cm_wk"], cfg.quant)))
    out = jax.nn.sigmoid(qdense(xr2, p["cm_wr"], cfg.quant)) * qdense(
        kk, p["cm_wv"], cfg.quant
    )
    x = x + out
    new_state = dict(tm_x=new_tm, cm_x=h2[:, -1, :], s=s_new)
    return x, new_state
