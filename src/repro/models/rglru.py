"""RecurrentGemma recurrent block (arXiv:2402.19427): RG-LRU + causal
depthwise conv, used in a 1:2 (attention : recurrent) pattern with local
sliding-window MQA attention.

Paper-technique applicability: the in/out/gate projections run through
:func:`qdense`; the RG-LRU recurrence is element-wise fp dynamics (no GEMM)
and stays fp (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, norm_init, qdense, rms_norm

RGLRU_C = 8.0  # paper's recurrence sharpness constant


def init_rec_block(key, cfg, plan):
    d = cfg.d_model
    W = cfg.lru_width or d
    cw = cfg.conv_width
    ks = jax.random.split(key, 7)
    p, a = {}, {}
    p["ln"], a["ln"] = norm_init(d, cfg.param_dtype)
    p["wx"], a["wx"] = dense_init(ks[0], d, W, ("embed", "mlp"), cfg.param_dtype)
    p["wy"], a["wy"] = dense_init(ks[1], d, W, ("embed", "mlp"), cfg.param_dtype)
    p["conv_w"] = jax.random.normal(ks[2], (cw, W), cfg.param_dtype) / math.sqrt(cw)
    a["conv_w"] = (None, "mlp")
    p["conv_b"] = jnp.zeros((W,), cfg.param_dtype); a["conv_b"] = ("mlp",)
    p["wr"], a["wr"] = dense_init(ks[3], W, W, (None, "mlp"), cfg.param_dtype)
    p["wi"], a["wi"] = dense_init(ks[4], W, W, (None, "mlp"), cfg.param_dtype)
    # Λ init so a^c ∈ (0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[5], (W,), jnp.float32, 0.9, 0.999)
    p["lam"] = jnp.log(jnp.exp(-jnp.log(u) / RGLRU_C) - 1.0).astype(cfg.param_dtype)
    a["lam"] = ("mlp",)
    p["wo"], a["wo"] = dense_init(ks[6], W, d, ("mlp", "embed"), cfg.param_dtype)
    return p, a


def _causal_conv1d(x, w, b, carry):
    """Depthwise causal conv. x (B,S,W), w (cw,W), carry (B,cw-1,W)."""
    cw = w.shape[0]
    xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(cw))
    new_carry = xp[:, xp.shape[1] - (cw - 1) :, :]
    return out + b.astype(x.dtype), new_carry


def _rglru_scan(xg, a, h0):
    """h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * xg_t.  All (B,S,W) fp32."""
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 0.0))

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(mult * xg, 1, 0))
    h, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), h


def _rglru_assoc(xg, a, h0):
    """Parallel form via associative_scan (beyond-paper TPU optimization):
    the linear recurrence h_t = a_t h_{t-1} + b_t composes associatively as
    (a, b) * (a', b') = (a a', a' b + b')."""
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 0.0))
    b = mult * xg
    # fold h0 into the first element
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    a_c, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_seq, h_seq[:, -1]


def rec_block_fwd(p, x, cfg, plan, *, mode: str, state=None, use_assoc=False):
    """x (B,S,d); state: dict(h (B,W) f32, conv (B,cw-1,W)) or None.

    Returns (out, new_state).
    """
    B, S, d = x.shape
    W = cfg.lru_width or d
    cw = cfg.conv_width
    if state is None:
        state = dict(
            h=jnp.zeros((B, W), jnp.float32),
            conv=jnp.zeros((B, cw - 1, W), jnp.float32),
        )
    h_in = rms_norm(x, p["ln"])
    xb = qdense(h_in, p["wx"], cfg.quant)
    yb = jax.nn.gelu(qdense(h_in, p["wy"], cfg.quant))
    xc, conv_new = _causal_conv1d(xb, p["conv_w"], p["conv_b"], state["conv"])
    r = jax.nn.sigmoid(xc @ p["wr"].astype(xc.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(xc @ p["wi"].astype(xc.dtype)).astype(jnp.float32)
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * xc.astype(jnp.float32)
    scan_fn = _rglru_assoc if (use_assoc or cfg.rglru_assoc) else _rglru_scan
    h_seq, h_last = scan_fn(gated, a, state["h"])
    y = (h_seq.astype(x.dtype) * yb)
    out = qdense(y, p["wo"], cfg.quant)
    return out, dict(h=h_last, conv=conv_new.astype(jnp.float32))
