"""Shared neural-net layers (pure-JAX pytrees, no flax).

Conventions
-----------
* Every ``init_*`` returns ``(params, axes)`` — two parallel pytrees; the
  axes tree holds tuples of *logical* axis names consumed by
  ``repro.distributed.sharding`` (e.g. ``("embed", "heads")``).
* All matmul-bearing layers route through :func:`qdense`, which applies
  the paper's AND-Accumulation quantized GEMM per the arch's
  ``QuantConfig`` (fake-quant STE in training, integer engine in
  serving), or a plain matmul for fp configs.
* Shapes: activations ``(B, S, d)``; attention heads ``(B, S, H, hd)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.and_accum import quant_dense_forward_signed
from repro.core.quant import QuantConfig, fake_quant_act_signed, quantize_weight

# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, axes: tuple, dtype=jnp.float32,
               scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * scale
    return w, axes


def norm_init(dim: int, dtype=jnp.float32):
    return jnp.ones((dim,), dtype), ("embed",)


# ---------------------------------------------------------------------------
# Quantized dense — the paper's technique as a layer primitive
# ---------------------------------------------------------------------------

def qdense(x: jax.Array, w, quant: QuantConfig, *,
           role: str = "mid", mode: str = "train") -> jax.Array:
    """Dense layer running the AND-Accumulation engine when quantized.

    role: 'first'|'mid'|'last' — paper keeps first/last layers fp.
    mode: 'train' -> fake-quant STE float GEMM (differentiable);
          'serve' -> integer engine (exact int32 accumulation).
    w may be a prequantized dict {"q": int8 levels, "s": scale, "z": zp}
    (see :func:`prequantize_params`) — serve-only, 4x less weight traffic.
    """
    if isinstance(w, dict):
        from repro.core.and_accum import quant_dense_forward_signed_pre
        a_scale = _STATIC_ACT_SCALE[0]
        if a_scale is None and quant.act_scale_mode == "row":
            a_scale = "row"
        return quant_dense_forward_signed_pre(
            x, w["q"], w["s"], w["z"], quant.a_bits, quant.w_bits,
            engine=_signed_engine(x, w["q"].shape[-1], quant),
            a_scale=a_scale)
    if quant.engine == "fp" or quant.w_bits >= 32 or (
        role in ("first", "last") and quant.first_last_fp
    ):
        return x @ w.astype(x.dtype)
    if mode == "serve":
        lead = x.shape[:-1]
        x2 = x.reshape((-1, x.shape[-1]))
        out = quant_dense_forward_signed(
            x2, w, quant.a_bits, quant.w_bits,
            engine=_signed_engine(x, w.shape[-1], quant),
            a_scale_mode=quant.act_scale_mode,
        )
        return out.reshape(lead + (w.shape[-1],))
    aq = fake_quant_act_signed(x, quant.a_bits)
    wq = quantize_weight(w, quant.w_bits).astype(x.dtype)
    return aq @ wq


def _signed_engine(x, n_out: int, quant: QuantConfig) -> str:
    """Level-GEMM engine for the signed (affine-corrected) serve path.

    Honors an explicit bitwise engine from the config; otherwise asks the
    backend/shape dispatcher and maps its fused pick down to ``int8`` (the
    fused Pallas epilogue implements the unsigned DoReFa correction only).
    """
    if quant.engine in ("planes", "packed", "int8", "f32dot"):
        return quant.engine
    from repro.kernels.ops import select_engine

    m = 1
    for d in x.shape[:-1]:
        m *= d
    eng = select_engine(m, x.shape[-1], n_out, quant.a_bits, quant.w_bits)
    # fused/faithful are unsigned-serve Pallas paths; the signed correction
    # runs on the plain level-GEMM engines
    return eng if eng in ("planes", "packed", "int8", "f32dot") else "int8"


PREQUANT_KEYS = {"wq", "wk", "wv", "wo", "w_in", "w_gate", "w_out"}
# module-level static-activation-scale knob (set by launch/ for serve cells;
# 0/None = dynamic absmax).  A list so closures observe mutation.
_STATIC_ACT_SCALE: list = [None]


def set_static_act_scale(v):
    _STATIC_ACT_SCALE[0] = v if v else None


def _quantize_leaf_stacked(w, bits: int):
    """(L, K, N) fp -> per-layer int8 levels + scales (vmapped)."""
    from repro.core.quant import weight_levels

    def one(wl):
        lv, s, z = weight_levels(wl, bits)
        return lv.astype(jnp.int8), s, z

    q, s, z = jax.vmap(one)(w)
    return {"q": q, "s": s, "z": z}


def prequantize_params(params, cfg):
    """Serve-time transform: store projection weights as int8 levels
    (the checkpoint-resident analogue of the paper's in-array bit planes)."""
    out = dict(params)
    blocks = {}
    for kind, tree in params["blocks"].items():
        new = {}
        for sub, sv in tree.items():
            if isinstance(sv, dict):
                new[sub] = {k: (_quantize_leaf_stacked(v, cfg.quant.w_bits)
                                if k in PREQUANT_KEYS else v)
                            for k, v in sv.items()}
            else:
                new[sub] = sv
        blocks[kind] = new
    out["blocks"] = blocks
    return out


def prequantize_axes(axes, cfg):
    """Axes tree mirroring :func:`prequantize_params`."""
    out = dict(axes)
    blocks = {}
    for kind, tree in axes["blocks"].items():
        new = {}
        for sub, sv in tree.items():
            if isinstance(sv, dict):
                new[sub] = {k: ({"q": v, "s": ("layers",), "z": ("layers",)}
                                if k in PREQUANT_KEYS else v)
                            for k, v in sv.items()}
            else:
                new[sub] = sv
        blocks[kind] = new
    out["blocks"] = blocks
    return out


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd), positions (..., S) or (S,) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (full + chunked online-softmax paths)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask(iq, jk, causal: bool, window: Optional[int]):
    """iq (Sq,), jk (Skv,) absolute positions; jk<0 marks invalid slots."""
    m = jk[None, :] >= 0
    if causal:
        m &= jk[None, :] <= iq[:, None]
    if window is not None:
        m &= jk[None, :] > (iq[:, None] - window)
    return m  # (Sq, Skv)


def expand_kv(k, v, n_q_real: int, n_q_padded: int):
    """GQA: map KV heads onto (possibly TP-padded) query heads.

    Query head j attends kv head j // (H/Hkv); padded q heads (j >= H,
    zero-masked downstream) reuse kv head Hkv-1.  Explicit materialization
    keeps the head-axis sharding uniform under GSPMD (a grouped reshape of
    a TP-sharded head axis would force all-gathers).
    """
    hkv = k.shape[2]
    if hkv == n_q_padded:
        return k, v
    g = max(n_q_real // hkv, 1)
    idx = jnp.minimum(jnp.arange(n_q_padded) // g, hkv - 1)
    return jnp.take(k, idx, axis=2), jnp.take(v, idx, axis=2)


def attn_full(q, k, v, *, causal: bool, window: Optional[int],
              q_pos, kv_pos, logits_dtype=jnp.float32) -> jax.Array:
    """q (B,Sq,H,hd); k,v (B,Skv,H,hd) (KV pre-repeated for GQA)."""
    B, Sq, H, hd = q.shape
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=logits_dtype)
    logits = logits / math.sqrt(hd)
    m = _mask(q_pos, kv_pos, causal, window)  # (Sq, Skv)
    logits = jnp.where(m[None, None], logits,
                       jnp.asarray(NEG_INF, logits.dtype))
    # softmax in the logits dtype: with bf16_logits the whole S^2 chain
    # (max/sub/exp/sum/div) stays bf16 — halves every attention temp
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", p, v)
    return out


def _chunk_plan(n: int, target: int) -> tuple[int, int]:
    """(chunk, padded_n) for the online-softmax scans.

    Pads n up to a multiple of the target chunk instead of shrinking the
    chunk to a divisor — the divisor rule degenerated on prime/awkward
    lengths (S=1021 -> chunk=1, a 1021-step scan).  Padded slots carry
    position -1, which the existing invalid-slot masking (``_mask``'s
    ``jk >= 0``) zeroes out.
    """
    c = min(target, n)
    return c, -(-n // c) * c


def _pad_chunk_dim(x, padded: int, axis: int = 1):
    pad = padded - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_positions(pos, padded: int):
    pad = padded - pos.shape[0]
    if pad == 0:
        return pos
    return jnp.concatenate([pos, jnp.full((pad,), -1, pos.dtype)])


def attn_banded(q, k, v, *, window: int, q_pos, kv_pos,
                logits_dtype=jnp.float32) -> jax.Array:
    """Local (sliding-window) attention computing ONLY the window band.

    Python loop over q blocks of size `window`; block i attends kv
    [max(0,(i-1)W) : (i+1)W) — static slices, so the compiled HLO holds
    exactly the banded work: 2*S*W logits instead of S^2 (16x less for
    recurrentgemma's W=2048 @ S=32k).  Loop is unrolled (analysis-exact).
    """
    B, Sq, H, hd = q.shape
    W = window
    nb = -(-Sq // W)
    outs = []
    for i in range(nb):
        q0, q1 = i * W, min((i + 1) * W, Sq)
        k0 = max(0, (i - 1) * W)
        k1 = q1
        qi = jax.lax.slice_in_dim(q, q0, q1, axis=1)
        ki = jax.lax.slice_in_dim(k, k0, k1, axis=1)
        vi = jax.lax.slice_in_dim(v, k0, k1, axis=1)
        outs.append(attn_full(
            qi, ki, vi, causal=True, window=W,
            q_pos=q_pos[q0:q1], kv_pos=kv_pos[k0:k1],
            logits_dtype=logits_dtype))
    return jnp.concatenate(outs, axis=1)


def attn_chunked(q, k, v, *, causal: bool, window: Optional[int],
                 q_pos, kv_pos, q_chunk: int = 1024, kv_chunk: int = 1024,
                 skip_masked: bool = True):
    """Online-softmax attention, O(chunk^2) memory (prefill_32k path).

    Sequential scan over q chunks with an inner scan over kv chunks —
    the pure-JAX flash-attention dataflow.  Fully masked kv chunks
    (the causal upper triangle, out-of-window bands, all-padding chunks)
    are skipped by a position-bound ``cond`` in the scan body: a skipped
    chunk leaves the (m, l, acc) carry untouched, which is *bit-identical*
    to computing it (its mask zeroes every softmax weight, so m_new = m,
    corr = 1, and both l and acc accumulate exact zeros).  ~2x on causal
    prefill; ``skip_masked=False`` keeps the compute-and-zero dataflow
    (the bench's baseline row).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    q_chunk, Sq_p = _chunk_plan(Sq, q_chunk)
    kv_chunk, Skv_p = _chunk_plan(Skv, kv_chunk)
    q = _pad_chunk_dim(q, Sq_p)
    k = _pad_chunk_dim(k, Skv_p)
    v = _pad_chunk_dim(v, Skv_p)
    q_pos = _pad_positions(q_pos, Sq_p)
    kv_pos = _pad_positions(kv_pos, Skv_p)
    Nq, Nk = Sq_p // q_chunk, Skv_p // kv_chunk
    qs = q.reshape(B, Nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    ks = k.reshape(B, Nk, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, Nk, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(Nq, q_chunk)
    kp = kv_pos.reshape(Nk, kv_chunk)
    scale = 1.0 / math.sqrt(hd)

    def q_body(_, qc):
        qi, qpos = qc  # (B,H,Cq,hd), (Cq,)
        # chunk-level position bounds: a kv chunk intersects this q
        # chunk's mask iff some slot is valid (>= 0), at or before the
        # latest query (causal), and inside the earliest query's window
        qmax = jnp.max(qpos)
        qmin = jnp.min(qpos)

        def compute(carry, kc):
            m_run, l_run, acc = carry
            kj, vj, kpos = kc
            s = jnp.einsum("bhqd,bhsd->bhqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpos, kpos, causal, window)[None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * msk
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqs,bhsd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_run, acc)

        def kv_body(carry, kc):
            if not skip_masked:
                return compute(carry, kc), None
            kpos = kc[2]
            alive = kpos >= 0
            if causal:
                alive &= kpos <= qmax
            if window is not None:
                alive &= kpos > qmin - window
            return jax.lax.cond(jnp.any(alive),
                                lambda c: compute(c, kc),
                                lambda c: c, carry), None

        init = (
            jnp.full((B, H, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, H, q_chunk), jnp.float32),
            jnp.zeros((B, H, q_chunk, hd), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(kv_body, init, (ks, vs, kp))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, qp))  # (Nq,B,H,Cq,hd)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq_p, H, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA attention block (params + forward; zero-masked Q-head padding)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, plan) -> tuple[dict, dict]:
    d, hd = cfg.d_model, cfg.hd
    Hp = plan.padded_heads(cfg.n_heads)
    Hkv = cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["ln"], a["ln"] = norm_init(d, cfg.param_dtype)
    p["wq"], a["wq"] = dense_init(ks[0], d, Hp * hd, ("embed", "heads"), cfg.param_dtype)
    p["wk"], a["wk"] = dense_init(ks[1], d, Hkv * hd, ("embed", "kv_heads"), cfg.param_dtype)
    p["wv"], a["wv"] = dense_init(ks[2], d, Hkv * hd, ("embed", "kv_heads"), cfg.param_dtype)
    p["wo"], a["wo"] = dense_init(ks[3], Hp * hd, d, ("heads", "embed"), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = jnp.ones((hd,), cfg.param_dtype), (None,)
        p["k_norm"], a["k_norm"] = jnp.ones((hd,), cfg.param_dtype), (None,)
    return p, a


def _head_mask(cfg, plan, dtype):
    Hp = plan.padded_heads(cfg.n_heads)
    if Hp == cfg.n_heads:
        return None
    return (jnp.arange(Hp) < cfg.n_heads).astype(dtype)


@dataclasses.dataclass
class AttnCache:
    """Linear (full-seq) or rolling (windowed) KV cache for one layer kind."""

    k: jax.Array        # (L, B, S_slots, Hkv, hd)
    v: jax.Array
    pos: jax.Array      # (L, B, S_slots) absolute positions, -1 = empty


def attn_quantized(quant: QuantConfig, qmode: str) -> bool:
    """Is this the integer-levels serve path (quantized-flash eligible)?

    The flash engine consumes level-quantized q/k, so it may only be
    dispatched where the projections already serve on integer levels —
    never in training or on fp configs (their numerics must not change).
    """
    return (qmode == "serve" and quant.engine != "fp"
            and quant.w_bits < 32 and quant.a_bits <= 8)


def resolve_attn_engine(cfg, *, seq_q: int, seq_kv: int, heads: int,
                        causal: bool, window: Optional[int],
                        qmode: str = "train") -> str:
    """Resolve the attention engine for one static geometry.

    Asks the layered dispatcher (installed plan table, then the backend
    target's decision procedure).  ``cfg.full_attn_analysis`` pins the
    materialized-logits path (the analysis contract) without disturbing
    the banded window realization, exactly as the old hardcoded
    ``CHUNK_ATTN_THRESHOLD`` switch did.
    """
    from repro.kernels.ops import AttnShape, select_attn_engine

    attn = AttnShape(
        seq_q=seq_q, seq_kv=seq_kv, heads=heads, head_dim=cfg.hd,
        causal=bool(causal), window=window,
        quantized=attn_quantized(cfg.quant, qmode),
        banded_ok=bool(getattr(cfg, "banded_attn", False)))
    eng = select_attn_engine(attn)
    if getattr(cfg, "full_attn_analysis", False) and eng in ("chunked",
                                                             "flash"):
        return "full"
    return eng


def attention_fwd(p, x, cfg, plan, *, mode: str, pos_offset=0,
                  cache_k=None, cache_v=None, cache_pos=None,
                  cache_table=None, valid_len=None,
                  window: Optional[int] = None, causal: Optional[bool] = None,
                  engine: Optional[str] = None, qmode: str = "train"):
    """Returns (out, (new_k, new_v, new_pos)) — cache parts None in train mode.

    ``engine`` pins one of ``kernels.ops.ATTN_ENGINES``
    (full/chunked/banded/flash); ``None`` resolves it through
    :func:`resolve_attn_engine`.  Decode steps always run ``full`` (one
    query row — nothing to tile).

    ``mode == 'paged'`` is the continuous-batching path: ``cache_k`` /
    ``cache_v`` / ``cache_pos`` are reinterpreted as the shared page pools
    (``pool_k/pool_v`` ``(NP+1, ps, Hkv, hd)``, ``ppos`` ``(NP+1, ps)``),
    ``cache_table`` is the per-slot page table ``(B, P)``, ``pos_offset``
    and ``valid_len`` are per-slot ``(B,)`` int arrays.  The same program
    serves chunked prefill insert (S = chunk) and decode (S = 1).
    """
    B, S, d = x.shape
    hd = cfg.hd
    Hp = plan.padded_heads(cfg.n_heads)
    Hkv = cfg.n_kv_heads
    causal = cfg.causal if causal is None else causal
    h = rms_norm(x, p["ln"])
    q = qdense(h, p["wq"], cfg.quant, mode=qmode).reshape(B, S, Hp, hd)
    k = qdense(h, p["wk"], cfg.quant, mode=qmode).reshape(B, S, Hkv, hd)
    v = qdense(h, p["wv"], cfg.quant, mode=qmode).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if mode == "paged":
        out, new_cache = _paged_attn_fwd(
            q, k, v, cfg, pos_offset, valid_len,
            cache_k, cache_v, cache_pos, cache_table,
            causal=causal, window=window, qmode=qmode)
        hm = _head_mask(cfg, plan, out.dtype)
        if hm is not None:
            out = out * hm[None, None, :, None]
        out = qdense(out.reshape(B, S, Hp * hd), p["wo"], cfg.quant,
                     mode=qmode)
        return out, new_cache
    q_pos = pos_offset + jnp.arange(S)
    k_roped = rope(k, q_pos, cfg.rope_theta)
    q = rope(q, q_pos, cfg.rope_theta)

    new_cache = (None, None, None)
    if mode == "train":
        kv, vv, kv_pos = k_roped, v, q_pos
    elif mode == "prefill":
        kv, vv, kv_pos = k_roped, v, q_pos
        new_cache = (k_roped, v, jnp.broadcast_to(q_pos[None], (B, S)).astype(jnp.int32))
    else:  # decode: S == 1, write into cache slots
        slots = cache_k.shape[1]
        write_at = (pos_offset % slots) if window is not None else pos_offset
        kv = jax.lax.dynamic_update_slice(cache_k, k_roped, (0, write_at, 0, 0))
        vv = jax.lax.dynamic_update_slice(cache_v, v, (0, write_at, 0, 0))
        posu = jax.lax.dynamic_update_slice(
            cache_pos, jnp.broadcast_to(jnp.asarray(pos_offset, jnp.int32), (B, 1)),
            (0, write_at))
        new_cache = (kv, vv, posu)
        kv_pos = posu[0]  # positions identical across batch

    kv, vv = expand_kv(kv, vv, cfg.n_heads, Hp)
    ldt = jnp.bfloat16 if getattr(cfg, "bf16_logits", False) else jnp.float32
    if mode == "decode":
        engine = "full"
    elif engine is None:
        engine = resolve_attn_engine(
            cfg, seq_q=S, seq_kv=kv.shape[1], heads=Hp, causal=causal,
            window=window, qmode=qmode)
    if engine == "banded" and window is not None and S > 2 * window:
        out = attn_banded(q, kv, vv, window=window, q_pos=q_pos,
                          kv_pos=kv_pos, logits_dtype=ldt)
    elif engine == "flash" and S == kv.shape[1]:
        # flash tiles contiguous prefill positions (masks consume only
        # position differences, so the rope offset cancels); ragged
        # cache geometries stay on the position-indexed paths above
        from repro.kernels.attn_flash import attn_flash

        bits = min(cfg.quant.a_bits, 8)
        out = attn_flash(q, kv, vv, causal=bool(causal), window=window,
                         q_bits=bits, k_bits=bits).astype(q.dtype)
    elif engine in ("chunked", "banded", "flash"):
        out = attn_chunked(q, kv, vv, causal=causal, window=window,
                           q_pos=q_pos, kv_pos=kv_pos)
    else:
        out = attn_full(q, kv, vv, causal=causal, window=window,
                        q_pos=q_pos, kv_pos=kv_pos, logits_dtype=ldt)
    hm = _head_mask(cfg, plan, out.dtype)
    if hm is not None:
        out = out * hm[None, None, :, None]
    out = qdense(out.reshape(B, S, Hp * hd), p["wo"], cfg.quant, mode=qmode)
    return out, new_cache


def _paged_attn_fwd(q, k, v, cfg, pos_offset, valid_len,
                    pool_k, pool_v, ppos, table, *,
                    causal: bool, window: Optional[int], qmode: str):
    """One paged step: scatter this chunk's K/V into the page pools, then
    gather-attend each slot over its own page-table row.

    Scatter targeting: a row's page is ``table[b, q_pos // ps]`` and its
    in-page offset ``q_pos % ps``; rows beyond ``valid_len`` (and any
    position past the table width) are redirected to index ``NP+1`` —
    out of bounds for the ``(NP+1, ...)`` pools — so ``mode='drop'``
    discards the write entirely.  The reserved null page (index NP) is
    therefore never written and its ``ppos`` stays -1 forever, which is
    what keeps table padding masked in the gather.
    """
    from repro.kernels.attn_flash import attn_paged
    from repro.kernels.ops import AttnShape, select_attn_engine

    B, S, Hkv, hd = k.shape
    NP1, ps = ppos.shape
    P = table.shape[1]
    pos_offset = jnp.asarray(pos_offset, jnp.int32)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    q_pos = pos_offset[:, None] + jnp.arange(S, dtype=jnp.int32)[None]  # (B,S)
    ok = (jnp.arange(S, dtype=jnp.int32)[None] < valid_len[:, None]) \
        & (q_pos >= 0) & (q_pos < P * ps)
    k_roped = rope(k, q_pos, cfg.rope_theta)
    q = rope(q, q_pos, cfg.rope_theta)
    page_idx = jnp.take_along_axis(
        table, jnp.clip(q_pos // ps, 0, P - 1), axis=1)
    page_idx = jnp.where(ok, page_idx, NP1)  # OOB sentinel -> dropped write
    off = jnp.where(ok, q_pos % ps, 0)
    new_pk = pool_k.at[page_idx, off].set(k_roped, mode="drop")
    new_pv = pool_v.at[page_idx, off].set(v, mode="drop")
    new_ppos = ppos.at[page_idx, off].set(q_pos, mode="drop")

    attn = AttnShape(
        seq_q=S, seq_kv=P * ps, heads=q.shape[2], head_dim=hd,
        causal=bool(causal), window=window,
        quantized=attn_quantized(cfg.quant, qmode), page_size=ps)
    eng = select_attn_engine(attn)
    if eng != "paged":
        raise ValueError(
            f"paged attention geometry resolved to engine {eng!r}")
    out = attn_paged(
        q, new_pk, new_pv, new_ppos, table, jnp.where(ok, q_pos, -1),
        causal=bool(causal), window=window, quantized=attn.quantized,
        bits=min(cfg.quant.a_bits, 8), n_q_heads=cfg.n_heads)
    return out.astype(q.dtype), (new_pk, new_pv, new_ppos)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU) with quantized GEMMs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, plan, d_ff: Optional[int] = None) -> tuple[dict, dict]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["ln"], a["ln"] = norm_init(d, cfg.param_dtype)
    p["w_in"], a["w_in"] = dense_init(ks[0], d, ff, ("embed", "mlp"), cfg.param_dtype)
    if cfg.act == "swiglu":
        p["w_gate"], a["w_gate"] = dense_init(ks[1], d, ff, ("embed", "mlp"), cfg.param_dtype)
    p["w_out"], a["w_out"] = dense_init(ks[2], ff, d, ("mlp", "embed"), cfg.param_dtype)
    return p, a


def mlp_fwd(p, x, cfg, *, norm=True, qmode: str = "train"):
    h = rms_norm(x, p["ln"]) if norm else x
    up = qdense(h, p["w_in"], cfg.quant, mode=qmode)
    if cfg.act == "swiglu":
        up = jax.nn.silu(qdense(h, p["w_gate"], cfg.quant, mode=qmode)) * up
    else:
        up = jax.nn.gelu(up)
    return qdense(up, p["w_out"], cfg.quant, mode=qmode)


# ---------------------------------------------------------------------------
# Mixture-of-Experts (token-choice top-k, capacity-based gather dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg, plan) -> tuple[dict, dict]:
    d, E, eff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["ln"], a["ln"] = norm_init(d, cfg.param_dtype)
    p["router"], a["router"] = dense_init(ks[0], d, E, ("embed", None), cfg.param_dtype)
    s = 1.0 / math.sqrt(d)
    p["w1"] = jax.random.normal(ks[1], (E, d, eff), cfg.param_dtype) * s
    a["w1"] = ("expert", "embed", "mlp")
    p["wg"] = jax.random.normal(ks[2], (E, d, eff), cfg.param_dtype) * s
    a["wg"] = ("expert", "embed", "mlp")
    p["w2"] = jax.random.normal(ks[3], (E, eff, d), cfg.param_dtype) * (1.0 / math.sqrt(eff))
    a["w2"] = ("expert", "mlp", "embed")
    if cfg.n_shared_experts:
        sh, ash = init_mlp(ks[4], cfg, plan, d_ff=cfg.expert_d_ff * cfg.n_shared_experts)
        p["shared"], a["shared"] = sh, ash
    return p, a


def moe_fwd(p, x, cfg):
    """x (B,S,d) -> (out, aux_loss). Capacity-dropped token-choice routing.

    Dispatch is gather/scatter-based (not one-hot matmul), so compiled
    FLOPs reflect *active* expert compute: E*C*d*ff with
    C = ceil(cf * T * k / E) — the MoE roofline stays honest.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    h = rms_norm(xt, p["ln"])
    logits = (h @ p["router"].astype(h.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # (T,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * E * cfg.router_aux_coef

    # capacity: floor of 4 so tiny decode batches never drop; cap at T
    # (an expert can receive each token at most once).
    C = min(T, max(int(math.ceil(cfg.capacity_factor * T * k / E)), 4))
    e_flat = idx.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.sum(pos * onehot, axis=-1)                       # (T*k,) slot in expert
    keep = pos < C
    tok = jnp.repeat(jnp.arange(T), k)
    # dispatch: (E, C, d) buffer, dropped tokens discarded by mode="drop"
    buf = jnp.zeros((E, C, d), h.dtype).at[
        jnp.where(keep, e_flat, E), jnp.where(keep, pos, 0)
    ].add(h[tok], mode="drop")
    up = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(h.dtype))
    gate = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(h.dtype))
    act = jax.nn.silu(gate) * up
    y_e = jnp.einsum("ecf,efd->ecd", act, p["w2"].astype(h.dtype))
    # combine: gather each (token, k) slot's expert output, weight by gate
    y_slots = y_e[jnp.where(keep, e_flat, 0), jnp.where(keep, pos, 0)]
    y_slots = jnp.where(keep[:, None], y_slots, 0.0)
    w_gates = gates.reshape(-1).astype(h.dtype)
    y = jax.ops.segment_sum(y_slots * w_gates[:, None], tok, num_segments=T)
    if cfg.n_shared_experts:
        y = y + mlp_fwd(p["shared"], h, cfg, norm=False)
    return y.reshape(B, S, d), aux
