"""The paper's CNN models (§III-A):

* ``svhn_cnn`` — 6 conv + 2 average-pool + 2 FC layers (FC realized as
  1x1 convolutions, as the paper states), for 40x40 SVHN digits.
  First and last layers stay full precision (paper follows DoReFa/XNOR).
* ``alexnet`` — binary-weight AlexNet used for the ImageNet storage /
  energy rows (Fig. 8b, Table II).

Serve mode executes a compiled execution plan (``repro.core.plan``): the
per-layer engine choices, weight pre-quantization, and feasibility checks
all happen ONCE at plan-compile time, and ``cnn_forward(mode="serve")``
just walks the LayerPlan sequence — no per-call dispatch, no
float-vs-prequant branching in the forward.  Training mode keeps the
fake-quant STE conv.  The ``prepare_serve_params`` deprecation shim was
removed (PR 5): pre-quantize through :func:`repro.core.plan.compile_model`
/ ``repro.api.build(...).compile()`` (or, for tests that only need the
raw levels, :func:`repro.core.prequant.prequantize_cnn_params`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.conv_lowering import conv2d_float
from repro.core.prequant import is_fp_layer
from repro.core.quant import (
    QuantConfig,
    quantize_activation,
    quantize_gradient,
    quantize_weight,
)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    cin: int
    cout: int
    k: int = 3
    stride: int = 1
    pool: bool = False   # 2x2 average pool after this layer
    role: str = "mid"    # first | mid | last
    fc: bool = False     # fully-connected: VALID conv reducing to 1x1


def svhn_cnn_spec(channels: int = 64) -> list[ConvSpec]:
    """6 conv + 2 pool + 2 FC(=1x1 conv) — the paper's SVHN model."""
    c = channels
    return [
        ConvSpec(3, c, 5, role="first"),
        ConvSpec(c, c, 3),
        ConvSpec(c, 2 * c, 3, pool=True),       # avg-pool #1
        ConvSpec(2 * c, 2 * c, 3),
        ConvSpec(2 * c, 4 * c, 3, pool=True),   # avg-pool #2
        ConvSpec(4 * c, 4 * c, 3),
        ConvSpec(4 * c, 8 * c, 1),              # FC-equivalent 1
        ConvSpec(8 * c, 10, 1, role="last"),    # FC-equivalent 2 (10 classes)
    ]


def alexnet_spec() -> list[ConvSpec]:
    """AlexNet conv/FC stack (FCs as convs) for the ImageNet rows."""
    return [
        ConvSpec(3, 96, 11, stride=4, pool=True, role="first"),
        ConvSpec(96, 256, 5, pool=True),
        ConvSpec(256, 384, 3),
        ConvSpec(384, 384, 3),
        ConvSpec(384, 256, 3, pool=True),
        ConvSpec(256, 4096, 6, fc=True),                 # FC6
        ConvSpec(4096, 4096, 1, fc=True),                # FC7
        ConvSpec(4096, 1000, 1, fc=True, role="last"),   # FC8
    ]


def init_cnn(key, spec: Sequence[ConvSpec], dtype=jnp.float32):
    params, axes = [], []
    keys = jax.random.split(key, len(spec))
    for k, s in zip(keys, spec):
        fan_in = s.k * s.k * s.cin
        w = jax.random.normal(k, (s.k, s.k, s.cin, s.cout), dtype) / math.sqrt(fan_in)
        b = jnp.zeros((s.cout,), dtype)
        g = jnp.ones((s.cout,), dtype)  # batch-norm-ish scale (folded form)
        beta = jnp.zeros((s.cout,), dtype)
        params.append(dict(w=w, b=b, g=g, beta=beta))
        axes.append(dict(w=(None, None, None, "mlp"), b=("mlp",), g=("mlp",),
                         beta=("mlp",)))
    return params, axes


def _norm_act(x, g, beta, quant: QuantConfig, role: str, mode: str = "train"):
    """Per-channel norm (BN inference form) + bounded activation.

    The bounded ReLU (clip to [0,1]) is exactly DoReFa's activation domain,
    so quantize_activation is the identity structure the paper assumes.

    Serve mode normalizes with PER-SAMPLE (spatial-only) statistics instead
    of batch statistics: a served request's output must not depend on which
    other requests the engine co-batched it with (request isolation), and
    per-sample stats make the whole serve forward batch-invariant — the
    bit-identity contract `launch/engine.py` batching relies on.  Training
    keeps cross-batch statistics (the usual BN regularizer).
    """
    stat_axes = (1, 2) if mode == "serve" else (0, 1, 2)
    mu = jnp.mean(x, axis=stat_axes, keepdims=True)
    var = jnp.var(x, axis=stat_axes, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + beta
    x = jnp.clip(x, 0.0, 1.0)
    if role == "last" or quant.engine == "fp":
        return x
    return quantize_activation(x, quant.a_bits)


def cnn_forward(params, x, spec: Sequence[ConvSpec], quant: QuantConfig,
                mode: str = "train", g_key=None):
    """x (B,H,W,3) in [0,1]. Returns logits (B, n_classes).

    Serve mode compiles (or reuses — the structural pass is cached) an
    execution plan for this (spec, quant, shape, backend) and executes it:
    engine choices are made once per compiled program, not once per layer
    call.  Bit-identical to the pre-plan per-call dispatch — the plan's
    heuristic resolution IS that dispatch, hoisted to trace time.
    """
    if mode == "serve":
        from repro.core.plan import cnn_serve_layers, execute_cnn_layers

        layers = cnn_serve_layers(spec, quant, batch=x.shape[0],
                                  img_hw=(x.shape[1], x.shape[2]))
        return execute_cnn_layers(layers, params, x, quant)
    h = x
    for i, (p, s) in enumerate(zip(params, spec)):
        pad = "VALID" if (s.fc or s.k == 1) else "SAME"
        if s.fc and s.k > 1 and h.shape[1] != s.k:
            # FC over whatever spatial extent remains: pool/crop to k x k
            h = jax.image.resize(h, (h.shape[0], s.k, s.k, h.shape[3]), "linear")
        fp_layer = is_fp_layer(s, quant)
        if fp_layer:
            h = conv2d_float(h, p["w"], stride=s.stride, padding=pad)
        else:  # fake-quant STE training conv
            wq = quantize_weight(p["w"], quant.w_bits)
            hq = h  # already quantized by the previous _norm_act
            h = conv2d_float(hq, wq, stride=s.stride, padding=pad)
        if g_key is not None and not fp_layer:
            h = quantize_gradient(h, quant.g_bits,
                                  jax.random.fold_in(g_key, i))
        h = h + p["b"]
        if i < len(spec) - 1:
            h = _norm_act(h, p["g"], p["beta"], quant, s.role, mode)
        if s.pool:
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    return jnp.mean(h, axis=(1, 2))  # global average -> (B, classes)


def cnn_loss(params, batch, spec, quant: QuantConfig, g_key=None):
    logits = cnn_forward(params, batch["image"], spec, quant, "train", g_key)
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, dict(loss=loss, acc=acc)


def count_params(spec: Sequence[ConvSpec]) -> int:
    return sum(s.k * s.k * s.cin * s.cout for s in spec)


def count_acts(spec: Sequence[ConvSpec], img: int) -> int:
    """Peak activation element count for the storage model (Fig. 8)."""
    h = img
    total = img * img * 3
    for s in spec:
        h = max(h // s.stride, 1)
        total += h * h * s.cout
        if s.pool:
            h //= 2
    return total


def count_macs(spec: Sequence[ConvSpec], img: int) -> int:
    """MAC count per image (the paper's '80 FLOPs' ~ 80 MFLOPs on 40x40)."""
    h = img
    total = 0
    for s in spec:
        if s.fc:
            oh = 1
        else:
            oh = max(-(-h // s.stride), 1)
        total += oh * oh * s.k * s.k * s.cin * s.cout
        h = oh
        if s.pool:
            h = max(h // 2, 1)
    return total
