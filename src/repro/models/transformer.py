"""Unified LM covering all assigned families.

A model is a block-pattern (``cfg.pattern``) tiled over ``n_layers``:
  dense    -> ('attn',)                  attention + MLP
  moe      -> ('moe',)                   attention + MoE FFN (+ shared)
  rwkv     -> ('rwkv',)                  RWKV-6 time mix + channel mix
  rglru    -> ('rec','rec','attn_local') RecurrentGemma 2:1 pattern
  encoder  -> ('attn',) causal=False     HuBERT backbone
  vlm      -> ('attn',)                  + stub vision-embedding prefix

Layers are scan-stacked in *superblocks* of one pattern period so compile
time is O(one period), with the pattern remainder unrolled — exact layer
counts are preserved (e.g. recurrentgemma's 38 = 12x(rec,rec,attn) +
(rec,rec)).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import rglru, rwkv6
from .layers import (
    attention_fwd,
    dense_init,
    init_attention,
    init_mlp,
    init_moe,
    mlp_fwd,
    moe_fwd,
    norm_init,
    qdense,
    rms_norm,
)

# Attention realization (full / chunked / banded / flash) is no longer a
# hardcoded sequence-length switch here: attention_fwd resolves it per
# static geometry through kernels.ops.select_attn_engine — an installed
# ModelPlan's attention table first, then the backend target's decision
# procedure (api/targets.py cost tables).


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(kind: str, key, cfg, plan):
    k1, k2 = jax.random.split(key)
    if kind in ("attn", "attn_local"):
        pa, aa = init_attention(k1, cfg, plan)
        pm, am = init_mlp(k2, cfg, plan)
        return {"attn": pa, "mlp": pm}, {"attn": aa, "mlp": am}
    if kind == "moe":
        pa, aa = init_attention(k1, cfg, plan)
        pm, am = init_moe(k2, cfg, plan)
        return {"attn": pa, "moe": pm}, {"attn": aa, "moe": am}
    if kind == "rec":
        pr, ar = rglru.init_rec_block(k1, cfg, plan)
        pm, am = init_mlp(k2, cfg, plan)
        return {"rec": pr, "mlp": pm}, {"rec": ar, "mlp": am}
    if kind == "rwkv":
        return rwkv6.init_rwkv_block(k1, cfg, plan)
    raise ValueError(kind)


def _is_axes(x):
    return isinstance(x, tuple) or x is None


def _stack_axes(axes):
    return jax.tree.map(
        lambda ax: ("layers",) + tuple(ax) if isinstance(ax, tuple) else ("layers",),
        axes, is_leaf=_is_axes,
    )


def init_lm(key, cfg, plan):
    """Returns (params, axes) pytrees for the full LM."""
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    d, Vp = cfg.d_model, cfg.padded_vocab
    if cfg.frame_input:
        params["frame_proj"], axes["frame_proj"] = dense_init(
            keys[-1], cfg.frame_dim, d, (None, "embed"), cfg.param_dtype)
    else:
        params["embed"] = jax.random.normal(keys[-1], (Vp, d), cfg.param_dtype) * 0.02
        axes["embed"] = ("vocab_in", "embed")
    if cfg.n_patches:
        params["vision_proj"], axes["vision_proj"] = dense_init(
            keys[-2], cfg.vit_dim, d, (None, "embed"), cfg.param_dtype)
    params["final_norm"], axes["final_norm"] = norm_init(d, cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = dense_init(
            keys[-3], d, Vp, ("embed", "vocab"), cfg.param_dtype, scale=0.02)

    # one stacked param tree per block kind, in occurrence order
    pattern = cfg.blocks_pattern
    per_kind: dict[str, list] = {}
    kind_axes: dict[str, Any] = {}
    for i, kind in enumerate(pattern):
        p, a = _init_block(kind, keys[i], cfg, plan)
        per_kind.setdefault(kind, []).append(p)
        kind_axes[kind] = a
    blocks = {
        kind: jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        for kind, ps in per_kind.items()
    }
    params["blocks"] = blocks
    axes["blocks"] = {k: _stack_axes(a) for k, a in kind_axes.items()}
    return params, axes


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _attn_slots(cfg, kind, max_len):
    if kind == "attn_local" and cfg.window:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg, plan, batch: int, max_len: int, dtype=None):
    """Decode cache pytree: one stacked entry per block kind."""
    dtype = dtype or cfg.compute_dtype
    d, hd, Hkv = cfg.d_model, cfg.hd, cfg.n_kv_heads
    cache: dict[str, Any] = {}
    counts: dict[str, int] = {}
    for kind in cfg.blocks_pattern:
        counts[kind] = counts.get(kind, 0) + 1
    for kind, n in counts.items():
        if kind in ("attn", "moe", "attn_local"):
            slots = _attn_slots(cfg, kind, max_len)
            cache[kind] = dict(
                k=jnp.zeros((n, batch, slots, Hkv, hd), dtype),
                v=jnp.zeros((n, batch, slots, Hkv, hd), dtype),
                pos=jnp.full((n, batch, slots), -1, jnp.int32),
            )
        elif kind == "rec":
            W = cfg.lru_width or d
            cache[kind] = dict(
                h=jnp.zeros((n, batch, W), jnp.float32),
                conv=jnp.zeros((n, batch, cfg.conv_width - 1, W), jnp.float32),
            )
        elif kind == "rwkv":
            H = d // cfg.rwkv_head_dim
            cache[kind] = dict(
                tm_x=jnp.zeros((n, batch, d), dtype),
                cm_x=jnp.zeros((n, batch, d), dtype),
                s=jnp.zeros((n, batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                            jnp.float32),
            )
    return cache


def init_paged_cache(cfg, plan, num_slots: int, num_pages: int,
                     page_size: int, table_pages: int, dtype=None):
    """Paged decode cache (continuous-batching serve path).

    Layout per layer: shared page pools ``pk``/``pv``
    ``(n, NP+1, page_size, Hkv, hd)`` — NP allocatable pages plus the
    reserved null page (index NP, never written) — a position buffer
    ``ppos (n, NP+1, page_size)`` initialized to -1 (= never written), and
    the per-slot page table ``table (n, num_slots, table_pages)``
    initialized to the null page.  The table is logically one host-side
    object (``core.kv_pages``); it is replicated per layer so the cache
    pytree stays uniform under the superblock scan.

    Only the pure-attention pattern is supported: recurrent/rwkv state is
    not page-granular, and rolling-window layers would need a second
    allocator policy.
    """
    bad = [k for k in set(cfg.blocks_pattern) if k != "attn"]
    if bad:
        raise ValueError(
            f"paged KV cache requires a pure-'attn' block pattern; "
            f"got kinds {sorted(bad)}")
    dtype = dtype or cfg.compute_dtype
    n = len(cfg.blocks_pattern)
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    return {"attn": dict(
        pk=jnp.zeros((n, num_pages + 1, page_size, Hkv, hd), dtype),
        pv=jnp.zeros((n, num_pages + 1, page_size, Hkv, hd), dtype),
        ppos=jnp.full((n, num_pages + 1, page_size), -1, jnp.int32),
        table=jnp.full((n, num_slots, table_pages), num_pages, jnp.int32),
    )}


def cache_axes(cfg, plan):
    """Logical axes for the cache pytree (mirrors init_cache)."""
    ax: dict[str, Any] = {}
    counts: dict[str, int] = {}
    for kind in cfg.blocks_pattern:
        counts[kind] = counts.get(kind, 0) + 1
    for kind in counts:
        if kind in ("attn", "moe", "attn_local"):
            ax[kind] = dict(
                k=("layers", "batch", "cache_seq", "kv_heads", None),
                v=("layers", "batch", "cache_seq", "kv_heads", None),
                pos=("layers", "batch", "cache_seq"),
            )
        elif kind == "rec":
            ax[kind] = dict(h=("layers", "batch", "mlp"),
                            conv=("layers", "batch", None, "mlp"))
        elif kind == "rwkv":
            ax[kind] = dict(tm_x=("layers", "batch", "embed"),
                            cm_x=("layers", "batch", "embed"),
                            s=("layers", "batch", "heads", None, None))
    return ax


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _run_block(kind, p, h, cfg, plan, *, mode, pos_offset, cache, qmode,
               valid_len=None):
    """Returns (h, new_cache_for_block)."""
    if kind in ("attn", "moe", "attn_local"):
        window = cfg.window if kind == "attn_local" else None
        if cache and "pk" in cache:  # paged pools, not contiguous k/v/pos
            att, (npk, npv, nppos) = attention_fwd(
                p["attn"], h, cfg, plan, mode="paged",
                pos_offset=pos_offset, cache_k=cache["pk"],
                cache_v=cache["pv"], cache_pos=cache["ppos"],
                cache_table=cache["table"], valid_len=valid_len,
                window=window, qmode=qmode)
            h = h + att
            if kind == "moe":
                y, aux = moe_fwd(p["moe"], h, cfg)
                h = h + y
            else:
                aux = jnp.zeros((), jnp.float32)
                h = h + mlp_fwd(p["mlp"], h, cfg, qmode=qmode)
            return h, dict(pk=npk, pv=npv, ppos=nppos,
                           table=cache["table"]), aux
        ck = cache["k"] if cache else None
        cv = cache["v"] if cache else None
        cp = cache["pos"] if cache else None
        att, (nk, nv, npos) = attention_fwd(
            p["attn"], h, cfg, plan, mode=mode, pos_offset=pos_offset,
            cache_k=ck, cache_v=cv, cache_pos=cp, window=window,
            qmode=qmode)
        h = h + att
        aux = jnp.zeros((), jnp.float32)
        if kind == "moe":
            y, aux = moe_fwd(p["moe"], h, cfg)
            h = h + y
        else:
            h = h + mlp_fwd(p["mlp"], h, cfg, qmode=qmode)
        new_cache = dict(k=nk, v=nv, pos=npos) if nk is not None else None
        return h, new_cache, aux
    if kind == "rec":
        out, st = rglru.rec_block_fwd(
            p["rec"], h, cfg, plan, mode=mode,
            state=cache if cache else None)
        h = h + out
        h = h + mlp_fwd(p["mlp"], h, cfg, qmode=qmode)
        return h, (st if mode != "train" else None), jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        h, st = rwkv6.rwkv_block_fwd(p, h, cfg, plan, mode=mode,
                                     state=cache if cache else None)
        return h, (st if mode != "train" else None), jnp.zeros((), jnp.float32)
    raise ValueError(kind)


def _group_stacked(tree, n_super: int, c: int):
    """(n_total, ...) -> scan xs (n_super, c, ...) + remainder (rem, ...)."""
    head = jax.tree.map(lambda t: t[: n_super * c].reshape((n_super, c) + t.shape[1:]),
                        tree)
    rem = jax.tree.map(lambda t: t[n_super * c :], tree)
    return head, rem


def run_blocks(params, h, cfg, plan, *, mode="train", pos_offset=0, cache=None,
               qmode="train", valid_len=None):
    """Superblock-scanned layer stack. Returns (h, new_cache, aux_sum)."""
    pattern = tuple(cfg.pattern)
    period = len(pattern)
    n_super = cfg.n_layers // period
    rem_pattern = cfg.blocks_pattern[n_super * period :]
    counts = {k: pattern.count(k) for k in set(pattern)}

    if not cfg.scan_layers:
        return _run_blocks_unrolled(params, h, cfg, plan, mode=mode,
                                    pos_offset=pos_offset, cache=cache,
                                    qmode=qmode, valid_len=valid_len)

    blocks = params["blocks"]
    grouped, rem_params = {}, {}
    for kind, c in counts.items():
        grouped[kind], rem_params[kind] = _group_stacked(blocks[kind], n_super, c)
    if cache is not None:
        gcache, rem_cache = {}, {}
        for kind, c in counts.items():
            if kind in cache:
                gcache[kind], rem_cache[kind] = _group_stacked(cache[kind], n_super, c)
    else:
        gcache = {k: {} for k in counts}
        rem_cache = {k: {} for k in counts}

    def superblock(carry, xs):
        h, aux = carry
        pslice, cslice = xs
        idx = {k: 0 for k in counts}
        new_c = {k: [] for k in counts}
        for kind in pattern:
            i = idx[kind]
            idx[kind] += 1
            p_i = jax.tree.map(lambda t: t[i], pslice[kind])
            c_i = (jax.tree.map(lambda t: t[i], cslice[kind])
                   if cache is not None and kind in cache else None)
            h, cu, a = _run_block(kind, p_i, h, cfg, plan, mode=mode,
                                  pos_offset=pos_offset, cache=c_i,
                                  qmode=qmode, valid_len=valid_len)
            h = _constrain_batch(h, cfg, plan)
            if cu is not None:
                new_c[kind].append(cu)
        stacked = {k: (jax.tree.map(lambda *xs: jnp.stack(xs), *v) if v else {})
                   for k, v in new_c.items()}
        return (h, aux + a), stacked

    body = superblock
    if cfg.remat and mode == "train":
        body = jax.checkpoint(superblock, prevent_cse=cfg.remat_prevent_cse)

    (h, aux), new_gcache = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (grouped, gcache))

    # remainder layers (unrolled; exact layer count)
    rem_new = {k: [] for k in counts}
    idx = {k: 0 for k in counts}
    for kind in rem_pattern:
        i = idx[kind]
        idx[kind] += 1
        p_i = jax.tree.map(lambda t: t[i], rem_params[kind])
        c_i = (jax.tree.map(lambda t: t[i], rem_cache[kind])
               if cache is not None and kind in cache else None)
        h, cu, a = _run_block(kind, p_i, h, cfg, plan, mode=mode,
                              pos_offset=pos_offset, cache=c_i,
                              qmode=qmode, valid_len=valid_len)
        aux = aux + a
        if cu is not None:
            rem_new[kind].append(cu)

    if cache is None and mode == "train":
        return h, None, aux

    # reassemble stacked cache: scan output (n_super, c, ...) -> (n_total, ...)
    out_cache = {}
    for kind in counts:
        parts = []
        g = new_gcache.get(kind, {})
        if g and jax.tree_util.tree_leaves(g):
            parts.append(jax.tree.map(
                lambda t: t.reshape((-1,) + t.shape[2:]), g))
        if rem_new[kind]:
            parts.append(jax.tree.map(lambda *xs: jnp.stack(xs), *rem_new[kind]))
        if len(parts) == 2:
            out_cache[kind] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), parts[0], parts[1])
        elif parts:
            out_cache[kind] = parts[0]
    return h, out_cache, aux


def _constrain_batch(h, cfg, plan):
    """Pin the residual stream to batch-sharded (GSPMD-FSDP idiom): without
    this, contracting over the data-sharded ("embed") weight axis makes XLA
    replicate activations across the data axis — catastrophic for the S^2
    attention intermediates (observed: f32[256,2,4096,4096] per device)."""
    if plan is None or not cfg.constrain_acts or not plan.batch_axes:
        return h
    if h.shape[0] % plan.dp != 0:
        return h
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            h, P(tuple(plan.batch_axes), *([None] * (h.ndim - 1))))
    except RuntimeError:
        return h  # no mesh in context


def _run_blocks_unrolled(params, h, cfg, plan, *, mode, pos_offset, cache,
                         qmode, valid_len=None):
    """Python-loop layer stack (analysis mode): every layer's ops appear
    explicitly in the HLO so cost_analysis trip-counts are exact."""
    blocks = params["blocks"]
    idx = {k: 0 for k in blocks}
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {k: [] for k in blocks}
    for kind in cfg.blocks_pattern:
        i = idx[kind]
        idx[kind] += 1
        p_i = jax.tree.map(lambda t: t[i], blocks[kind])
        c_i = (jax.tree.map(lambda t: t[i], cache[kind])
               if cache is not None and kind in cache else None)
        def call(p_b, h_b, _kind=kind, _c=c_i):
            return _run_block(_kind, p_b, h_b, cfg, plan, mode=mode,
                              pos_offset=pos_offset, cache=_c,
                              qmode=qmode, valid_len=valid_len)

        if cfg.remat and mode == "train":
            call = jax.checkpoint(call, prevent_cse=cfg.remat_prevent_cse)
        h, cu, a = call(p_i, h)
        h = _constrain_batch(h, cfg, plan)
        aux = aux + a
        if cu is not None:
            new_cache[kind].append(cu)
    if mode == "train" and cache is None:
        return h, None, aux
    out_cache = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                 for k, v in new_cache.items() if v}
    return h, out_cache, aux


def embed_inputs(params, cfg, tokens=None, patch_embeds=None, frame_feats=None):
    if cfg.frame_input:
        h = frame_feats @ params["frame_proj"].astype(cfg.compute_dtype)
    else:
        h = params["embed"][tokens].astype(cfg.compute_dtype)
    if cfg.n_patches and patch_embeds is not None:
        vis = patch_embeds.astype(cfg.compute_dtype) @ params["vision_proj"].astype(
            cfg.compute_dtype)
        h = jnp.concatenate([vis, h], axis=1)
    return h


def unembed(params, cfg, h, plan=None):
    h = rms_norm(h, params["final_norm"])
    if cfg.tie_embeddings:
        w = params["embed"].T
        logits = h @ w.astype(h.dtype)
    else:
        logits = qdense(h, params["lm_head"], cfg.quant, role="last")
    logits = logits.astype(jnp.float32)
    if plan is not None and plan.tp > 1 and logits.shape[-1] % plan.tp == 0:
        # keep logits vocab-sharded through the loss (MaxText-style)
        from jax.sharding import PartitionSpec as P
        spec = [None] * logits.ndim
        spec[0] = tuple(plan.batch_axes) if plan.batch_axes else None
        spec[-1] = "model"
        try:
            logits = jax.lax.with_sharding_constraint(logits, P(*spec))
        except RuntimeError:
            pass  # no mesh in context (e.g. padding-equivalence unit tests)
    return logits


def forward(params, cfg, plan, *, tokens=None, patch_embeds=None,
            frame_feats=None, mode="train", cache=None, pos_offset=0,
            qmode="train", valid_len=None):
    """Full forward. Returns (logits, new_cache, aux)."""
    h = embed_inputs(params, cfg, tokens, patch_embeds, frame_feats)
    h = h.astype(cfg.compute_dtype)
    h = _constrain_batch(h, cfg, plan)
    h, new_cache, aux = run_blocks(params, h, cfg, plan, mode=mode,
                                   pos_offset=pos_offset, cache=cache,
                                   qmode=qmode, valid_len=valid_len)
    logits = unembed(params, cfg, h, plan)
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# Losses / steps (model-level; distribution wrapping lives in launch/)
# ---------------------------------------------------------------------------

def lm_loss(params, batch, cfg, plan, qmode="train"):
    """Next-token (or frame-classification) CE. batch keys per family."""
    logits, _, aux = forward(
        params, cfg, plan,
        tokens=batch.get("tokens"),
        patch_embeds=batch.get("patch_embeds"),
        frame_feats=batch.get("frame_feats"),
        mode="train", qmode=qmode)
    labels = batch["labels"]
    if cfg.n_patches:  # loss only over text positions
        logits = logits[:, cfg.n_patches :]
    # mask out vocab padding
    Vp = logits.shape[-1]
    if Vp > cfg.vocab:
        pad_mask = jnp.arange(Vp) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    valid = (labels >= 0) & (labels < cfg.vocab)
    labels_c = jnp.clip(labels, 0, cfg.vocab - 1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: the contraction over
    # the vocab-sharded axis lowers to a partial sum + all-reduce instead of
    # an all-gather of the full logits (DESIGN.md §6).
    if cfg.ce_where_mask:
        # hillclimb: bool broadcast-compare (1 B/elem) instead of a f32
        # one-hot (4 B/elem) — 4x less CE intermediate HBM traffic
        sel = jnp.arange(Vp)[None, None, :] == labels_c[..., None]
        ll = jnp.sum(jnp.where(sel, logp, 0.0), axis=-1)
    else:
        onehot = jax.nn.one_hot(labels_c, Vp, dtype=logp.dtype)
        ll = jnp.sum(logp * onehot, axis=-1)
    n = jnp.maximum(jnp.sum(valid), 1)
    loss = -jnp.sum(jnp.where(valid, ll, 0.0)) / n
    acc = jnp.sum(jnp.where(valid, jnp.argmax(logits, -1) == labels_c, False)) / n
    return loss + aux, dict(loss=loss, aux=aux, acc=acc)


def prefill(params, cfg, plan, *, tokens=None, patch_embeds=None,
            frame_feats=None, qmode="train"):
    logits, cache, _ = forward(params, cfg, plan, tokens=tokens,
                               patch_embeds=patch_embeds,
                               frame_feats=frame_feats, mode="prefill",
                               qmode=qmode)
    return logits, cache


def decode_step(params, cache, token, pos, cfg, plan, qmode="train"):
    """One token step. token (B,1) int32; pos scalar int32. -> (logits, cache)."""
    logits, new_cache, _ = forward(params, cfg, plan, tokens=token,
                                   mode="decode", cache=cache,
                                   pos_offset=pos, qmode=qmode)
    return logits, new_cache


def paged_step(params, cache, tokens, pos, valid_len, cfg, plan,
               qmode="serve"):
    """One paged step over the in-flight slot batch.

    tokens (B, S) int32; pos (B,) per-slot start positions; valid_len (B,)
    rows of each slot that are real (0 = slot idle this step).  The
    continuous engine calls this at exactly two shapes — (1, chunk) for a
    prefill-chunk insert (table sliced to the admitting slot) and
    (num_slots, 1) for a decode step — so its whole model jit cache is two
    programs regardless of the request mix.  -> (logits, cache).
    """
    logits, new_cache, _ = forward(params, cfg, plan, tokens=tokens,
                                   mode="paged", cache=cache,
                                   pos_offset=pos, valid_len=valid_len,
                                   qmode=qmode)
    return logits, new_cache
