"""Distributed trainer: jit'd sharded train step + data pipeline +
checkpoint/restore + (optional) gradient compression and mid-step
intermittency snapshots.

This is the production loop behind launch/train.py; IntermittentTrainer
(intermittent.py) is the failure-injection harness over the same step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.distributed import sharding as shd
from repro.models import transformer as T
from . import optimizer as opt_mod
from .checkpoint import Checkpointer
from .compression import compressed_allreduce, init_error_feedback


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    accum_steps: int = 1
    log_every: int = 10
    ckpt_every: int = 50
    compress_grads: bool = False
    compress_bits: int = 8


class Trainer:
    def __init__(self, cfg, plan, mesh, opt_cfg: opt_mod.OptConfig,
                 tcfg: TrainConfig, ckpt_dir: Optional[str] = None,
                 loss_fn=None):
        self.cfg, self.plan, self.mesh = cfg, plan, mesh
        self.opt_cfg, self.tcfg = opt_cfg, tcfg
        self.loss_fn = loss_fn or (lambda p, b: T.lm_loss(p, b, cfg, plan))
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.step = 0
        self._build()

    def _build(self):
        cfg, plan, mesh = self.cfg, self.plan, self.mesh
        params, axes = T.init_lm(jax.random.PRNGKey(0), cfg, plan)
        p_sh = shd.tree_shardings(params, axes, plan, mesh, cfg)
        self.params = jax.device_put(params, p_sh)
        self.opt_state = opt_mod.init_opt_state(self.params, self.opt_cfg)
        self.ef = (init_error_feedback(self.params)
                   if self.tcfg.compress_grads else None)
        tc, oc = self.tcfg, self.opt_cfg

        def train_step(params, opt_state, ef, batch):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            if tc.compress_grads:
                grads, ef = compressed_allreduce(grads, ef,
                                                 bits=tc.compress_bits)
            params, opt_state, stats = opt_mod.apply_updates(
                params, grads, opt_state, oc)
            return params, opt_state, ef, {**metrics, **stats}

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    def restore(self):
        if not self.ckpt:
            return False
        st = dict(params=self.params, opt=self.opt_state)
        step, restored = self.ckpt.restore(st)
        if restored is None:
            return False
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = step
        return True

    def run(self, batch_fn: Callable[[int, int], Any], log=print):
        history = []
        t0 = time.time()
        while self.step < self.tcfg.steps:
            batch = batch_fn(self.step, 0)
            self.params, self.opt_state, self.ef, m = self._step_fn(
                self.params, self.opt_state, self.ef, batch)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == 1:
                m = {k: float(v) for k, v in m.items()}
                m["step"] = self.step
                m["sps"] = self.step / (time.time() - t0)
                history.append(m)
                log(f"step {self.step}: loss={m['loss']:.4f} "
                    f"acc={m.get('acc', 0):.3f} gnorm={m['grad_norm']:.2f}")
            if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step,
                               dict(params=self.params, opt=self.opt_state))
        if self.ckpt:
            self.ckpt.wait()
        return history
