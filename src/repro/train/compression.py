"""Gradient compression for the DP all-reduce (int8 + error feedback).

The paper's whole premise is that low-bitwidth arithmetic preserves CNN
quality; we extend the same idea to the *distributed-optimization* plane:
gradients are quantized to int8 (per-leaf absmax scale, exactly the
signed-level scheme of core/quant.py) before the cross-pod all-reduce,
with an error-feedback residual so the quantization noise telescopes
instead of accumulating (1-bit-Adam-style).  8x less DCI traffic on the
slowest links of the 2x16x16 mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(jnp.zeros_like, params)


def compress(g: jax.Array, bits: int = 8):
    """g -> (levels int8, scale). Symmetric absmax quantization."""
    z = float(1 << (bits - 1)) - 1
    scale = jnp.max(jnp.abs(g)) / z + 1e-12
    levels = jnp.clip(jnp.round(g / scale), -z, z).astype(jnp.int8)
    return levels, scale.astype(jnp.float32)


def decompress(levels: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return levels.astype(dtype) * scale


def compressed_allreduce(grads, ef_state, axis_name: str | None = None,
                         bits: int = 8):
    """Error-feedback compressed mean-all-reduce over ``axis_name``.

    Works inside shard_map/pmap (axis_name set) or as a pure local
    quantization pass (axis_name None — the GSPMD path where XLA owns the
    collective; compression then models the wire format).
    Returns (new_grads, new_ef_state).
    """
    def one(g, e):
        corrected = g + e
        lv, sc = compress(corrected, bits)
        deq = decompress(lv, sc, g.dtype)
        new_e = corrected - deq
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def compression_ratio(params, bits: int = 8) -> float:
    fp_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    q_bytes = sum(x.size * bits / 8 + 4 for x in jax.tree.leaves(params))
    return fp_bytes / q_bytes
