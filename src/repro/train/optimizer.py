"""Pure-pytree optimizers (no optax): AdamW, SGD-momentum, Lion.

State is a pytree mirroring params, so the distributed layer shards
optimizer moments exactly like parameters (ZeRO: params are already
model x data sharded via the FSDP rule, hence moments are too).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"           # adamw | sgd | lion
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    st = dict(step=jnp.zeros((), jnp.int32))
    if cfg.kind in ("adamw",):
        st["m"] = zeros()
        st["v"] = zeros()
    elif cfg.kind in ("sgd", "lion"):
        st["m"] = zeros()
    return st


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)

    if cfg.kind == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
            return p - lr * (u + cfg.weight_decay * p)

        new_params = jax.tree.map(upd, params, m, v)
        new_state = dict(step=step, m=m, v=v)
    elif cfg.kind == "lion":
        b1, b2 = cfg.b1, cfg.b2

        def upd(p, m_, g):
            u = jnp.sign(b1 * m_ + (1 - b1) * g)
            return p - lr * (u + cfg.weight_decay * p)

        new_params = jax.tree.map(upd, params, state["m"], grads)
        m = jax.tree.map(lambda m_, g: b2 * m_ + (1 - b2) * g, state["m"], grads)
        new_state = dict(step=step, m=m)
    elif cfg.kind == "sgd":
        m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + g, state["m"], grads)
        new_params = jax.tree.map(lambda p, m_: p - lr * m_, params, m)
        new_state = dict(step=step, m=m)
    else:
        raise ValueError(cfg.kind)
    return new_params, new_state, dict(lr=lr, grad_norm=gnorm)


def opt_state_axes(param_axes, cfg: OptConfig):
    """Logical axes for the optimizer state (mirrors init_opt_state)."""
    ax = dict(step=())
    if cfg.kind == "adamw":
        ax["m"] = param_axes
        ax["v"] = param_axes
    elif cfg.kind in ("sgd", "lion"):
        ax["m"] = param_axes
    return ax
