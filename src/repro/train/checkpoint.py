"""Fault-tolerant checkpointing (the NV-element analogue, DESIGN.md §2).

Design mirrors the paper's two-tier retention:
  * FULL checkpoints (params + optimizer + data cursor) — the "NV write
    every N frames": async (background thread), atomic (write tmp ->
    fsync -> rename), self-describing manifest, keep-k GC.
  * ACCUMULATION snapshots (see intermittent.py) — the NV-FA partial-sum
    retention: tiny, frequent, resumable mid-step.

No orbax dependency: npz + json manifest, multi-host-aware naming
(process_index suffix) so each host writes only its addressable shards.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointWriteError(RuntimeError):
    """An async checkpoint write failed after `save()` already returned."""


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        # sweep stale .tmp_* dirs left by a process killed mid-write: they
        # never published (rename never ran) so they hold no durable state,
        # but they escape keep-k GC and would otherwise accumulate forever
        for name in os.listdir(directory):
            if name.startswith(".tmp_"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None,
             tag: str = "ckpt") -> str:
        """Returns the final path (rename happens after write completes)."""
        self.wait()  # one in-flight save at a time
        flat = _flatten(state)  # device->host copy happens here, synchronously
        final = os.path.join(self.dir, f"{tag}_{step:08d}")

        def _write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                manifest = dict(step=step, time=time.time(),
                                n_arrays=len(flat), tag=tag,
                                process_index=jax.process_index(),
                                extra=extra or {})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):  # same-step overwrite (re-snapshot
                    old = final + ".old"   # after a mid-step restart)
                    shutil.rmtree(old, ignore_errors=True)
                    os.rename(final, old)
                    os.rename(tmp, final)  # atomic publish
                    shutil.rmtree(old, ignore_errors=True)
                else:
                    os.rename(tmp, final)  # atomic publish
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            self._gc(tag)

        if self.async_save:
            # A daemon thread's exception would otherwise only reach
            # threading's default excepthook (stderr) — the caller would
            # believe the NV write succeeded and GC the durable state it
            # replaces.  Capture it; wait()/the next save() re-raises.
            def _run():
                try:
                    _write()
                except BaseException as e:  # noqa: BLE001  repro-lint: disable=RL003 — captured into _error; wait()/next save() re-raises
                    self._error = e

            self._thread = threading.Thread(target=_run, daemon=True)
            self._thread.start()
        else:
            _write()
        return final

    def wait(self):
        """Block until the in-flight save completes; raise if it failed.

        A failed async write surfaces here (or at the next ``save()``,
        which waits first) instead of being silently dropped — callers
        treating ``wait()`` as the durability barrier get the truth.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointWriteError(
                f"async checkpoint write failed: {err!r}") from err

    # -- restore --------------------------------------------------------------
    def latest_step(self, tag: str = "ckpt") -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith(f"{tag}_") and not name.startswith("."):
                p = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(p):  # only fully-published checkpoints
                    steps.append(int(name.split("_")[-1]))
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                tag: str = "ckpt"):
        """Returns (step, state) or (None, None) when nothing to restore."""
        step = step if step is not None else self.latest_step(tag)
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"{tag}_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return step, _unflatten(template, flat)

    def manifest(self, step: int, tag: str = "ckpt") -> dict:
        path = os.path.join(self.dir, f"{tag}_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def purge(self, prefix: str) -> int:
        """Remove every published checkpoint whose name starts with
        ``prefix``; returns how many were removed.  Prefix (not exact-tag)
        matching on purpose: families of derived tags (e.g. the resilience
        layer's ``dec<hash>`` composition tags) can be dropped wholesale
        with their common stem.  Waits for any in-flight async save first
        so a concurrent write cannot republish what was just purged."""
        self.wait()
        n = 0
        for name in list(os.listdir(self.dir)):
            if name.startswith(prefix) and not name.startswith("."):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
                n += 1
        return n

    def _gc(self, tag: str):
        entries = sorted(
            n for n in os.listdir(self.dir)
            if n.startswith(f"{tag}_") and not n.startswith("."))
        for name in entries[: max(0, len(entries) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
