"""Elastic scaling + straggler mitigation for the multi-pod runtime.

Checkpoint-mediated elasticity: shardings are *functions of the mesh*
(distributed/sharding.py), so growing/shrinking the slice is: drain ->
full checkpoint -> rebuild mesh/plan -> re-place params under the new
shardings -> resume at the same step with the same data cursor (the
pipeline addresses batches by (step, micro), not by wall clock).

Straggler policy: deterministic data reassignment — every host can compute
any other host's shard from (step, host_id), so a backup host can shadow a
straggler's microbatch without coordination (speculative execution); the
first result wins at the all-reduce via the standard "first write" rule.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.configs.base import make_plan
from repro.distributed import sharding as shd


@dataclasses.dataclass
class ElasticState:
    mesh: Any
    plan: Any


def build(mesh) -> ElasticState:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ElasticState(mesh=mesh, plan=make_plan(shape))


def remesh(params, param_axes, cfg, old: ElasticState, new_mesh) -> tuple[Any, ElasticState]:
    """Re-place a param pytree under a new mesh's shardings."""
    new = build(new_mesh)
    sh = shd.tree_shardings(params, param_axes, new.plan, new_mesh, cfg)

    def place(x, s):
        return jax.device_put(np.asarray(x), s)

    # lockstep walk (axes leaves are tuples)
    def walk(t, s):
        if isinstance(t, dict):
            return {k: walk(t[k], s[k]) for k in t}
        if isinstance(t, list):
            return [walk(a, b) for a, b in zip(t, s)]
        return place(t, s)

    return walk(params, sh), new


def shard_assignment(n_hosts: int, step: int, micro: int,
                     global_batch: int) -> list[tuple[int, int]]:
    """Deterministic (host -> batch-slice) map; any host can recompute any
    other host's slice, enabling speculative straggler shadowing."""
    per = global_batch // n_hosts
    # rotate assignments each step so a persistently slow host doesn't
    # starve the same data shard
    rot = (step + micro) % n_hosts
    return [((h + rot) % n_hosts, h * per) for h in range(n_hosts)]


def straggler_backup(host: int, n_hosts: int, step: int, micro: int) -> int:
    """Which host shadows ``host`` this microbatch (ring neighbor)."""
    return (host + 1 + (step + micro) % (n_hosts - 1)) % n_hosts if n_hosts > 1 else host
