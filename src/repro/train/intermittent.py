"""Power-intermittency-resilient training — the NV-FA adapted to pods.

Paper §II-B3: NV full adders retain *partial accumulation state* so a
power failure loses only the in-flight add (~(m+n)x58 ps), not the whole
feature map; full NV writes happen every fixed number of frames.

Datacenter analogue implemented here: gradient-accumulation microbatches
are the partial sums.  The trainer snapshots (microbatch index, gradient
accumulator, RNG) every ``snapshot_every`` microbatches — cheap and
frequent, like the NV-FF — while full (params+opt) checkpoints happen
every ``full_every`` steps.  On restart after a failure the step resumes
*mid-accumulation*: at most ``snapshot_every - 1`` microbatches are
recomputed, and the result is bit-identical to the uninterrupted run
(deterministic data + integer-indexed RNG), which tests/test_intermittent.py
asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import optimizer as opt_mod
from .checkpoint import Checkpointer


class PowerFailure(RuntimeError):
    """Injected by tests / chaos harnesses to simulate power loss."""


@dataclasses.dataclass
class IntermittentConfig:
    accum_steps: int = 8          # microbatches per optimizer step
    snapshot_every: int = 2       # NV-FA analogue period (microbatches)
    full_every: int = 10          # full checkpoint period (steps)


class IntermittentTrainer:
    """Microbatched trainer with mid-step restartability.

    loss_fn(params, microbatch) -> (loss, metrics); grads are averaged over
    ``accum_steps`` microbatches produced by ``batch_fn(step, micro_idx)``
    (deterministic addressing = the replayable "frame stream").
    """

    def __init__(self, loss_fn, params, opt_cfg: opt_mod.OptConfig,
                 batch_fn: Callable[[int, int], Any],
                 ckpt: Checkpointer, icfg: IntermittentConfig,
                 fail_at: Optional[set] = None):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.icfg = icfg
        self.fail_at = fail_at or set()   # {(step, micro_idx), ...}
        self.params = params
        self.opt_state = opt_mod.init_opt_state(params, opt_cfg)
        self.step = 0
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        self._zero_grads = lambda: jax.tree.map(jnp.zeros_like, self.params)

    # -- persistence ---------------------------------------------------------
    def _train_state(self):
        return dict(params=self.params, opt=self.opt_state)

    def save_full(self):
        self.ckpt.save(self.step, self._train_state(), tag="full")

    def restore(self) -> bool:
        """Restore latest full checkpoint + any newer accumulation snapshot."""
        step, st = self.ckpt.restore(self._train_state(), tag="full")
        restored = False
        if st is not None:
            self.params, self.opt_state = st["params"], st["opt"]
            self.step = step
            restored = True
        snap_step = self.ckpt.latest_step(tag="accum")
        if snap_step is not None and snap_step >= self.step:
            template = dict(accum=self._zero_grads(),
                            micro=jnp.zeros((), jnp.int32),
                            loss_sum=jnp.zeros(()))
            _, snap = self.ckpt.restore(template, step=snap_step, tag="accum")
            self._pending = (snap_step, int(snap["micro"]), snap["accum"],
                             float(snap["loss_sum"]))
            restored = True
        else:
            self._pending = None
        return restored

    # -- the step ------------------------------------------------------------
    def _run_step(self, resume_micro: int = 0, accum=None, loss_sum=0.0):
        icfg = self.icfg
        accum = accum if accum is not None else self._zero_grads()
        for mi in range(resume_micro, icfg.accum_steps):
            if (self.step, mi) in self.fail_at:
                self.fail_at.discard((self.step, mi))
                raise PowerFailure(f"power lost at step {self.step} micro {mi}")
            batch = self.batch_fn(self.step, mi)
            (loss, _), grads = self._grad_fn(self.params, batch)
            accum = jax.tree.map(jnp.add, accum, grads)
            loss_sum = loss_sum + float(loss)
            nxt = mi + 1
            if nxt % icfg.snapshot_every == 0 and nxt < icfg.accum_steps:
                # NV-FA write: persist the partial accumulation
                self.ckpt.save(self.step, dict(
                    accum=accum, micro=jnp.asarray(nxt, jnp.int32),
                    loss_sum=jnp.asarray(loss_sum)), tag="accum")
                self.ckpt.wait()
        grads = jax.tree.map(lambda g: g / icfg.accum_steps, accum)
        self.params, self.opt_state, stats = opt_mod.apply_updates(
            self.params, grads, self.opt_state, self.opt_cfg)
        self.step += 1
        return dict(loss=loss_sum / icfg.accum_steps, **stats)

    def train(self, n_steps: int):
        """Run n_steps; raises PowerFailure when injected (caller restarts)."""
        metrics = None
        pend = getattr(self, "_pending", None)
        if pend is not None and pend[0] == self.step:
            _, micro, accum, loss_sum = pend
            self._pending = None
            metrics = self._run_step(micro, accum, loss_sum)
            if self.step % self.icfg.full_every == 0:
                self.save_full()
        while self.step < n_steps:
            metrics = self._run_step()
            if self.step % self.icfg.full_every == 0:
                self.save_full()
        self.ckpt.wait()
        return metrics


def run_with_failures(make_trainer, n_steps: int, max_restarts: int = 64):
    """Chaos harness: restart-on-failure loop (the battery-less IoT node)."""
    restarts = 0
    trainer = make_trainer()
    trainer.restore()
    while True:
        try:
            out = trainer.train(n_steps)
            trainer.save_full()
            trainer.ckpt.wait()
            return trainer, out, restarts
        except PowerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            trainer = make_trainer()   # cold boot
            trainer.restore()
