"""Fault-surviving serve engine: epoch decode, recovery, degradation.

``ServeEngine`` (launch/engine.py) made many requests fast; this subclass
makes them survive the paper's operating environment — a power-intermittent
node (§II-B3) — without giving up the bit-identity contract:

* every dispatch is bracketed by :class:`repro.resilience.faults.FaultPlan`
  hook points (staging, prefill, per decode epoch, single-shot dispatch);
* the LM decode runs as K-step **epochs** (:class:`EpochLMRunner`) whose
  state commits through :class:`~repro.resilience.checkpoints.
  DecodeCheckpointer` after every epoch — the software NV-FA: a kill
  mid-decode loses at most one epoch, never the prefill or prior tokens;
* a killed bucket's requests are **re-enqueued idempotently** (same rid,
  same ``t_submit``, results recorded at most once) behind bounded
  exponential backoff with jitter; a request that exhausts its retries or
  its deadline lands in :attr:`ResilientServeEngine.dead_letters` instead
  of vanishing;
* under repeated faults or a modeled energy budget, the engine **degrades**
  to a pre-compiled lower-bit-width plan
  (:class:`repro.resilience.degrade.DegradePolicy`) — trading accuracy for
  forward progress exactly as the paper's low-bit operating points do.

The resilient engine is deliberately a *per-node* story (mesh=None only)
and dispatches buckets synchronously — recoverability instead of the base
engine's double-buffered overlap.  Forward-progress work accounting lives
in ``stats`` in logical decode steps, so a chaos run's efficiency is a
deterministic function of the fault seed and maps directly onto
``pim/intermittent.forward_progress`` (``benchmarks/bench_resilience.py``).
"""
from __future__ import annotations

import contextlib
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.engine import Bucket, LMRunner, Result, ServeEngine
from .checkpoints import DecodeCheckpointer
from .faults import (DEVICE_DROP, POWER_LOSS, SLOW_DISPATCH,
                     STAGING_CORRUPTION, DeviceDrop, FaultPlan, PowerLoss)

# logical work-clock charge (in decode-step units) for non-decode hooks:
# staging is a host copy (cheap), prefill one fused program over the prompt
STAGING_DT = 0.25
PREFILL_DT = 1.0


class EpochLMRunner(LMRunner):
    """LM runner whose decode is segmented into K-step checkpoint epochs.

    Instead of one fused prefill+scan program per bucket (``LMRunner``),
    the engine drives ``make_prefill_fn`` once and ``make_epoch_fn`` per
    epoch, committing state between epochs.  Each epoch is still a jitted
    ``lax.scan`` — the per-step dataflow is identical to ``launch/serve``'s
    one-trace decode, only the scan boundary moves — and only two epoch
    lengths ever compile (K and the tail remainder).

    ``epoch_steps`` is the checkpoint period: the paper's P, in decode
    steps.  Faulted-and-resumed output is bit-identical to a fault-free
    run *of this same runner* (the epoch boundary is a program boundary,
    so resume replays the exact program sequence on the exact state).
    """

    supports_epochs = True

    def __init__(self, params, cfg, *, new_tokens: int, epoch_steps: int = 4,
                 qmode: str = "serve", plan=None, model_plan=None):
        super().__init__(params, cfg, new_tokens=new_tokens, qmode=qmode,
                         plan=plan, model_plan=model_plan)
        if epoch_steps < 1:
            raise ValueError(f"epoch_steps must be >= 1, got {epoch_steps}")
        self.epoch_steps = int(epoch_steps)

    def epoch_schedule(self) -> tuple:
        """Decode-step counts per epoch: K, K, ..., remainder."""
        n, k = self.new_tokens - 1, self.epoch_steps
        return tuple([k] * (n // k) + ([n % k] if n % k else []))

    def _ctx(self):
        return (self.model_plan.activate() if self.model_plan is not None
                else contextlib.nullcontext())

    def make_prefill_fn(self, key):
        """(params, toks (B, S_p)) -> (grown cache, tok (B,1), pos)."""
        from repro.launch.serve import greedy_token, grow_cache
        from repro.models import transformer as T

        _, prompt_len, new_tokens = key
        cfg, plan, qmode = self.cfg, self.plan, self.qmode
        slots = prompt_len + new_tokens

        def fwd(params, toks):
            with self._ctx():
                logits, cache = T.prefill(params, cfg, plan, tokens=toks,
                                          qmode=qmode)
                cache = grow_cache(cache, prompt_len, slots)
                first = greedy_token(logits, cfg.vocab)
            return cache, first, jnp.asarray(prompt_len, jnp.int32)

        return fwd

    def make_epoch_fn(self, key, steps: int):
        """(params, cache, tok, pos) -> (cache, tok, pos, chunk (B, steps))."""
        from repro.launch.serve import make_decode_step

        cfg, plan, qmode = self.cfg, self.plan, self.qmode

        def fwd(params, cache, tok, pos):
            with self._ctx():
                step = make_decode_step(params, cfg, plan, qmode)
                (cache, tok, pos), toks = jax.lax.scan(
                    step, (cache, tok, pos), None, length=steps)
            return cache, tok, pos, toks[:, :, 0].T

        return fwd

    def decode_state_template(self, key, batch: int, emitted: int) -> dict:
        """Checkpoint-state structure rebuilt from config alone — nothing
        volatile survives a reboot, so restore cannot depend on any live
        cache object (shapes come from the stored arrays; the template
        supplies structure and dtypes)."""
        from repro.models import transformer as T

        _, prompt_len, new_tokens = key
        cache = T.init_cache(self.cfg, self.plan, batch,
                             prompt_len + new_tokens)
        return dict(cache=cache,
                    tok=np.zeros((batch, 1), np.int32),
                    pos=np.zeros((), np.int32),
                    toks=np.zeros((batch, emitted), np.int32))


class ResilientServeEngine(ServeEngine):
    """A :class:`ServeEngine` that survives an adversarial ``FaultPlan``.

    Parameters (beyond the base engine's)
    -------------------------------------
    fault_plan:      the seeded fault schedule (None -> fault-free, same
                     code path — the reference arm of bit-identity tests).
    checkpoint_dir:  where decode epoch checkpoints commit; None disables
                     micro-checkpointing (the volatile P=0 baseline: a kill
                     restarts the whole bucket from prefill).
    max_retries:     kills a request survives before dead-lettering.
    backoff_base_s / backoff_max_s: exponential backoff bounds for
                     re-enqueued buckets (jittered; the engine's ``clock``
                     gates eligibility, so fake clocks stay deterministic).
    deadline_s:      per-request wall budget (submit -> dispatch start);
                     expired requests dead-letter with reason "deadline".
    degrade:         a :class:`repro.resilience.degrade.DegradePolicy`;
                     with ``fallbacks``, repeated faults or an exhausted
                     energy budget swap the runner to the next (lower-bit)
                     plan and reset the retry budget.  With the policy's
                     ``recover_after`` set, a streak of clean dispatches
                     re-arms the primary plan (``stats["recoveries"]``).
    fallbacks:       runners over pre-compiled degraded plans, best first.
    """

    def __init__(self, runner, *, fault_plan: FaultPlan | None = None,
                 checkpoint_dir: str | None = None, max_retries: int = 3,
                 backoff_base_s: float = 0.01, backoff_max_s: float = 1.0,
                 deadline_s: float | None = None, degrade=None,
                 fallbacks=(), slow_dispatch_s: float = 0.0, seed: int = 0,
                 **kw):
        if kw.get("mesh") is not None:
            raise ValueError(
                "ResilientServeEngine is the per-node intermittency story "
                "(paper §II-B3): mesh sharding is not supported — shard "
                "above the engine, one resilient engine per node")
        super().__init__(runner, **kw)
        self.faults = fault_plan if fault_plan is not None else FaultPlan(None)
        self.ckpt = (DecodeCheckpointer(checkpoint_dir)
                     if checkpoint_dir else None)
        self.max_retries = int(max_retries)
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.deadline_s = deadline_s
        self.policy = degrade
        self.slow_dispatch_s = slow_dispatch_s
        self._runners = [runner, *fallbacks]
        self._active = 0
        # energy-weighted fault clock: MTBF is really mean-energy-between-
        # failures on a harvested supply, so a dispatch's fault exposure
        # scales with the active plan's energy per step.  1.0 for the
        # primary plan; degrading rescales by the fallback's relative
        # modeled energy — the causal mechanism by which the paper's
        # lower-bit operating points survive more brownouts (§II-B3)
        self._energy_scale = 1.0
        self._rng = np.random.RandomState(seed)
        self._attempts: dict[int, int] = {}
        self._retry: list[tuple[float, object]] = []   # (eligible_at, Request)
        self.dead_letters: dict[int, str] = {}
        self.result_runner: dict[int, int] = {}        # rid -> runner index
        self.stats.update(
            faults=0, power_losses=0, device_drops=0, slow_dispatches=0,
            staging_retries=0, retries=0, dead_lettered=0, degrades=0,
            recoveries=0,
            prefills=0, resumes=0, epochs=0, commits=0, commit_s=0.0,
            executed_steps=0, useful_steps=0, wasted_steps=0.0,
            energy_pj=0.0)

    # -- queue side: retries are pre-admitted work --------------------------

    def _queued(self) -> int:
        return super()._queued() + len(self._retry)

    def _admit_retries(self, force: bool = False) -> None:
        """Move backoff-expired retries back into the batcher (original
        Request objects: same rid, same t_submit — idempotent)."""
        now = self.clock()
        still = []
        for eligible_at, req in self._retry:
            if force or eligible_at <= now:
                b = self.batcher.add(req, self.runner.shape_key(req.payload),
                                     now)
                if b is not None:
                    self._ready.append(b)
            else:
                still.append((eligible_at, req))
        self._retry = still

    def pump(self) -> None:
        self._admit_retries()
        super().pump()

    def drain(self) -> list[Result]:
        """Run to completion: every request either completes or
        dead-letters.  Closed-loop drain force-admits backoff'd retries
        (backoff paces the open-loop ``pump`` path; "drain now" means the
        caller is the clock).  Terminates because every kill increments an
        attempt counter bounded by ``max_retries``."""
        while True:
            self._admit_retries(force=True)
            self._flush_all()
            if not self._retry and not self.batcher.pending() \
                    and not self._ready:
                break
        out = [self._results[rid] for rid in sorted(self._results)]
        self._results.clear()
        return out

    # -- recovery ------------------------------------------------------------

    def _dead_letter(self, req, reason: str) -> None:
        if req.rid in self.dead_letters or req.rid in self._results:
            return
        self.dead_letters[req.rid] = reason
        self.stats["dead_lettered"] += 1
        self._attempts.pop(req.rid, None)

    def _requeue(self, bucket: Bucket) -> None:
        """Idempotent re-enqueue of a killed bucket: bounded retries,
        exponential backoff with jitter, dead-letter on exhaustion."""
        now = self.clock()
        survivors = []
        for req in bucket.requests:
            a = self._attempts.get(req.rid, 0) + 1
            self._attempts[req.rid] = a
            if a > self.max_retries:
                self._dead_letter(req,
                                  f"retries exhausted ({self.max_retries})")
                continue
            delay = min(self.backoff_base_s * (1 << (a - 1)),
                        self.backoff_max_s)
            delay *= 0.5 + self._rng.uniform()          # jitter [0.5, 1.5)
            self._retry.append((now + delay, req))
            self.stats["retries"] += 1
            survivors.append(req)
        if self.ckpt is not None and len(survivors) != len(bucket.requests):
            # composition changed: the old tag can never be resumed
            self.ckpt.purge(self._bucket_tag(bucket))

    def _maybe_degrade(self) -> None:
        if self.policy is None or self._active + 1 >= len(self._runners):
            return
        if not self.policy.should_degrade():
            return
        old = self.runner
        self._active += 1
        self.runner = self._runners[self._active]
        self._energy_scale *= self._relative_energy(old, self.runner)
        self._params = jax.device_put(self.runner.params)
        self._attempts.clear()   # fresh retry budget at the new operating point
        self.policy.reset()
        self.stats["degrades"] += 1
        if self.ckpt is not None:
            # every outstanding checkpoint names the retired plan fingerprint
            self.ckpt.purge_all()

    def _maybe_recover(self) -> None:
        """Re-arm the primary plan once fault pressure has subsided: the
        inverse of :meth:`_maybe_degrade`, gated by the policy's clean-
        dispatch streak.  Recovery jumps straight back to runner 0 (the
        best operating point — intermediate fallbacks only matter on the
        way *down*) and restores the unit energy scale that the degrades
        had discounted."""
        if self.policy is None or self._active == 0:
            return
        if not self.policy.should_recover():
            return
        self.runner = self._runners[0]
        self._active = 0
        self._energy_scale = 1.0
        self._params = jax.device_put(self.runner.params)
        self._attempts.clear()   # fresh retry budget at the restored point
        self.policy.reset()
        self.stats["recoveries"] += 1
        if self.ckpt is not None:
            # outstanding checkpoints name the degraded plan fingerprint
            self.ckpt.purge_all()

    @staticmethod
    def _relative_energy(old, new) -> float:
        """new plan's modeled energy per step relative to old's (< 1 for a
        genuine bit-width downgrade; 1.0 when either lacks annotations)."""
        from repro.core.plan import plan_energy_pj

        def _e(r):
            plan = getattr(r, "model_plan", None) or getattr(r, "plan", None)
            if plan is not None and hasattr(plan, "layers"):
                return plan_energy_pj(plan)
            return 0.0

        e_old, e_new = _e(old), _e(new)
        return e_new / e_old if e_old > 0 and e_new > 0 else 1.0

    # -- fault hooks ---------------------------------------------------------

    def _fault_gate(self, site: str, dt: float):
        """Poll the fault plan at one hook; kill-class events raise.

        ``dt`` is charged through the energy-weighted clock: the active
        plan's relative energy scales its exposure window."""
        ev = self.faults.poll(site, dt=dt * self._energy_scale)
        if ev is None:
            return None
        if ev.kind == SLOW_DISPATCH:
            self.stats["slow_dispatches"] += 1
            if self.slow_dispatch_s > 0:
                time.sleep(self.slow_dispatch_s)
            return ev
        if ev.kind in (POWER_LOSS, DEVICE_DROP):
            self.stats["wasted_steps"] += ev.offset
            FaultPlan.raise_for(ev)
        return ev

    # -- device side: synchronous, recoverable dispatch ---------------------

    def _execute(self, buckets: list[Bucket]) -> None:
        for bucket in buckets:
            self._run_bucket(bucket)

    def _run_bucket(self, bucket: Bucket) -> None:
        now = self.clock()
        live = []
        for req in bucket.requests:
            if (self.deadline_s is not None
                    and now - req.t_submit > self.deadline_s):
                self._dead_letter(req, "deadline")
            else:
                live.append(req)
        if len(live) != len(bucket.requests):
            if self.ckpt is not None:
                self.ckpt.purge(self._bucket_tag(bucket))
            if not live:
                return
            bucket = Bucket(bucket.key, live)
        try:
            self._dispatch_bucket(bucket)
        except (PowerLoss, DeviceDrop) as f:
            self.stats["faults"] += 1
            self.stats["power_losses" if isinstance(f, PowerLoss)
                       else "device_drops"] += 1
            if self.policy is not None:
                self.policy.record_fault()
            self._requeue(bucket)
            self._maybe_degrade()

    def _dispatch_bucket(self, bucket: Bucket) -> None:
        padded = self._pad_to(len(bucket.requests))
        dev = self._stage_checked(bucket, padded)
        if getattr(self.runner, "supports_epochs", False):
            host = self._run_epochs(bucket, padded, dev)
        else:
            self._fault_gate("dispatch", dt=1.0)
            out = self._executable(bucket.key, padded)(self._params, dev)
            host = np.asarray(out)
            self.stats["executed_steps"] += 1
            self.stats["useful_steps"] += 1
        self._record_results(bucket, padded, host)

    def _stage_checked(self, bucket: Bucket, padded: int):
        """Collate + host->device with corruption detection: a
        ``staging_corruption`` event flips bytes in the staged copy; the
        checksum taken at collate time catches it and the intact host
        payloads are restaged."""
        payloads = [r.payload for r in bucket.requests]
        batch = self.runner.collate(payloads, padded)
        checksum = hashlib.sha1(np.ascontiguousarray(batch)).hexdigest()
        ev = self.faults.poll("staging", dt=STAGING_DT * self._energy_scale)
        if ev is not None:
            if ev.kind == STAGING_CORRUPTION:
                corrupt = batch.copy()
                flat = corrupt.reshape(-1).view(np.uint8)
                flat[self._rng.randint(flat.size)] ^= 0xFF
                staged = corrupt
                if hashlib.sha1(np.ascontiguousarray(staged)).hexdigest() \
                        != checksum:
                    self.stats["staging_retries"] += 1
                    staged = self.runner.collate(payloads, padded)
                batch = staged
            else:
                FaultPlan.raise_for(ev)
        return jax.device_put(batch)

    # -- epoch decode with micro-checkpoints --------------------------------

    def _bucket_tag(self, bucket: Bucket) -> str:
        fp = getattr(self.runner, "plan_fingerprint", lambda: None)()
        return DecodeCheckpointer.tag(
            (r.rid for r in bucket.requests), bucket.key, fp,
            getattr(self.runner, "epoch_steps", 0))

    def _prog(self, kind: str, key, padded: int, steps: int | None = None):
        fp = getattr(self.runner, "plan_fingerprint", lambda: None)()
        cache_key = ("resilient", kind, key, padded, steps, fp)
        if cache_key not in self._fns:
            if kind == "prefill":
                fn = self.runner.make_prefill_fn(key)
            else:
                fn = self.runner.make_epoch_fn(key, steps)
            self._fns[cache_key] = jax.jit(fn)
        return self._fns[cache_key]

    def _run_epochs(self, bucket: Bucket, padded: int, dev) -> np.ndarray:
        r = self.runner
        key = bucket.key
        schedule = r.epoch_schedule()
        tag = self._bucket_tag(bucket) if self.ckpt is not None else None
        start_epoch, state = 0, None
        if tag is not None:
            restored = self.ckpt.restore(
                tag, lambda emitted: r.decode_state_template(key, padded,
                                                             emitted))
            if restored is not None:
                committed, s = restored
                start_epoch = committed
                state = (s["cache"], s["tok"], s["pos"], s["toks"])
                self.stats["resumes"] += 1
        if state is None:
            self._fault_gate("prefill", dt=PREFILL_DT)
            cache, tok, pos = self._prog("prefill", key, padded)(self._params,
                                                                 dev)
            state = (cache, tok, pos, tok)
            self.stats["prefills"] += 1
            if tag is not None:
                self._commit(tag, 0, state)
        for e in range(start_epoch, len(schedule)):
            steps = schedule[e]
            self._fault_gate("decode", dt=float(steps))
            cache, tok, pos, toks = state
            cache, tok, pos, chunk = self._prog("epoch", key, padded,
                                                steps)(self._params, cache,
                                                       tok, pos)
            state = (cache, tok, pos, jnp.concatenate([toks, chunk], axis=1))
            self.stats["executed_steps"] += steps
            self.stats["epochs"] += 1
            if tag is not None:
                self._commit(tag, e + 1, state)
        host = np.asarray(state[3])
        self.stats["useful_steps"] += sum(schedule)
        if tag is not None:
            self.ckpt.purge(tag)
        return host

    def _commit(self, tag: str, epoch: int, state) -> None:
        cache, tok, pos, toks = state
        self.stats["commit_s"] += self.ckpt.commit(
            tag, epoch, dict(cache=cache, tok=tok, pos=pos, toks=toks),
            emitted=int(toks.shape[1]))
        self.stats["commits"] += 1

    # -- harvest -------------------------------------------------------------

    def _record_results(self, bucket: Bucket, padded: int,
                        host: np.ndarray) -> None:
        n = len(bucket.requests)
        t_done = self.clock()
        for req, val in zip(bucket.requests, self.runner.split(host, n)):
            self._results[req.rid] = Result(req.rid, val, req.t_submit,
                                            t_done, n, padded)
            self._attempts.pop(req.rid, None)
            self.result_runner[req.rid] = self._active
        self.stats["dispatches"] += 1
        self.stats["requests"] += n
        self.stats["padded_rows"] += padded - n
        plan = getattr(self.runner, "model_plan", None) \
            or getattr(self.runner, "plan", None)
        energy = 0.0
        if plan is not None and hasattr(plan, "layers"):
            from repro.core.plan import plan_energy_pj

            energy = plan_energy_pj(plan) * padded
            self.stats["energy_pj"] += energy
        if self.policy is not None:
            self.policy.record_dispatch(energy)
            self._maybe_degrade()
            self._maybe_recover()
