"""Crash-consistent decode micro-checkpoints (DESIGN.md §11).

The paper's NV-FA retains partial accumulation state through power loss so
a frame never restarts from scratch (§II-B3); the serving analogue is the
decode epoch: the scanned greedy decode is segmented into K-step epochs,
and after each epoch the bucket's full decode state — KV cache, last
token, position, every token emitted so far — commits through the atomic
:class:`repro.train.checkpoint.Checkpointer` (write tmp -> fsync ->
rename).  A request killed mid-decode resumes from its last committed
epoch; K plays exactly the role of the paper's checkpoint period P, and
``benchmarks/bench_resilience.py`` sweeps it against the analytic
``pim/intermittent.forward_progress`` curves.

Checkpoints are keyed by a **composition tag**: a hash of the bucket's
request ids, its shape key, the plan fingerprint, and the epoch length.
The LM engine's bit-identity contract holds at fixed bucket composition,
so a checkpoint is only ever resumed by a re-dispatch of the *same*
requests under the *same* plan — anything else (a partially dead-lettered
bucket, a degraded plan) hashes to a different tag and restarts cleanly
from prefill.

Restore is template-free in the crash sense: the state *structure* is
rebuilt from the model config (``runner.decode_state_template``) and the
emitted-token count recorded in the checkpoint manifest, so a rebooted
process needs nothing volatile to resume — only the directory.
"""
from __future__ import annotations

import hashlib
import time

from repro.train.checkpoint import Checkpointer


class DecodeCheckpointer:
    """Per-bucket epoch checkpoints over the atomic ``Checkpointer``.

    Writes are synchronous: the commit IS the durability point the
    resilience contract counts on (an async write racing a power loss is
    exactly the window the paper's NV-FA closes), and its measured cost is
    the ``nv_write_us`` of the analytic model.
    """

    def __init__(self, directory: str, keep: int = 2):
        self.dir = directory
        self._ck = Checkpointer(directory, keep=keep, async_save=False)

    # -- identity ------------------------------------------------------------

    @staticmethod
    def tag(rids, shape_key, plan_fp, epoch_steps: int) -> str:
        blob = repr((tuple(rids), shape_key, plan_fp,
                     int(epoch_steps))).encode()
        return "dec" + hashlib.sha256(blob).hexdigest()[:16]

    # -- commit / restore ----------------------------------------------------

    def commit(self, tag: str, epoch: int, state: dict,
               emitted: int) -> float:
        """Durably commit one epoch's state; returns the write seconds.

        ``epoch`` counts committed epochs: 0 after prefill, e+1 after
        decode epoch e.  ``emitted`` (tokens per request so far) goes into
        the manifest so restore can rebuild the token-buffer template
        without any volatile knowledge.
        """
        t0 = time.perf_counter()
        self._ck.save(int(epoch), state, extra=dict(emitted=int(emitted)),
                      tag=tag)
        return time.perf_counter() - t0

    def latest(self, tag: str):
        return self._ck.latest_step(tag)

    def restore(self, tag: str, template_fn):
        """Resume state for ``tag``: ``(committed_epochs, state)`` or None.

        ``template_fn(emitted) -> state pytree`` supplies the structure
        (from model config, not from any live object) for the flat-array
        unflatten.
        """
        step = self._ck.latest_step(tag)
        if step is None:
            return None
        emitted = int(self._ck.manifest(step, tag)["extra"]["emitted"])
        _, state = self._ck.restore(template_fn(emitted), step, tag)
        return step, state

    # -- lifecycle -----------------------------------------------------------

    def purge(self, tag: str) -> int:
        """Drop every epoch of one completed/abandoned bucket."""
        return self._ck.purge(tag)

    def purge_all(self) -> int:
        """Drop everything — e.g. after a plan degrade, when every
        outstanding checkpoint refers to the retired plan fingerprint."""
        return self._ck.purge("dec")
