"""Graceful degradation policy: trade bits for forward progress.

The paper's low bit-width operating points (W1A1 .. W1A8, Table/Fig. 5-6)
are not just an accuracy/energy dial — under intermittent power they are a
*survival* dial: a lower-bit plan moves fewer bytes and burns fewer pJ per
dispatch, so the same harvested-energy envelope completes more frames.
:class:`DegradePolicy` decides *when* the serving engine should take that
trade; :class:`repro.resilience.engine.ResilientServeEngine` executes it by
swapping to the next pre-compiled fallback ``ModelPlan`` (plans reload in
~26 ms, so the swap is cheap and deterministic).

Two triggers, either sufficient:

* **fault pressure** — more than ``fault_threshold`` kill-class faults in
  the last ``fault_window`` dispatch outcomes (a brownout storm: the
  current operating point is too expensive for the incoming energy);
* **energy budget** — cumulative modeled dispatch energy (from the plan's
  per-layer ``cost`` annotations, summed in
  :func:`repro.core.plan.plan_energy_pj`) exceeds ``energy_budget_pj``
  (the harvested-energy envelope of the paper's §II-B3 scenario).

The policy is deliberately memoryless across degrades: the engine calls
:meth:`reset` after each swap so the *new* operating point gets a fresh
window and budget before any further fallback.

Degradation is also reversible: when ``recover_after`` is set, a run of
that many consecutive fault-free dispatches at a degraded operating point
(``should_recover``) re-arms the **primary** plan — the brownout storm has
passed and the node claws back the accuracy it paid for survival.  The
engine resets the policy on recovery too, so a recovered node has to
re-earn any further degrade from a clean window.
"""
from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class DegradePolicy:
    """Sliding-window fault counter + cumulative energy budget."""

    fault_window: int = 8          # dispatch outcomes remembered
    fault_threshold: int = 3       # kill-class faults in window that trigger
    energy_budget_pj: float | None = None   # None = no energy trigger
    recover_after: int | None = None   # clean dispatches that re-arm primary
                                       # (None = degrades are one-way)

    def __post_init__(self):
        if self.fault_window < 1:
            raise ValueError(f"fault_window must be >= 1, "
                             f"got {self.fault_window}")
        if self.fault_threshold < 1:
            raise ValueError(f"fault_threshold must be >= 1, "
                             f"got {self.fault_threshold}")
        if self.energy_budget_pj is not None and self.energy_budget_pj <= 0:
            raise ValueError(f"energy_budget_pj must be positive or None, "
                             f"got {self.energy_budget_pj}")
        if self.recover_after is not None and self.recover_after < 1:
            raise ValueError(f"recover_after must be >= 1 or None, "
                             f"got {self.recover_after}")
        self._window: deque[int] = deque(maxlen=self.fault_window)
        self._energy_pj = 0.0
        self._clean_streak = 0

    # -- observations --------------------------------------------------------

    def record_fault(self) -> None:
        """One kill-class fault (power loss / device drop) happened."""
        self._window.append(1)
        self._clean_streak = 0

    def record_dispatch(self, energy_pj: float = 0.0) -> None:
        """One dispatch completed, spending ``energy_pj`` modeled energy."""
        self._window.append(0)
        self._energy_pj += float(energy_pj)
        self._clean_streak += 1

    # -- decision ------------------------------------------------------------

    @property
    def spent_pj(self) -> float:
        return self._energy_pj

    def fault_pressure(self) -> int:
        return sum(self._window)

    def clean_streak(self) -> int:
        return self._clean_streak

    def should_degrade(self) -> bool:
        if self.fault_pressure() >= self.fault_threshold:
            return True
        return (self.energy_budget_pj is not None
                and self._energy_pj >= self.energy_budget_pj)

    def should_recover(self) -> bool:
        """Fault pressure has subsided: ``recover_after`` consecutive clean
        dispatches since the last kill-class fault (or reset)."""
        return (self.recover_after is not None
                and self._clean_streak >= self.recover_after)

    def reset(self) -> None:
        """Fresh window, budget, and streak for the new operating point."""
        self._window.clear()
        self._energy_pj = 0.0
        self._clean_streak = 0
