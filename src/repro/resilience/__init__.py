"""Executable intermittency resilience (DESIGN.md §11).

Makes the paper's power-intermittency claim (§II-B3, Fig. 7) a property of
the *running* serve stack instead of only the analytic
``pim/intermittent.forward_progress`` model:

* :class:`FaultPlan` — seeded deterministic fault schedules (power loss,
  device drop, slow dispatch, staging corruption) on a logical work clock;
* :class:`DecodeCheckpointer` — crash-consistent K-step decode epoch
  checkpoints through the atomic train Checkpointer (software NV-FA);
* :class:`ResilientServeEngine` / :class:`EpochLMRunner` — a ServeEngine
  that survives the schedule: idempotent re-enqueue, bounded backoff
  retries, deadlines, dead letters;
* :class:`DegradePolicy` — fall back to a pre-compiled lower-bit plan
  under fault pressure or an energy budget.

Entry points: construct the pieces directly, or go through
``repro.api``::

    compiled = api.build(cfg, params=p).compile()
    dep = compiled.serve(resilience=ResilienceConfig(
        fault_plan=FaultPlan(mtbf=32.0, seed=0),
        checkpoint_dir="results/ckpt", epoch_steps=4))
"""
from __future__ import annotations

import dataclasses

from .checkpoints import DecodeCheckpointer
from .degrade import DegradePolicy
from .engine import EpochLMRunner, ResilientServeEngine
from .faults import (DEVICE_DROP, POWER_LOSS, SITE_KINDS, SLOW_DISPATCH,
                     STAGING_CORRUPTION, DeviceDrop, FaultError, FaultEvent,
                     FaultPlan, PowerLoss)

__all__ = [
    "FaultPlan", "FaultEvent", "FaultError", "PowerLoss", "DeviceDrop",
    "POWER_LOSS", "DEVICE_DROP", "SLOW_DISPATCH", "STAGING_CORRUPTION",
    "SITE_KINDS", "DecodeCheckpointer", "DegradePolicy", "EpochLMRunner",
    "ResilientServeEngine", "ResilienceConfig", "build_resilient_engine",
]


@dataclasses.dataclass
class ResilienceConfig:
    """Everything the facade needs to stand up a resilient engine."""

    fault_plan: FaultPlan | None = None     # None = fault-free reference arm
    checkpoint_dir: str | None = None       # None = volatile (P=0) baseline
    epoch_steps: int = 4                    # checkpoint period K (paper's P)
    max_retries: int = 3
    deadline_s: float | None = None
    backoff_base_s: float = 0.01
    backoff_max_s: float = 1.0
    degrade: DegradePolicy | None = None


def build_resilient_engine(compiled, config: ResilienceConfig, *,
                           fallback=None, new_tokens: int = 16,
                           qmode: str = "serve",
                           **engine_kw) -> ResilientServeEngine:
    """Resilient engine over a :class:`repro.api.session.CompiledModel`.

    ``fallback`` is another CompiledModel (same architecture, lower bit
    width) compiled ahead of time; with ``config.degrade`` set, the engine
    swaps to it under fault pressure / energy exhaustion.
    """
    from repro.core.plan import PlanError
    from repro.launch.engine import CNNRunner

    def _runner(c):
        if c.plan.kind == "lm":
            if c.model is None:
                raise PlanError(
                    "resilient LM serving needs the ArchConfig — build the "
                    "CompiledModel through api.build(cfg, ...).compile() or "
                    "api.load(path, spec=cfg)")
            return EpochLMRunner(None, c.model.spec, new_tokens=new_tokens,
                                 epoch_steps=config.epoch_steps, qmode=qmode,
                                 model_plan=c.plan)
        return CNNRunner(None, c.model.spec if c.model is not None else None,
                         None, plan=c.plan)

    fallbacks = () if fallback is None else (_runner(fallback),)
    return ResilientServeEngine(
        _runner(compiled),
        fault_plan=config.fault_plan,
        checkpoint_dir=config.checkpoint_dir,
        max_retries=config.max_retries,
        deadline_s=config.deadline_s,
        backoff_base_s=config.backoff_base_s,
        backoff_max_s=config.backoff_max_s,
        degrade=config.degrade,
        fallbacks=fallbacks,
        **engine_kw)
