"""Deterministic fault injection for the live serve path (DESIGN.md §11).

The paper's second headline claim is power-intermittency resilience: a
battery-less node keeps making forward progress because the partial state
it needs lives in non-volatile elements (§II-B3, Fig. 7).  The analytic
side of that claim is ``pim/intermittent.forward_progress``; this module
supplies the *executable* side — a seeded, reproducible schedule of fault
events that :class:`repro.resilience.engine.ResilientServeEngine` polls at
its hook points (staging, prefill, each decode epoch, single-shot
dispatch).

Faults are drawn on a **logical work clock** measured in decode steps, not
wall time: every hook advances the clock by the amount of work it is about
to attempt (``dt``), and a fault fires when the pre-drawn exponential
schedule (mean ``mtbf`` steps — the MTBF of the paper's Fig. 7, in frames)
lands inside that window.  Logical time makes a chaos run a pure function
of ``(seed, mtbf, submit order)``: the bit-identity tests replay the exact
same kill points on every host, and the measured forward-progress
efficiency maps onto the analytic model without wall-clock noise.

Event kinds and who may draw them:

=====================  =====================================================
``power_loss``         the node browns out: everything volatile in the
                       current dispatch is lost (any site)
``device_drop``        the accelerator disappears mid-dispatch; host state
                       survives (prefill/decode/dispatch)
``slow_dispatch``      the dispatch stalls (brownout throttling) — latency
                       only, no state loss (prefill/decode/dispatch)
``staging_corruption`` the host->device copy is corrupted; detected by
                       checksum and restaged (staging only)
=====================  =====================================================
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

POWER_LOSS = "power_loss"
DEVICE_DROP = "device_drop"
SLOW_DISPATCH = "slow_dispatch"
STAGING_CORRUPTION = "staging_corruption"

KINDS = (POWER_LOSS, DEVICE_DROP, SLOW_DISPATCH, STAGING_CORRUPTION)

# which kinds are physically meaningful at each hook site: a corrupted
# host->device copy can only be observed while staging; a lost device or a
# stalled program only while a program is (about to be) in flight
SITE_KINDS = {
    "staging": (POWER_LOSS, STAGING_CORRUPTION),
    "prefill": (POWER_LOSS, DEVICE_DROP, SLOW_DISPATCH),
    "decode": (POWER_LOSS, DEVICE_DROP, SLOW_DISPATCH),
    "dispatch": (POWER_LOSS, DEVICE_DROP, SLOW_DISPATCH),
}

DEFAULT_WEIGHTS = {POWER_LOSS: 0.6, DEVICE_DROP: 0.2,
                   SLOW_DISPATCH: 0.1, STAGING_CORRUPTION: 0.1}


class FaultError(RuntimeError):
    """A fault event realized as an exception; ``.event`` holds it."""

    def __init__(self, event: "FaultEvent"):
        super().__init__(f"{event.kind} at {event.site} (t={event.t:.2f})")
        self.event = event


class PowerLoss(FaultError):
    """Power failed: all volatile state in the current dispatch is gone."""


class DeviceDrop(FaultError):
    """The device vanished mid-dispatch; host-side state survives."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str
    site: str
    t: float          # logical work-clock time at which the fault fired
    offset: float     # how far into this hook's dt window it landed
    seq: int          # firing order (0-based)


class FaultPlan:
    """A seeded, deterministic schedule of fault events.

    Three construction modes:

    * ``FaultPlan(mtbf, seed=..)`` — random schedule: inter-fault gaps are
      exponential with mean ``mtbf`` logical steps; the kind of each fault
      is drawn from ``weights`` restricted to what is meaningful at the
      site that happens to be polling (:data:`SITE_KINDS`).  Same seed +
      same poll sequence -> same events, always.
    * ``FaultPlan.scripted([(site, n, kind), ..])`` — fire ``kind`` at the
      ``n``-th poll of ``site`` (0-based, counted per site).  This is the
      test surface: "kill the first prefill", "corrupt the second staging"
      are one tuple each, with no RNG in the way.
    * ``FaultPlan.timeline([(t, kind), ..])`` — fire ``kind`` at fixed
      work-clock times, whichever site happens to be polling when the
      clock reaches ``t``.  This is how an energy-harvest trace becomes a
      live fault schedule: ``repro.fleet.sim`` derives outage instants
      from a trace and both the fleet simulator and the serve engine
      consume the *same* event list.  Only site-universal kinds
      (``power_loss``) are allowed — a timeline does not know which site
      will observe it.

    Modes compose (scripted events take precedence, then timeline, then
    random); :meth:`to_json`/:meth:`from_json` round-trip the construction
    spec so chaos tests, benchmarks, and fleet traces share one on-disk
    format.  ``FaultPlan(None)`` never fires — the fault-free reference
    arm of every bit-identity assertion runs the identical code path.
    """

    def __init__(self, mtbf: float | None, *, seed: int = 0,
                 weights: dict | None = None):
        if mtbf is not None and mtbf <= 0:
            raise ValueError(f"mtbf must be positive (logical decode steps) "
                             f"or None for no random faults, got {mtbf}")
        self.mtbf = mtbf
        self.seed = int(seed)
        self.weights = dict(weights or DEFAULT_WEIGHTS)
        unknown = set(self.weights) - set(KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds {sorted(unknown)}; "
                             f"valid: {list(KINDS)}")
        self._rng = np.random.RandomState(seed)
        self._t = 0.0
        self._next = (self._t + self._rng.exponential(mtbf)
                      if mtbf is not None else float("inf"))
        self._scripted: dict[tuple[str, int], str] = {}
        self._timeline: list[tuple[float, str]] = []
        self._timeline_idx = 0
        self._site_calls: dict[str, int] = {}
        self.log: list[FaultEvent] = []

    @classmethod
    def scripted(cls, events) -> "FaultPlan":
        """``events``: iterable of ``(site, nth_poll_of_site, kind)``."""
        plan = cls(None)
        for site, n, kind in events:
            if site not in SITE_KINDS:
                raise ValueError(f"unknown site {site!r}; "
                                 f"valid: {sorted(SITE_KINDS)}")
            if kind not in SITE_KINDS[site]:
                raise ValueError(f"kind {kind!r} cannot fire at {site!r} "
                                 f"(allowed: {SITE_KINDS[site]})")
            plan._scripted[(site, int(n))] = kind
        return plan

    @classmethod
    def timeline(cls, events) -> "FaultPlan":
        """``events``: iterable of ``(work_clock_t, kind)``, non-decreasing
        ``t >= 0``.  Each event fires inside the first poll whose window
        reaches ``t`` (the clock stops at the event, like random mode)."""
        universal = set(KINDS)
        for kinds in SITE_KINDS.values():
            universal &= set(kinds)
        plan = cls(None)
        prev = 0.0
        for t, kind in events:
            t = float(t)
            if t < 0:
                raise ValueError(f"timeline t must be >= 0, got {t}")
            if t < prev:
                raise ValueError(f"timeline times must be non-decreasing "
                                 f"(got {t} after {prev})")
            if kind not in universal:
                raise ValueError(
                    f"kind {kind!r} is not valid at every site (a timeline "
                    f"does not know which site observes it); allowed: "
                    f"{sorted(universal)}")
            plan._timeline.append((t, kind))
            prev = t
        return plan

    # -- serialization (one on-disk format for chaos + fleet schedules) ------

    def to_json(self) -> dict:
        """The *construction* spec (not mid-run polling state): feeding the
        result to :meth:`from_json` yields a fresh, equivalent plan."""
        return dict(
            version=1,
            mtbf=self.mtbf,
            seed=self.seed,
            weights=dict(self.weights),
            scripted=[[site, n, kind]
                      for (site, n), kind in sorted(self._scripted.items())],
            timeline=[[t, kind] for t, kind in self._timeline],
        )

    @classmethod
    def from_json(cls, spec: dict) -> "FaultPlan":
        version = spec.get("version", 1)
        if version != 1:
            raise ValueError(f"unknown FaultPlan spec version {version!r}")
        plan = cls(spec.get("mtbf"), seed=spec.get("seed", 0),
                   weights=spec.get("weights") or None)
        if spec.get("scripted"):
            scripted = cls.scripted(spec["scripted"])
            plan._scripted = scripted._scripted
        if spec.get("timeline"):
            timeline = cls.timeline(spec["timeline"])
            plan._timeline = timeline._timeline
        return plan

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- polling -------------------------------------------------------------

    def poll(self, site: str, dt: float = 1.0):
        """Advance the work clock by ``dt`` for one hook at ``site``.

        Returns the :class:`FaultEvent` that fires inside this window, or
        None.  At most one event fires per poll: once the node is down the
        rest of the window never executes, so the clock stops at the fault
        and the next inter-fault gap is drawn from there.
        """
        n = self._site_calls.get(site, 0)
        self._site_calls[site] = n + 1
        kind = self._scripted.get((site, n))
        if kind is not None:
            ev = FaultEvent(kind, site, self._t, 0.0, len(self.log))
            self.log.append(ev)
            return ev
        end = self._t + dt
        if self._timeline_idx < len(self._timeline):
            ft, tkind = self._timeline[self._timeline_idx]
            if ft <= end:
                self._timeline_idx += 1
                offset = max(0.0, ft - self._t)
                self._t = max(self._t, ft)
                ev = FaultEvent(tkind, site, self._t, offset, len(self.log))
                self.log.append(ev)
                return ev
        if self._next <= end:
            ft = self._next
            offset = ft - self._t
            self._t = ft
            self._next = ft + self._rng.exponential(self.mtbf)
            ev = FaultEvent(self._draw_kind(site), site, ft, offset,
                            len(self.log))
            self.log.append(ev)
            return ev
        self._t = end
        return None

    def _draw_kind(self, site: str) -> str:
        allowed = [k for k in SITE_KINDS.get(site, KINDS)
                   if self.weights.get(k, 0.0) > 0.0]
        if not allowed:
            return POWER_LOSS
        w = np.asarray([self.weights[k] for k in allowed], float)
        return allowed[int(self._rng.choice(len(allowed), p=w / w.sum()))]

    # -- realization ---------------------------------------------------------

    @staticmethod
    def raise_for(event: FaultEvent) -> None:
        """Turn a kill-class event into its exception (the engine's hook
        helper); latency/corruption kinds are handled in place, not raised."""
        if event.kind == POWER_LOSS:
            raise PowerLoss(event)
        if event.kind == DEVICE_DROP:
            raise DeviceDrop(event)
