"""Production serving driver: batched prefill + decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 16 [--quant w1a8]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


def widen_cache(cache, prompt_len: int, slots: int):
    """Grow a prefill cache to the decode horizon (position-preserving)."""
    cache = jax.tree.map(
        lambda t: jnp.pad(t, [(0, 0), (0, 0), (0, slots - t.shape[2])]
                          + [(0, 0)] * (t.ndim - 3))
        if t.ndim >= 3 and t.shape[2] == prompt_len else t, cache)
    for kind in cache:
        if "pos" in cache[kind]:
            cache[kind]["pos"] = jnp.where(
                jnp.arange(slots)[None, None, :] < prompt_len,
                cache[kind]["pos"], -1)
    return cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default=None, choices=list(PAPER_CONFIGS))
    ap.add_argument("--prequant", action="store_true",
                    help="quantize projection weights to int8 levels once at "
                         "model load (serve reads 4x less weight HBM and "
                         "skips per-call weight_levels)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=PAPER_CONFIGS[args.quant])
    qmode = "serve" if args.quant and args.quant != "w32a32" else "train"

    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    if args.prequant and qmode == "serve":
        from repro.models.layers import prequantize_params
        params = prequantize_params(params, cfg)
    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    t0 = time.perf_counter()
    logits, cache = T.prefill(params, cfg, SINGLE, tokens=prompts, qmode=qmode)
    cache = widen_cache(cache, S_p, S_p + S_d)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    step = jax.jit(
        lambda c, t, p: T.decode_step(params, c, t, p, cfg, SINGLE, qmode=qmode))
    toks = [tok]
    for t in range(S_d - 1):
        lg, cache = step(cache, tok, S_p + t)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    jax.block_until_ready(gen)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} quant={args.quant or 'fp'} engine={qmode}"
          f"{' prequant' if args.prequant and qmode == 'serve' else ''}")
    print(f"generated {B}x{S_d} tokens in {dt:.2f}s "
          f"({B * S_d / dt:.1f} tok/s incl. compile)")
    for b in range(min(B, 2)):
        print(f"  sample[{b}]: {list(map(int, gen[b][:12]))}")


if __name__ == "__main__":
    main()
