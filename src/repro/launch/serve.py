"""Production serving driver: batched prefill + scanned decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --batch 4 --prompt-len 16 --new-tokens 16 [--quant w1a8] [--no-smoke]

Decode runs as ONE ``lax.scan``-compiled program over the token axis: a
single trace/dispatch for the whole generation, greedy argmax in-graph (no
host sync per token), and the KV cache donated into the step so XLA updates
it in place instead of copying the full cache every token.  The seed path
re-dispatched a jitted single-token step from Python ``S_d - 1`` times —
each step paid dispatch latency plus a device->host argmax round-trip.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


# The only cache tensors with a sequence axis are the attention KV entries
# (k, v, pos), and their layout is fixed by transformer.init_cache:
# (layers, batch, slots, ...).  Identified by KEY, never by size: recurrent
# state (rec.h is (layers, batch, lru_width), rwkv.s is (layers, batch,
# heads, hd, hd), ...) has no sequence axis, and a width/head count that
# merely *equals* the prompt length must not be padded.
CACHE_SEQ_AXIS = {"k": 2, "v": 2, "pos": 2}


def grow_cache(cache, prompt_len: int, slots: int):
    """Grow a prefill cache to the decode horizon (position-preserving).

    Only attention-style entries (dicts carrying k/v/pos) are grown, along
    their structural sequence axis; every other state tensor passes through
    untouched regardless of any size coincidence with ``prompt_len``.
    New k/v slots are zero-filled and their ``pos`` is -1 (empty).

    This is the *contiguous* cache's growth path (bucket engine, single-
    shot CLI).  The continuous engine
    (``launch/engine.ContinuousLMEngine``) never grows or re-pads a cache:
    KV lives in fixed-size pages and a request's extent is a page-table
    row (``core/kv_pages``).
    """
    out = {}
    for kind, entry in cache.items():
        if not (isinstance(entry, dict) and "pos" in entry):
            out[kind] = entry  # recurrent state: no sequence axis
            continue
        widened = dict(entry)
        for key, axis in CACHE_SEQ_AXIS.items():
            if key not in entry:
                continue
            t = entry[key]
            grow = slots - t.shape[axis]
            if grow <= 0:
                continue
            pad = [(0, 0)] * t.ndim
            pad[axis] = (0, grow)
            widened[key] = jnp.pad(t, pad,
                                   constant_values=-1 if key == "pos" else 0)
        out[kind] = widened
    return out


def widen_cache(cache, prompt_len: int, slots: int):
    """Deprecated alias for :func:`grow_cache` (one-release shim).

    The name now distinguishes the contiguous growth path from the paged
    path, which neither grows nor re-pads.  Delegates unchanged; removal
    after one release.
    """
    import warnings
    warnings.warn(
        "widen_cache is deprecated; use grow_cache (contiguous caches) or "
        "the paged serve path (ContinuousLMEngine), which never re-pads",
        DeprecationWarning, stacklevel=2)
    return grow_cache(cache, prompt_len, slots)


def make_prefill(params, cfg, plan, qmode: str):
    """Jitted prefill: tokens (B, S_p) -> (logits, cache)."""
    return jax.jit(
        lambda toks: T.prefill(params, cfg, plan, tokens=toks, qmode=qmode))


def greedy_token(logits, vocab: int):
    """Greedy next token over the REAL vocab only: the padded unembed tail
    (rows added for TP divisibility, ``cfg.padded_vocab``) holds
    random-init weights and must never be served as an output token."""
    return jnp.argmax(logits[:, -1:, :vocab], -1).astype(jnp.int32)


def make_decode_step(params, cfg, plan, qmode: str):
    """The one greedy scan step shared by every decode realization (this
    CLI's generate and the serving engine's per-bucket program): one
    ``decode_step`` + real-vocab argmax, carry (cache, token, pos)."""
    def step(carry, _):
        cache, tok, pos = carry
        logits, cache = T.decode_step(params, cache, tok, pos, cfg, plan,
                                      qmode=qmode)
        tok = greedy_token(logits, cfg.vocab)
        return (cache, tok, pos + 1), tok

    return step


def make_generate(params, cfg, plan, qmode: str, prompt_len: int,
                  new_tokens: int):
    """One-trace greedy decode: (widened cache, first token) -> (B, S_d).

    The whole token loop is a ``lax.scan`` inside a single jit — one
    dispatch for the full generation — and ``donate_argnums=(0,)`` lets XLA
    reuse the (largest-buffer-in-the-request) KV cache in place.  The
    caller must not reuse the passed cache afterwards.
    """
    step = make_decode_step(params, cfg, plan, qmode)

    def gen(cache, first_tok):
        (_, _, _), toks = jax.lax.scan(
            step, (cache, first_tok, jnp.asarray(prompt_len, jnp.int32)),
            None, length=new_tokens - 1)
        # toks (S_d-1, B, 1) scan-major -> (B, S_d) with the prefill token
        return jnp.concatenate([first_tok, toks[:, :, 0].T], axis=1)

    # CPU can't donate (XLA copies anyway and warns); elsewhere the cache
    # buffers update in place across the whole scan
    donate = () if jax.default_backend() == "cpu" else (0,)
    return jax.jit(gen, donate_argnums=donate)


def serve_once(params, cfg, plan, prompts, new_tokens: int, qmode: str,
               prefill_fn=None, generate_fn=None):
    """One batched request: prefill -> grow -> scanned decode.

    Returns (tokens (B, S_d), wall seconds).  Pass pre-built ``prefill_fn``
    / ``generate_fn`` to measure warm (compile-free) latency.
    """
    B, S_p = prompts.shape
    prefill_fn = prefill_fn or make_prefill(params, cfg, plan, qmode)
    generate_fn = generate_fn or make_generate(params, cfg, plan, qmode,
                                               S_p, new_tokens)
    t0 = time.perf_counter()
    logits, cache = prefill_fn(prompts)
    cache = grow_cache(cache, S_p, S_p + new_tokens)
    first = greedy_token(logits, cfg.vocab)
    gen = generate_fn(cache, first)
    jax.block_until_ready(gen)
    return gen, time.perf_counter() - t0


def run_throughput(params, cfg, qmode: str, args, model_plan=None) -> None:
    """Offered-load throughput mode: drive the request-level engine
    (``repro.launch.engine``) with ``--requests`` independent prompts and
    report requests/s + p50/p99 latency for sequential (max_batch=1) vs
    batched dispatch, plus an offered-rate sweep.  Rows append to
    ``results/bench_serve.json``-style output on stdout."""
    import json

    import numpy as np

    from repro.launch.engine import (LMRunner, ServeEngine, run_offered_load,
                                     warm_engine)
    from repro.launch.mesh import make_serve_mesh

    mesh = make_serve_mesh()
    prompts = [np.random.RandomState(i)
               .randint(0, cfg.vocab, size=(args.prompt_len,))
               .astype(np.int32) for i in range(args.requests)]

    def mk(max_batch):
        return ServeEngine(
            LMRunner(params, cfg, new_tokens=args.new_tokens, qmode=qmode,
                     model_plan=model_plan),
            max_batch=max_batch, flush_deadline_s=args.flush_deadline_ms / 1e3,
            mesh=mesh)

    seq = run_offered_load(warm_engine(mk(1), prompts), prompts, None)
    eng = warm_engine(mk(args.batch), prompts)
    bat = run_offered_load(eng, prompts, None)
    n_dev = 1 if mesh is None else mesh.devices.size
    print(f"arch={cfg.name} devices={n_dev} requests={args.requests} "
          f"prompt_len={args.prompt_len} new_tokens={args.new_tokens}")
    print(f"sequential: {seq['achieved_rps']:.1f} req/s "
          f"p50={seq['p50_ms']}ms p99={seq['p99_ms']}ms")
    print(f"batch={args.batch}: {bat['achieved_rps']:.1f} req/s "
          f"p50={bat['p50_ms']}ms p99={bat['p99_ms']}ms "
          f"({bat['achieved_rps'] / max(seq['achieved_rps'], 1e-9):.2f}x)")
    for mult in (0.5, 1.0, 2.0, 4.0):
        row = run_offered_load(eng, prompts,
                               rate_rps=mult * seq["achieved_rps"])
        print(f"offered {row['offered_rps']:>8} req/s: {json.dumps(row)}")


def run_continuous(params, cfg, qmode: str, args, model_plan=None) -> None:
    """Continuous-batching mode (``--continuous``): drive the paged-KV
    step-granular engine with a mixed prompt/horizon request set and
    report req/s + the queue/service latency split against the bucket
    engine at the same capacity.  The benchmark-grade sweep lives in
    ``benchmarks/bench_serve.py --continuous``."""
    import json

    import numpy as np

    from repro.launch.engine import (ContinuousLMEngine, LMRunner,
                                     ServeEngine, run_offered_load,
                                     warm_engine)

    rng = np.random.RandomState(0)
    gens = (max(args.new_tokens // 2, 1), args.new_tokens,
            args.new_tokens * 2)
    payloads = [
        (rng.randint(0, cfg.vocab,
                     size=(int(rng.choice((args.prompt_len // 2 or 1,
                                           args.prompt_len),)),))
         .astype(np.int32), int(rng.choice(gens)))
        for _ in range(args.requests)]

    bucket = ServeEngine(
        LMRunner(params, cfg, new_tokens=args.new_tokens, qmode=qmode,
                 model_plan=model_plan),
        max_batch=args.batch,
        flush_deadline_s=args.flush_deadline_ms / 1e3)
    cont = ContinuousLMEngine(
        params, cfg, num_slots=args.slots, page_size=args.page_size,
        num_pages=args.pages, new_tokens=args.new_tokens,
        max_seq=args.prompt_len + 2 * args.new_tokens,
        qmode=qmode, model_plan=model_plan)
    rb = run_offered_load(warm_engine(bucket, payloads), payloads, None)
    rc = run_offered_load(warm_engine(cont, payloads), payloads, None)
    print(f"arch={cfg.name} requests={args.requests} mixed prompts/horizons "
          f"slots={args.slots} pages={args.pages}x{args.page_size}")
    print(f"bucket    : {json.dumps(rb)}")
    print(f"continuous: {json.dumps(rc)} "
          f"({rc['achieved_rps'] / max(rb['achieved_rps'], 1e-9):.2f}x)")
    print(f"programs={sorted(cont.program_shapes)} "
          f"pool={cont.pool.stats()}")


def run_chaos(params, cfg, qmode: str, args, model_plan=None) -> None:
    """Fault-injected serving mode (``--chaos-mtbf``): drive the resilient
    engine (``repro.resilience``) under a seeded exponential fault schedule
    with K-step decode epoch checkpoints, then verify every completed
    request against a fault-free run of the same engine configuration and
    print the recovery statistics.  The benchmark-grade sweep lives in
    ``benchmarks/bench_resilience.py``; this is the operational entry."""
    import tempfile

    import numpy as np

    from repro.resilience import (EpochLMRunner, FaultPlan,
                                  ResilientServeEngine)

    prompts = [np.random.RandomState(i)
               .randint(0, cfg.vocab, size=(args.prompt_len,))
               .astype(np.int32) for i in range(args.requests)]

    def mk(ckdir):
        runner = EpochLMRunner(params, cfg, new_tokens=args.new_tokens,
                               epoch_steps=args.epoch_steps, qmode=qmode,
                               model_plan=model_plan)
        return ResilientServeEngine(runner, checkpoint_dir=ckdir,
                                    max_batch=args.batch,
                                    flush_deadline_s=args.flush_deadline_ms
                                    / 1e3, max_retries=1000)

    ckroot = args.checkpoint_dir or tempfile.mkdtemp(prefix="chaos_ckpt_")
    ref = [r.value for r in mk(None).serve(list(prompts))]
    eng = mk(ckroot)
    eng.faults = FaultPlan(args.chaos_mtbf, seed=args.chaos_seed)
    t0 = time.perf_counter()
    res = eng.serve(list(prompts))
    wall = time.perf_counter() - t0
    identical = len(res) == len(ref) and all(
        np.array_equal(r.value, v) for r, v in zip(res, ref))
    s = eng.stats
    print(f"arch={cfg.name} chaos: mtbf={args.chaos_mtbf} steps "
          f"(seed {args.chaos_seed}), K={args.epoch_steps}, "
          f"requests={len(prompts)}")
    print(f"completed {len(res)}/{len(prompts)} in {wall:.2f}s, "
          f"bit-identical to fault-free: {identical}")
    print(f"faults={s['faults']} (power={s['power_losses']} "
          f"drop={s['device_drops']} slow={s['slow_dispatches']} "
          f"staging={s['staging_retries']}) retries={s['retries']} "
          f"dead={s['dead_lettered']}")
    print(f"prefills={s['prefills']} resumes={s['resumes']} "
          f"epochs={s['epochs']} commits={s['commits']} "
          f"executed_steps={s['executed_steps']} "
          f"useful_steps={s['useful_steps']} "
          f"wasted_steps={s['wasted_steps']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    # BooleanOptionalAction so --no-smoke can actually disable it
    # (store_true with default=True made the flag impossible to turn off)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default=None, choices=list(PAPER_CONFIGS))
    ap.add_argument("--prequant", action="store_true",
                    help="quantize projection weights to int8 levels once at "
                         "model load (deprecated: --plan-cache subsumes this "
                         "and also pins engines + persists to disk)")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="compile-once execution plan (repro.core.plan): if "
                         "PATH.json exists, reload it — a restarted node "
                         "skips requantization and autotuning entirely (the "
                         "intermittency-resume fast path); otherwise compile "
                         "the plan (prequant + engine resolution) and save "
                         "it there")
    ap.add_argument("--autotune", action="store_true",
                    help="with --plan-cache: MEASURE candidate engines per "
                         "GEMM shape on the live backend instead of trusting "
                         "the heuristic cost model")
    ap.add_argument("--throughput", action="store_true",
                    help="request-level offered-load mode: queue+bucket many "
                         "independent requests through launch/engine.py "
                         "(data-parallel across devices) instead of one "
                         "batched call")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode: step-granular admission "
                         "into a persistent decode batch over a paged KV "
                         "cache (launch/engine.ContinuousLMEngine), compared "
                         "against the bucket engine on a mixed-length mix")
    ap.add_argument("--slots", type=int, default=4,
                    help="--continuous: persistent decode batch width")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--continuous: tokens per KV page")
    ap.add_argument("--pages", type=int, default=64,
                    help="--continuous: KV page pool size")
    ap.add_argument("--requests", type=int, default=32,
                    help="--throughput: number of independent requests")
    ap.add_argument("--flush-deadline-ms", type=float, default=2.0,
                    help="--throughput: max bucket queueing delay")
    ap.add_argument("--chaos-mtbf", type=float, default=None, metavar="STEPS",
                    help="fault-injected serving: mean decode steps between "
                         "faults (exponential schedule, repro.resilience); "
                         "runs the resilient engine and verifies outputs "
                         "against a fault-free run")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="--chaos-mtbf: fault schedule seed")
    ap.add_argument("--epoch-steps", type=int, default=4,
                    help="--chaos-mtbf: decode checkpoint period K (the "
                         "paper's NV write period P, in decode steps)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="--chaos-mtbf: decode epoch checkpoint directory "
                         "(default: a fresh temp dir)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=PAPER_CONFIGS[args.quant])
    qmode = "serve" if args.quant and args.quant != "w32a32" else "train"

    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    model_plan = None
    if args.plan_cache and qmode == "serve":
        # the Session facade (repro.api): compile-or-reload the ModelPlan.
        # A cached plan compiled under a different quant/arch is refused
        # (wrong bit widths would silently decode the stored integer
        # levels into garbage rather than erroring on shapes).
        from repro import api

        compiled = api.build(cfg, params=params).compile(
            batch_hints=(args.batch,), prompt_len=args.prompt_len,
            autotune=args.autotune, cache=args.plan_cache)
        model_plan = compiled.plan
        if compiled.reloaded:
            print(f"plan: reloaded {args.plan_cache} in "
                  f"{compiled.compile_s * 1e3:.1f}ms (requantization "
                  f"+ autotune skipped)")
        else:
            print(f"plan: compiled{' +autotune' if args.autotune else ''} in "
                  f"{compiled.compile_s * 1e3:.1f}ms -> {compiled.cache_path}")
        params = model_plan.params
        model_plan.install()  # dense GEMM dispatch becomes a table lookup
    elif args.prequant and qmode == "serve":
        from repro.models.layers import prequantize_params
        params = prequantize_params(params, cfg)
    if args.chaos_mtbf is not None:
        run_chaos(params, cfg, qmode, args, model_plan=model_plan)
        return
    if args.continuous:
        run_continuous(params, cfg, qmode, args, model_plan=model_plan)
        return
    if args.throughput:
        run_throughput(params, cfg, qmode, args, model_plan=model_plan)
        return
    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    prefill_fn = make_prefill(params, cfg, SINGLE, qmode)
    generate_fn = make_generate(params, cfg, SINGLE, qmode, S_p, S_d)
    gen, dt_cold = serve_once(params, cfg, SINGLE, prompts, S_d, qmode,
                              prefill_fn, generate_fn)
    _, dt_warm = serve_once(params, cfg, SINGLE, prompts, S_d, qmode,
                            prefill_fn, generate_fn)
    print(f"arch={cfg.name} quant={args.quant or 'fp'} engine={qmode}"
          f"{' prequant' if args.prequant and qmode == 'serve' else ''}")
    print(f"generated {B}x{S_d} tokens: cold {dt_cold:.2f}s "
          f"({B * S_d / dt_cold:.1f} tok/s incl. compile), "
          f"warm {dt_warm * 1e3:.1f}ms ({B * S_d / dt_warm:.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  sample[{b}]: {list(map(int, gen[b][:12]))}")


if __name__ == "__main__":
    main()
