"""Production training driver.

On-cluster (TPU) it builds the production mesh and shards per DESIGN.md §6;
in this CPU container use --smoke for a reduced config:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --quant w1a8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config, make_plan
from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_shape_dict
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quant", default=None, choices=list(PAPER_CONFIGS))
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=PAPER_CONFIGS[args.quant])

    if len(jax.devices()) > 1:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        plan = make_plan(mesh_shape_dict(mesh))
    else:
        mesh = make_host_mesh()
        plan = SINGLE

    tr = Trainer(cfg, plan, mesh,
                 OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
                 TrainConfig(steps=args.steps, log_every=10, ckpt_every=50,
                             compress_grads=args.compress_grads),
                 ckpt_dir=args.ckpt_dir)
    if args.ckpt_dir and tr.restore():
        print(f"resumed from step {tr.step}")

    vocab = cfg.vocab

    def bf(s, m):
        b = lm_batch(s, m, batch=args.batch, seq=args.seq, vocab=vocab, seed=0)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.frame_input:
            out = dict(frame_feats=jax.random.normal(
                jax.random.PRNGKey(s), (args.batch, args.seq, cfg.frame_dim)),
                labels=out["labels"])
        if cfg.n_patches:
            out["patch_embeds"] = jax.random.normal(
                jax.random.PRNGKey(s), (args.batch, cfg.n_patches, cfg.vit_dim))
        return out

    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        tr.run(bf)


if __name__ == "__main__":
    main()
