"""Request-level serving engine: queue -> padding buckets -> device dispatch.

PRs 1-2 made a *single* request fast (fused qGEMM, implicit-GEMM conv,
scanned decode); this engine turns that fast single-shot path into a loaded
multi-request, multi-device system (DESIGN.md §7):

  * **Request queue + padding-bucket batcher** — independent requests are
    grouped by shape key (prompt length for LMs, image shape for CNNs) and
    coalesced into one device dispatch.  A bucket flushes when it reaches
    ``max_batch`` or when its oldest request has waited ``flush_deadline_s``
    (latency bound under light load).  Ragged flushes pad the batch up to
    the next power of two (and to a device-count multiple), so the jit
    cache holds at most log2(max_batch)+1 programs per shape key.
  * **Double-buffered host->device staging** — while bucket *i* computes,
    bucket *i+1*'s arrays transfer and bucket *i-1*'s results harvest; at
    most two buckets are in flight on device (bounded memory; the rest of
    the backpressure story is ``max_pending`` on the queue, see
    :meth:`ServeEngine.submit`).
  * **Data-parallel execution** — with more than one device, the batched
    forward runs under ``shard_map`` over the mesh's ``data`` axis
    (:func:`repro.distributed.sharding.data_parallel`): params replicated,
    request axis sharded.  This is the datacenter analogue of the paper's
    §II-A sub-array parallelism — independent kernel windows mapped onto
    parallel SOT-MRAM sub-arrays become independent requests mapped onto
    parallel devices.  With one device the engine falls back to plain
    ``jit`` (no collective machinery).

Correctness contract: batching is invisible.  The serve forwards are
per-sample independent (per-sample norm statistics, per-request KV cache
rows), so a request's result is bit-identical whether it ran alone, in a
full bucket, in a ragged padded bucket, or sharded across devices —
``tests/test_engine.py`` pins this across engines and bucket shapes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class QueueFull(RuntimeError):
    """Backpressure signal: the queue holds ``max_pending`` requests.

    Callers shed load or retry after draining — the engine never grows its
    buffers unboundedly under overload.
    """


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    payload: Any
    t_submit: float


@dataclasses.dataclass(frozen=True)
class Result:
    rid: int
    value: np.ndarray
    t_submit: float
    t_done: float
    batch: int    # real co-batched requests in the dispatch
    padded: int   # dispatched batch after padding
    t_start: float = 0.0  # when the engine began computing this request

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float:
        """Submit -> first compute (bucket dispatch / slot admission)."""
        return max(self.t_start - self.t_submit, 0.0)

    @property
    def service_s(self) -> float:
        """First compute -> harvest (the request's time on device)."""
        return self.t_done - self.t_start


@dataclasses.dataclass
class Bucket:
    key: Any
    requests: list


class BucketBatcher:
    """Pure-python bucketing queue (no jax): group by shape key, flush on
    ``max_batch`` or deadline.  Separately unit-testable."""

    def __init__(self, max_batch: int = 8, flush_deadline_s: float = 0.005):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.flush_deadline_s = flush_deadline_s
        self._open: dict[Any, list] = {}
        self._opened_at: dict[Any, float] = {}

    def pending(self) -> int:
        return sum(len(v) for v in self._open.values())

    def add(self, req: Request, key: Any, now: float) -> Optional[Bucket]:
        """Queue one request; returns the bucket if this filled it."""
        q = self._open.setdefault(key, [])
        if not q:
            self._opened_at[key] = now
        q.append(req)
        if len(q) >= self.max_batch:
            return self._close(key)
        return None

    def take_expired(self, now: float) -> list[Bucket]:
        """Buckets whose oldest request has waited past the deadline."""
        keys = [k for k, t in self._opened_at.items()
                if now - t >= self.flush_deadline_s and self._open.get(k)]
        return [self._close(k) for k in keys]

    def take_all(self) -> list[Bucket]:
        return [self._close(k) for k in list(self._open) if self._open[k]]

    def _close(self, key: Any) -> Bucket:
        reqs = self._open.pop(key)
        self._opened_at.pop(key, None)
        return Bucket(key, reqs)


# ---------------------------------------------------------------------------
# Model runners: how one bucket becomes one batched device program
# ---------------------------------------------------------------------------

def _collate(payloads, pad_to: int, dtype) -> np.ndarray:
    """Stack payloads into a (pad_to, ...) batch.  Padded rows are copies
    of row 0: real data keeps every lane's numerics in-range, and the
    engine slices padding off before results surface."""
    x = np.stack([np.asarray(p, dtype) for p in payloads])
    if pad_to > len(payloads):
        x = np.concatenate(
            [x, np.broadcast_to(x[:1], (pad_to - len(payloads),) + x.shape[1:])])
    return x


def _split_rows(host_out: np.ndarray, n: int) -> list[np.ndarray]:
    return [host_out[i] for i in range(n)]


class CNNRunner:
    """Batched CNN serve forward (image (H, W, C) -> logits row).

    Preferred construction is from a compiled plan
    (:func:`repro.core.plan.compile_model`): ``CNNRunner(None, spec, None,
    plan=plan)`` — params and quant come from the plan, every layer's
    engine is pinned ahead of dispatch, and the engine's program cache is
    keyed on the plan fingerprint.  The legacy form (explicit
    params/quant, per-trace structural planning) still works; float
    checkpoints prequantize at trace time.
    """

    def __init__(self, params, spec, quant, plan=None):
        self.plan = plan
        self.params = plan.params if plan is not None else params
        self.spec = spec
        self.quant = plan.quant if plan is not None else quant

    def plan_fingerprint(self):
        return None if self.plan is None else self.plan.fingerprint()

    def shape_key(self, payload) -> tuple:
        return ("cnn",) + tuple(payload.shape)

    def collate(self, payloads, pad_to: int) -> np.ndarray:
        return _collate(payloads, pad_to, np.float32)

    def make_forward(self, key) -> Callable:
        spec, quant, plan = self.spec, self.quant, self.plan

        if plan is not None:
            from repro.core.plan import plan_forward

            def fwd(params, x):
                # params arrive as jit arguments (device-put replicas);
                # the plan supplies structure + engines only
                return plan_forward(plan, x, params=params)

            return fwd
        from repro.models.cnn import cnn_forward

        def fwd(params, x):
            return cnn_forward(params, x, spec, quant, "serve")

        return fwd

    split = staticmethod(_split_rows)


class LMRunner:
    """Batched LM generate (tokens (S_p,) -> generated tokens (S_d,)).

    One device program per (prompt-len, horizon) bucket shape: jitted
    prefill + cache growth + the one-trace ``lax.scan`` greedy decode of
    ``launch/serve.py``, fused into a single dispatch per bucket.

    Payloads are either a plain token array (horizon = the runner-level
    ``new_tokens`` default) or a ``(tokens, new_tokens)`` tuple for
    per-request horizons — mixed horizons land in distinct buckets (the
    shape key includes the horizon), which is exactly the fragmentation
    the continuous engine exists to remove.
    """

    def __init__(self, params, cfg, *, new_tokens: int, qmode: str = "serve",
                 plan=None, model_plan=None):
        from repro.configs import SINGLE

        self.model_plan = model_plan  # compiled ModelPlan (core/plan.py)
        self.params = model_plan.params if model_plan is not None else params
        self.cfg = cfg
        self.new_tokens = new_tokens
        self.qmode = qmode
        self.plan = plan or SINGLE    # sharding plan (configs.SINGLE-style)

    def plan_fingerprint(self):
        return (None if self.model_plan is None
                else self.model_plan.fingerprint())

    @staticmethod
    def split_payload(payload) -> tuple:
        """Normalize a payload to ``(tokens, new_tokens | None)``."""
        if isinstance(payload, tuple):
            toks, nt = payload
            return np.asarray(toks, np.int32), int(nt)
        return np.asarray(payload, np.int32), None

    def shape_key(self, payload) -> tuple:
        toks, nt = self.split_payload(payload)
        return ("lm", int(toks.shape[-1]),
                self.new_tokens if nt is None else nt)

    def collate(self, payloads, pad_to: int) -> np.ndarray:
        return _collate([self.split_payload(p)[0] for p in payloads],
                        pad_to, np.int32)

    def make_forward(self, key) -> Callable:
        import contextlib

        from repro.launch.serve import (greedy_token, grow_cache,
                                        make_decode_step)
        from repro.models import transformer as T

        _, prompt_len, new_tokens = key
        cfg, plan, qmode = self.cfg, self.plan, self.qmode
        model_plan = self.model_plan
        slots = prompt_len + new_tokens

        def fwd(params, toks):
            # activate() covers jit TRACE time: projection GEMMs dispatch
            # through the plan's dense verdict table; the compiled program
            # keeps those engines for its lifetime
            ctx = (model_plan.activate() if model_plan is not None
                   else contextlib.nullcontext())
            with ctx:
                logits, cache = T.prefill(params, cfg, plan, tokens=toks,
                                          qmode=qmode)
                cache = grow_cache(cache, prompt_len, slots)
                first = greedy_token(logits, cfg.vocab)
                step = make_decode_step(params, cfg, plan, qmode)
                (_, _, _), toks_out = jax.lax.scan(
                    step, (cache, first, jnp.asarray(prompt_len, jnp.int32)),
                    None, length=new_tokens - 1)
                return jnp.concatenate([first, toks_out[:, :, 0].T], axis=1)

        return fwd

    split = staticmethod(_split_rows)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _seeded_rng(retry_rng) -> np.random.RandomState:
    """Normalize the injectable backoff RNG: None -> seed 0, int -> that
    seed, a RandomState -> used as-is.  Injection makes retry jitter a
    pure function of the seed — load tests replay identical backoff
    schedules instead of depending on global RNG state."""
    if isinstance(retry_rng, np.random.RandomState):
        return retry_rng
    return np.random.RandomState(0 if retry_rng is None else retry_rng)


class _SubmitRetryMixin:
    """Shared bounded-backoff admission (requires ``submit``/``pump`` and a
    ``self._rng`` seeded RandomState)."""

    def submit_retry(self, payload, t_submit: float | None = None, *,
                     attempts: int = 6, base_s: float = 1e-3,
                     max_s: float = 0.25,
                     sleep: Callable[[float], None] = time.sleep) -> int:
        """:meth:`submit` with bounded exponential backoff on QueueFull.

        Every open-loop caller used to hand-roll the shed/retry dance;
        this is the one blessed version: pump (dispatching is the only
        thing that relieves backpressure), sleep a jittered exponentially
        growing delay (capped at ``max_s``), retry — and re-raise
        QueueFull after ``attempts`` tries so overload still surfaces
        instead of blocking forever.  ``t_submit`` keeps the coordinated-
        omission contract: the request is charged from its true arrival
        time however long admission took.
        """
        for a in range(attempts):
            try:
                return self.submit(payload, t_submit=t_submit)
            except QueueFull:
                if a == attempts - 1:
                    raise
                self.pump()
                delay = min(base_s * (1 << a), max_s)
                sleep(delay * (0.5 + self._rng.uniform()))  # jitter [0.5,1.5)
        raise AssertionError("unreachable")


class ServeEngine(_SubmitRetryMixin):
    """Coalesce independent requests into batched, sharded device dispatches.

    Parameters
    ----------
    runner:           a :class:`CNNRunner`/:class:`LMRunner`-shaped adapter.
    max_batch:        bucket capacity = the largest dispatched batch.
    flush_deadline_s: max queueing delay before a partial bucket flushes.
    mesh:             1-D ``("data",)`` mesh (``launch/mesh.make_serve_mesh``)
                      or None for the single-device ``jit`` fallback.
    max_pending:      queue bound; :meth:`submit` raises :class:`QueueFull`
                      beyond it (backpressure, DESIGN.md §7).
    retry_rng:        seed (int) or ``np.random.RandomState`` for
                      :meth:`submit_retry` backoff jitter; None seeds 0.
    """

    def __init__(self, runner, *, max_batch: int = 8,
                 flush_deadline_s: float = 0.005, mesh=None,
                 max_pending: int = 4096, retry_rng=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.runner = runner
        self.mesh = mesh
        self.clock = clock
        self.max_pending = max_pending
        self.batcher = BucketBatcher(max_batch, flush_deadline_s)
        self._ready: deque[Bucket] = deque()
        self._results: dict[int, Result] = {}
        self._fns: dict = {}
        self._rng = _seeded_rng(retry_rng)    # submit_retry backoff jitter
        self._next_rid = 0
        self._n_data = 1 if mesh is None else int(np.prod(mesh.devices.shape))
        if mesh is not None:
            from repro.distributed.sharding import replicated
            self._params = jax.device_put(runner.params, replicated(mesh))
        else:
            self._params = jax.device_put(runner.params)
        self.stats = dict(dispatches=0, requests=0, padded_rows=0)

    # -- queue side ---------------------------------------------------------

    def _queued(self) -> int:
        """Requests waiting anywhere ahead of dispatch (open partial
        buckets + closed-but-undispatched buckets), in REQUESTS — the unit
        ``max_pending`` bounds."""
        return (self.batcher.pending()
                + sum(len(b.requests) for b in self._ready))

    def submit(self, payload, t_submit: float | None = None) -> int:
        """Enqueue one request; returns its rid.  Raises QueueFull when
        ``max_pending`` requests are already waiting (shed or retry).

        ``t_submit`` backdates the request's latency clock to its true
        arrival time (offered-load drivers running behind schedule must
        charge the client-side backlog wait to the request — coordinated
        omission otherwise hides exactly the latency overload creates).
        Flush-deadline bookkeeping always uses the actual clock.
        """
        if self._queued() >= self.max_pending:
            raise QueueFull(f"{self.max_pending} requests pending")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        bucket = self.batcher.add(
            Request(rid, payload, now if t_submit is None else t_submit),
            self.runner.shape_key(payload), now)
        if bucket is not None:
            self._ready.append(bucket)
        return rid

    def pump(self) -> None:
        """Dispatch full buckets plus any whose flush deadline expired."""
        self._ready.extend(self.batcher.take_expired(self.clock()))
        if self._ready:
            self._execute(list(self._ready))
            self._ready.clear()

    def _flush_all(self) -> None:
        """Dispatch EVERYTHING queued, partial buckets included — the only
        operation guaranteed to relieve backpressure (pump() can't help
        when the pressure is all in young partial buckets)."""
        self._ready.extend(self.batcher.take_all())
        if self._ready:
            self._execute(list(self._ready))
            self._ready.clear()

    def drain(self) -> list[Result]:
        """Flush everything (including partial buckets), run to idle, and
        return all accumulated results ordered by rid."""
        self._flush_all()
        out = [self._results[rid] for rid in sorted(self._results)]
        self._results.clear()
        return out

    def serve(self, payloads) -> list[Result]:
        """Closed-loop convenience: submit all, drain, results in order.

        Buckets accumulate and dispatch together in ``drain()`` so the
        double-buffered pipeline overlaps them (per-submit pumping would
        serialize stage->compute->harvest per bucket).  A full queue is
        flushed in place (partial buckets dispatch early) rather than
        surfacing QueueFull — closed loop means the caller IS the
        backpressure."""
        for p in payloads:
            try:
                self.submit(p)
            except QueueFull:
                self._flush_all()
                self.submit(p)
        return self.drain()

    # -- device side --------------------------------------------------------

    def _pad_to(self, n: int) -> int:
        # cap at max_batch itself (a full bucket never pads above its own
        # capacity); a non-pow2 cap still bounds the jit cache at
        # log2(max_batch)+1 programs per shape key.  The device-multiple
        # round-up may exceed max_batch when devices > max_batch — sharding
        # needs every device populated.
        padded = min(_pow2_ceil(n), self.batcher.max_batch)
        if self._n_data > 1:
            padded = -(-padded // self._n_data) * self._n_data
        return padded

    def _executable(self, key, padded: int):
        # program cache keyed on (shape key, padded batch, PLAN): two plans
        # over the same shapes (e.g. heuristic vs autotuned engines) must
        # never share a compiled program
        plan_fp = getattr(self.runner, "plan_fingerprint", lambda: None)()
        cache_key = (key, padded, plan_fp)
        if cache_key not in self._fns:
            fwd = self.runner.make_forward(key)
            # _pad_to guarantees device-divisible batches in mesh mode
            if self.mesh is not None:
                from repro.distributed.sharding import data_parallel
                fn = jax.jit(data_parallel(fwd, self.mesh))
            else:
                fn = jax.jit(fwd)
            self._fns[cache_key] = fn
        return self._fns[cache_key]

    def _stage(self, bucket: Bucket):
        """Start the host->device transfer for one bucket (async)."""
        padded = self._pad_to(len(bucket.requests))
        batch = self.runner.collate([r.payload for r in bucket.requests],
                                    padded)
        if self.mesh is not None:
            from repro.distributed.sharding import batch_sharding
            dev = jax.device_put(batch, batch_sharding(self.mesh))
        else:
            dev = jax.device_put(batch)
        return bucket, padded, dev

    def _execute(self, buckets: list[Bucket]) -> None:
        """Pipelined bucket loop: dispatch bucket i, then stage bucket i+1
        (H2D overlaps i's compute), then harvest bucket i-1 (its compute
        overlapped with i's dispatch).  At most two buckets in flight."""
        staged = self._stage(buckets[0]) if buckets else None
        inflight = None
        for i in range(len(buckets)):
            bucket, padded, dev = staged
            t_start = self.clock()
            out = self._executable(bucket.key, padded)(self._params, dev)
            staged = self._stage(buckets[i + 1]) if i + 1 < len(buckets) else None
            if inflight is not None:
                self._harvest(*inflight)
            inflight = (bucket, padded, out, t_start)
        if inflight is not None:
            self._harvest(*inflight)

    def _harvest(self, bucket: Bucket, padded: int, out,
                 t_start: float) -> None:
        host = np.asarray(out)  # blocks until this bucket's compute is done
        n = len(bucket.requests)
        t_done = self.clock()
        for req, val in zip(bucket.requests, self.runner.split(host, n)):
            self._results[req.rid] = Result(req.rid, val, req.t_submit,
                                            t_done, n, padded,
                                            t_start=t_start)
        self.stats["dispatches"] += 1
        self.stats["requests"] += n
        self.stats["padded_rows"] += padded - n


# ---------------------------------------------------------------------------
# Continuous batching over a paged KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    """A submitted request waiting for a slot + pages."""
    rid: int
    tokens: np.ndarray
    new_tokens: int
    t_submit: float


@dataclasses.dataclass
class _Slot:
    """One in-flight request occupying a decode slot."""
    rid: int
    t_submit: float
    t_start: float
    tokens: np.ndarray      # prompt tokens (S_p,)
    new_tokens: int
    pages: list             # page indices owned by this request
    pos: int                # next KV position to write (tokens inserted)
    emitted: list           # generated tokens so far (first from prefill)
    last_tok: int           # last generated token (next decode input)


class ContinuousLMEngine(_SubmitRetryMixin):
    """Step-granular continuous batching over a paged KV cache.

    The bucket engine (:class:`ServeEngine` + :class:`LMRunner`) closes a
    batch at dispatch: every co-batched request shares one (prompt-len,
    horizon) shape, runs its full scan, and the batch retires together —
    mixed lengths fragment into many small dispatches and short requests
    wait on long ones (head-of-line blocking).  This engine keeps ONE
    persistent in-flight batch of ``num_slots`` decode slots instead:

    * **Admission at step granularity** — a waiting request joins any free
      slot between decode steps.  Its KV pages (the full extent,
      ``pages_needed(prompt + horizon)``) are reserved up front from a
      :class:`~repro.core.kv_pages.PagePool`, so an admitted request can
      always run to completion — no mid-flight eviction, no deadlock.
      Admission is strictly FIFO (no skip-ahead past a too-big head): the
      schedule stays a pure function of the submit order, which is what
      the bit-identity and resume tests replay.
    * **Chunked prefill insert** — the prompt streams into its pages in
      fixed ``chunk``-token pieces at batch 1 (table sliced to the
      admitting slot).  No bucket re-open, no contiguous re-padding:
      ``launch/serve.grow_cache`` (ne ``widen_cache``) has no role here.
    * **Mid-flight retirement** — a slot that reaches its horizon retires
      between steps, frees its pages (FIFO reuse), and its slot admits the
      next waiting request.  Requests with different horizons coexist in
      one batch.
    * **Bounded jit cache** — the model runs at exactly two shapes,
      ``(1, chunk)`` prefill insert and ``(num_slots, 1)`` decode, plus
      one page-reset program: three compiled programs total regardless of
      the request mix (``self.program_shapes`` is the test surface).
    * **Backpressure** — ``submit`` raises :class:`QueueFull` past
      ``max_pending`` waiting requests; pool exhaustion defers admission
      (requests queue) rather than failing, so QueueFull is the single
      overload signal.  Oversized requests (``prompt + horizon`` beyond
      ``max_seq`` or the whole pool) are rejected with ``ValueError`` at
      submit — they could never be admitted.
    * **Power-intermittency resilience** — with ``checkpoint_dir`` set,
      the engine commits an epoch checkpoint every ``epoch_steps`` decode
      steps: device page pools plus the entire host schedule (page table,
      allocator free list, slot metadata, waiting queue, finished
      results).  A :class:`~repro.resilience.faults.PowerLoss` /
      ``DeviceDrop`` polled from ``faults`` wipes volatile state and
      resumes from the last commit; determinism of the schedule makes the
      resumed run bit-identical to an uninterrupted one.

    Correctness contract: per-slot numerics are independent of batchmates.
    The constructor forces ``act_scale_mode="row"`` for quantized serve
    configs (per-row activation absmax) and the paged attention kernels
    use per-slot q/k scales over ppos-masked gathers — a request's tokens
    are bit-identical whether it decodes alone or in a full batch, under
    the same chunk schedule.
    """

    def __init__(self, params, cfg, *, num_slots: int = 4,
                 page_size: int = 16, num_pages: int = 64,
                 max_seq: int | None = None, new_tokens: int = 16,
                 chunk: int | None = None, plan=None, model_plan=None,
                 qmode: str = "serve", max_pending: int = 4096,
                 retry_rng=None, deadline_s: float | None = None,
                 checkpoint_dir: str | None = None, epoch_steps: int = 4,
                 faults=None, clock: Callable[[], float] = time.perf_counter):
        from repro.configs import SINGLE
        from repro.core.kv_pages import PagePool, pages_needed
        from repro.models import transformer as T

        if num_slots < 1:
            raise ValueError(f"need at least one slot, got {num_slots}")
        self.model_plan = model_plan
        params = model_plan.params if model_plan is not None else params
        quant = cfg.quant
        if (qmode == "serve" and quant.engine != "fp" and quant.w_bits < 32
                and quant.act_scale_mode != "row"):
            # per-tensor activation absmax couples a row's quantization to
            # its batchmates — continuous batching changes batchmates every
            # step, so per-row scales are a correctness requirement here
            cfg = dataclasses.replace(
                cfg, quant=dataclasses.replace(quant, act_scale_mode="row"))
        self.cfg = cfg
        self.plan = plan or SINGLE
        self.qmode = qmode
        self.clock = clock
        self.num_slots = num_slots
        self.page_size = page_size
        self.new_tokens = new_tokens
        self.chunk = chunk or page_size
        self.max_seq = max_seq or page_size * num_pages
        self.max_pending = max_pending
        self.deadline_s = deadline_s
        self.faults = faults
        self._rng = _seeded_rng(retry_rng)
        self.table_pages = pages_needed(self.max_seq, page_size)
        self.pool = PagePool(num_pages, page_size)
        self._n_layers = len(cfg.blocks_pattern)
        self._params = jax.device_put(params)
        self._plan_fp = (None if model_plan is None
                         else model_plan.fingerprint())

        cache = T.init_paged_cache(cfg, self.plan, num_slots, num_pages,
                                   page_size, self.table_pages)
        self._pools = {k: cache["attn"][k] for k in ("pk", "pv", "ppos")}
        self._table = np.full((num_slots, self.table_pages),
                              self.pool.null_page, np.int32)
        self._slots: list = [None] * num_slots
        self._waiting: deque[_Pending] = deque()
        self._results: dict[int, Result] = {}
        self.dead_letters: list[dict] = []
        self._next_rid = 0
        self._step = 0              # decode steps executed (the work clock)
        self.program_shapes: set = set()
        self._run_fn = self._make_run()
        self._reset_fn = jax.jit(
            lambda ppos, pages: ppos.at[:, pages].set(-1, mode="drop"))
        self.stats = dict(dispatches=0, requests=0, padded_rows=0, steps=0,
                          admissions=0, retirements=0, prefill_chunks=0,
                          dead_lettered=0, commits=0, power_losses=0)

        self.epoch_steps = max(int(epoch_steps), 1)
        self._last_commit: int | None = None
        self.ckpt = None
        if checkpoint_dir is not None:
            from repro.train.checkpoint import Checkpointer
            self.ckpt = Checkpointer(checkpoint_dir, keep=2,
                                     async_save=False)
            self._try_restore()  # resume a prior engine's in-flight state

    # -- compiled programs ---------------------------------------------------

    def _make_run(self) -> Callable:
        import contextlib

        from repro.models import transformer as T

        cfg, plan, qmode = self.cfg, self.plan, self.qmode
        model_plan, vocab = self.model_plan, self.cfg.vocab

        def run(params, pools, table, toks, pos, valid):
            ctx = (model_plan.activate() if model_plan is not None
                   else contextlib.nullcontext())
            with ctx:
                cache = {"attn": dict(pools, table=table)}
                logits, new_cache = T.paged_step(params, cache, toks, pos,
                                                 valid, cfg, plan,
                                                 qmode=qmode)
            new_pools = {k: new_cache["attn"][k] for k in ("pk", "pv", "ppos")}
            return logits[:, :, :vocab], new_pools

        return jax.jit(run)

    def _dispatch(self, table_rows: np.ndarray, toks: np.ndarray,
                  pos: np.ndarray, valid: np.ndarray) -> np.ndarray:
        """Run one paged model step; adopts the updated pools.  Returns
        host logits (B, S, vocab)."""
        b = table_rows.shape[0]
        tbl = jnp.broadcast_to(
            jnp.asarray(table_rows, jnp.int32)[None],
            (self._n_layers, b, self.table_pages))
        self.program_shapes.add(("run", b, toks.shape[1]))
        logits, self._pools = self._run_fn(
            self._params, self._pools, tbl,
            jnp.asarray(toks, jnp.int32), jnp.asarray(pos, jnp.int32),
            jnp.asarray(valid, jnp.int32))
        self.stats["dispatches"] += 1
        return np.asarray(logits)

    def _reset_pages(self, pages: list) -> None:
        """Mark freshly-allocated pages never-written (ppos = -1) so stale
        positions from a prior tenant can't unmask its keys.  The page
        list pads to a fixed width with the out-of-bounds drop index, so
        this stays one compiled program."""
        drop = self.pool.num_pages + 1
        padded = np.full((self.table_pages,), drop, np.int32)
        padded[: len(pages)] = pages
        self.program_shapes.add(("reset",))
        self._pools["ppos"] = self._reset_fn(self._pools["ppos"],
                                             jnp.asarray(padded))

    # -- queue side ----------------------------------------------------------

    def _normalize(self, payload) -> tuple:
        toks, nt = LMRunner.split_payload(payload)
        toks = np.atleast_1d(toks).reshape(-1)
        return toks, (self.new_tokens if nt is None else nt)

    def submit(self, payload, t_submit: float | None = None) -> int:
        """Enqueue one request (token array, or ``(tokens, new_tokens)``);
        returns its rid.  Raises QueueFull past ``max_pending`` waiting
        requests, ValueError for requests that could never fit."""
        toks, nt = self._normalize(payload)
        from repro.core.kv_pages import pages_needed
        total = len(toks) + nt
        if total > self.max_seq:
            raise ValueError(f"prompt+horizon = {total} exceeds max_seq "
                             f"= {self.max_seq}")
        if pages_needed(total, self.page_size) > self.pool.num_pages:
            raise ValueError(f"request needs "
                             f"{pages_needed(total, self.page_size)} pages; "
                             f"pool has {self.pool.num_pages}")
        if nt < 1:
            raise ValueError(f"new_tokens must be >= 1, got {nt}")
        if len(toks) < 1:
            raise ValueError("empty prompt")
        if len(self._waiting) >= self.max_pending:
            raise QueueFull(f"{self.max_pending} requests pending")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        self._waiting.append(
            _Pending(rid, toks, nt, now if t_submit is None else t_submit))
        return rid

    # -- scheduler -----------------------------------------------------------

    def _free_slot(self):
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _admit(self) -> None:
        """FIFO admission: fill free slots while the head request's full
        page reservation fits.  A too-big head blocks the line (no
        skip-ahead) — determinism over utilization."""
        from repro.core.kv_pages import PoolExhausted, pages_needed

        while self._waiting:
            slot_i = self._free_slot()
            if slot_i is None:
                return
            req = self._waiting[0]
            need = pages_needed(len(req.tokens) + req.new_tokens,
                                self.page_size)
            try:
                pages = self.pool.alloc(need)
            except PoolExhausted:
                return
            self._waiting.popleft()
            self._reset_pages(pages)
            self._table[slot_i, :] = self.pool.null_page
            self._table[slot_i, : len(pages)] = pages
            s = _Slot(req.rid, req.t_submit, self.clock(), req.tokens,
                      req.new_tokens, pages, 0, [], -1)
            self._slots[slot_i] = s
            self.stats["admissions"] += 1
            self._prefill(slot_i, s)

    def _prefill(self, slot_i: int, s: _Slot) -> None:
        """Stream the prompt into this slot's pages in fixed-size chunks
        (batch 1); the final chunk's logits yield the first token."""
        c, s_p = self.chunk, len(s.tokens)
        table_row = self._table[slot_i: slot_i + 1]
        logits = None
        for c0 in range(0, s_p, c):
            if self.faults is not None:
                ev = self.faults.poll("prefill", dt=1.0)
                if ev is not None:
                    self.faults.raise_for(ev)
            piece = s.tokens[c0: c0 + c]
            buf = np.zeros((1, c), np.int32)
            buf[0, : len(piece)] = piece
            logits = self._dispatch(table_row, buf,
                                    np.asarray([c0], np.int32),
                                    np.asarray([len(piece)], np.int32))
            self.stats["prefill_chunks"] += 1
        s.pos = s_p
        first = int(np.argmax(logits[0, (s_p - 1) % c]))
        s.emitted = [first]
        s.last_tok = first
        if s.new_tokens <= 1:
            self._retire(slot_i)

    def _decode_step(self) -> None:
        """One step of the persistent in-flight batch: every active slot
        inserts its last token and emits the next; finished slots retire
        and free their pages mid-flight."""
        active = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        if self.faults is not None:
            ev = self.faults.poll("decode", dt=1.0)
            if ev is not None:
                self.faults.raise_for(ev)
        toks = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        valid = np.zeros((self.num_slots,), np.int32)
        for i, s in active:
            toks[i, 0] = s.last_tok
            pos[i] = s.pos
            valid[i] = 1
        logits = self._dispatch(self._table, toks, pos, valid)
        self._step += 1
        self.stats["steps"] += 1
        self.stats["padded_rows"] += self.num_slots - len(active)
        for i, s in active:
            nxt = int(np.argmax(logits[i, 0]))
            s.emitted.append(nxt)
            s.last_tok = nxt
            s.pos += 1
            if len(s.emitted) >= s.new_tokens:
                self._retire(i)

    def _retire(self, slot_i: int) -> None:
        s = self._slots[slot_i]
        self._slots[slot_i] = None
        self.pool.free(s.pages)
        self._table[slot_i, :] = self.pool.null_page
        self._results[s.rid] = Result(
            s.rid, np.asarray(s.emitted[: s.new_tokens], np.int32),
            s.t_submit, self.clock(), 1, 1, t_start=s.t_start)
        self.stats["retirements"] += 1
        self.stats["requests"] += 1

    def _reap_deadlines(self) -> None:
        if self.deadline_s is None:
            return
        now = self.clock()
        for i, s in enumerate(self._slots):
            if s is not None and now - s.t_submit > self.deadline_s:
                self._slots[i] = None
                self.pool.free(s.pages)
                self._table[i, :] = self.pool.null_page
                self.dead_letters.append(dict(
                    rid=s.rid, t_submit=s.t_submit,
                    emitted=list(s.emitted), reason="deadline"))
                self.stats["dead_lettered"] += 1

    # -- engine loop ---------------------------------------------------------

    def pump(self) -> None:
        """One scheduler tick: admit into free slots, commit a due epoch
        checkpoint, reap deadline overruns, run one decode step.  A
        kill-class fault wipes volatile state and resumes from the last
        commit."""
        from repro.resilience.faults import DeviceDrop, PowerLoss

        try:
            self._admit()
            self._maybe_commit()
            self._reap_deadlines()
            self._decode_step()
        except (PowerLoss, DeviceDrop):
            self.stats["power_losses"] += 1
            self._reboot()

    def drain(self) -> list[Result]:
        """Run the scheduler to idle; returns accumulated results by rid."""
        while self._waiting or any(s is not None for s in self._slots):
            self.pump()
        out = [self._results[rid] for rid in sorted(self._results)]
        self._results.clear()
        return out

    def serve(self, payloads) -> list[Result]:
        """Closed-loop convenience: submit all, drain, results in order."""
        for p in payloads:
            while True:
                try:
                    self.submit(p)
                    break
                except QueueFull:
                    self.pump()  # closed loop: the caller IS the backpressure
        return self.drain()

    def warm(self) -> "ContinuousLMEngine":
        """Compile all three programs (prefill chunk, decode, page reset)
        with one throwaway request."""
        self.serve([(np.asarray([1], np.int32), 2)])
        return self

    # -- epoch checkpoints ---------------------------------------------------

    def _maybe_commit(self) -> None:
        if self.ckpt is None:
            return
        if (self._last_commit is not None
                and self._step - self._last_commit < self.epoch_steps):
            return
        extra = dict(
            step=self._step, next_rid=self._next_rid,
            plan_fp=str(self._plan_fp), table=self._table.tolist(),
            pool=self.pool.snapshot(),
            slots=[None if s is None else dict(
                rid=s.rid, t_submit=s.t_submit, t_start=s.t_start,
                tokens=[int(t) for t in s.tokens], new_tokens=s.new_tokens,
                pages=[int(p) for p in s.pages], pos=s.pos,
                emitted=list(s.emitted), last_tok=s.last_tok)
                for s in self._slots],
            waiting=[dict(rid=p.rid, tokens=[int(t) for t in p.tokens],
                          new_tokens=p.new_tokens, t_submit=p.t_submit)
                     for p in self._waiting],
            results={str(r.rid): dict(
                value=[int(v) for v in r.value], t_submit=r.t_submit,
                t_done=r.t_done, t_start=r.t_start)
                for r in self._results.values()},
            dead=list(self.dead_letters),
        )
        self.ckpt.save(self._step, self._pools, extra=extra, tag="cbe")
        self._last_commit = self._step
        self.stats["commits"] += 1

    def _try_restore(self) -> bool:
        step = self.ckpt.latest_step(tag="cbe")
        if step is None:
            return False
        extra = self.ckpt.manifest(step, tag="cbe")["extra"]
        if extra.get("plan_fp") != str(self._plan_fp):
            return False  # foreign checkpoint: don't adopt another plan's KV
        _, pools = self.ckpt.restore(self._pools, step=step, tag="cbe")
        self._pools = jax.device_put(pools)
        self._table = np.asarray(extra["table"], np.int32)
        self.pool.restore(extra["pool"])
        self._slots = [
            None if d is None else _Slot(
                d["rid"], d["t_submit"], d["t_start"],
                np.asarray(d["tokens"], np.int32), d["new_tokens"],
                list(d["pages"]), d["pos"], list(d["emitted"]),
                d["last_tok"])
            for d in extra["slots"]]
        self._waiting = deque(
            _Pending(d["rid"], np.asarray(d["tokens"], np.int32),
                     d["new_tokens"], d["t_submit"])
            for d in extra["waiting"])
        self._results = {
            int(rid): Result(int(rid), np.asarray(d["value"], np.int32),
                             d["t_submit"], d["t_done"], 1, 1,
                             t_start=d["t_start"])
            for rid, d in extra["results"].items()}
        self.dead_letters = list(extra["dead"])
        self._step = int(extra["step"])
        self._next_rid = int(extra["next_rid"])
        self._last_commit = self._step
        return True

    def _reboot(self) -> None:
        """Power came back: everything volatile (device pools, host
        schedule) is gone.  Re-init cold, then resume from the last epoch
        commit if there is one — requests admitted or submitted after it
        are lost, exactly like a real brownout."""
        from repro.core.kv_pages import PagePool
        from repro.models import transformer as T

        cache = T.init_paged_cache(self.cfg, self.plan, self.num_slots,
                                   self.pool.num_pages, self.page_size,
                                   self.table_pages)
        self._pools = {k: cache["attn"][k] for k in ("pk", "pv", "ppos")}
        self._table = np.full((self.num_slots, self.table_pages),
                              self.pool.null_page, np.int32)
        self._slots = [None] * self.num_slots
        self._waiting.clear()
        self._results = {}
        self.pool = PagePool(self.pool.num_pages, self.page_size)
        self._step = 0
        self._last_commit = None
        if self.ckpt is not None:
            self._try_restore()


# ---------------------------------------------------------------------------
# Offered-load harness (shared by launch/serve.py --throughput and
# benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")

def warm_engine(engine, payloads):
    """Compile every program the engine can dispatch so measurements see a
    long-lived server's steady state.  Bucket engines: every padded bucket
    size (1, 2, 4, ..., max_batch) per shape key.  Continuous engines run
    at fixed shapes, so one pass over the payload mix compiles everything
    (ragged prompts exercise the same two programs)."""
    if not hasattr(engine, "batcher"):  # ContinuousLMEngine
        engine.serve(list(payloads))
        return engine
    size = 1
    while True:
        engine.serve(payloads[: min(size, len(payloads))])
        if size >= engine.batcher.max_batch:
            return engine
        size = min(size * 2, engine.batcher.max_batch)


def run_offered_load(engine: ServeEngine, payloads, rate_rps: float | None,
                     clock: Callable[[], float] = time.perf_counter) -> dict:
    """Drive the engine at a fixed offered rate (None = closed loop: all
    requests available immediately).  Returns throughput + latency stats;
    per-request latency is measured submit -> harvest (queueing included).
    Engine stats are reset at entry so one warmed engine can serve several
    measurement runs.
    """
    engine.stats.update(dispatches=0, requests=0, padded_rows=0)
    t0 = clock()
    for i, p in enumerate(payloads):
        t_arrive = None
        if rate_rps is not None:
            t_arrive = t0 + i / rate_rps
            while clock() < t_arrive:
                engine.pump()  # flush deadline-expired buckets while idle
                time.sleep(2e-4)
        # when the driver runs behind schedule (over-subscription), the
        # request still ARRIVED at t_arrive: charge the backlog wait to it.
        # submit_retry keeps the sweep honest at rates past saturation:
        # backpressure becomes bounded backoff instead of a crash, and the
        # admission wait lands in the request's latency via t_submit
        engine.submit_retry(p, t_submit=t_arrive)
        engine.pump()
    results = engine.drain()
    wall = clock() - t0
    lats = [r.latency_s for r in results]
    waits = [r.queue_wait_s for r in results]
    svc = [r.service_s for r in results]
    return dict(
        n_requests=len(results),
        offered_rps=(round(rate_rps, 1) if rate_rps is not None else "inf"),
        achieved_rps=round(len(results) / wall, 2),
        p50_ms=round(_percentile(lats, 50) * 1e3, 2),
        p99_ms=round(_percentile(lats, 99) * 1e3, 2),
        # end-to-end latency split: time waiting for a dispatch/slot vs
        # time computing — under overload the queue component explodes
        # while service stays flat, and the split says which engine knob
        # (capacity vs batching) is the bottleneck
        queue_p50_ms=round(_percentile(waits, 50) * 1e3, 2),
        queue_p99_ms=round(_percentile(waits, 99) * 1e3, 2),
        service_p50_ms=round(_percentile(svc, 50) * 1e3, 2),
        service_p99_ms=round(_percentile(svc, 99) * 1e3, 2),
        dispatches=engine.stats["dispatches"],
        mean_batch=round(engine.stats["requests"]
                         / max(engine.stats["dispatches"], 1), 2),
        padded_rows=engine.stats["padded_rows"],
        wall_s=round(wall, 4),
    )
