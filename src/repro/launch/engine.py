"""Request-level serving engine: queue -> padding buckets -> device dispatch.

PRs 1-2 made a *single* request fast (fused qGEMM, implicit-GEMM conv,
scanned decode); this engine turns that fast single-shot path into a loaded
multi-request, multi-device system (DESIGN.md §7):

  * **Request queue + padding-bucket batcher** — independent requests are
    grouped by shape key (prompt length for LMs, image shape for CNNs) and
    coalesced into one device dispatch.  A bucket flushes when it reaches
    ``max_batch`` or when its oldest request has waited ``flush_deadline_s``
    (latency bound under light load).  Ragged flushes pad the batch up to
    the next power of two (and to a device-count multiple), so the jit
    cache holds at most log2(max_batch)+1 programs per shape key.
  * **Double-buffered host->device staging** — while bucket *i* computes,
    bucket *i+1*'s arrays transfer and bucket *i-1*'s results harvest; at
    most two buckets are in flight on device (bounded memory; the rest of
    the backpressure story is ``max_pending`` on the queue, see
    :meth:`ServeEngine.submit`).
  * **Data-parallel execution** — with more than one device, the batched
    forward runs under ``shard_map`` over the mesh's ``data`` axis
    (:func:`repro.distributed.sharding.data_parallel`): params replicated,
    request axis sharded.  This is the datacenter analogue of the paper's
    §II-A sub-array parallelism — independent kernel windows mapped onto
    parallel SOT-MRAM sub-arrays become independent requests mapped onto
    parallel devices.  With one device the engine falls back to plain
    ``jit`` (no collective machinery).

Correctness contract: batching is invisible.  The serve forwards are
per-sample independent (per-sample norm statistics, per-request KV cache
rows), so a request's result is bit-identical whether it ran alone, in a
full bucket, in a ragged padded bucket, or sharded across devices —
``tests/test_engine.py`` pins this across engines and bucket shapes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class QueueFull(RuntimeError):
    """Backpressure signal: the queue holds ``max_pending`` requests.

    Callers shed load or retry after draining — the engine never grows its
    buffers unboundedly under overload.
    """


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    payload: Any
    t_submit: float


@dataclasses.dataclass(frozen=True)
class Result:
    rid: int
    value: np.ndarray
    t_submit: float
    t_done: float
    batch: int    # real co-batched requests in the dispatch
    padded: int   # dispatched batch after padding

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class Bucket:
    key: Any
    requests: list


class BucketBatcher:
    """Pure-python bucketing queue (no jax): group by shape key, flush on
    ``max_batch`` or deadline.  Separately unit-testable."""

    def __init__(self, max_batch: int = 8, flush_deadline_s: float = 0.005):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.flush_deadline_s = flush_deadline_s
        self._open: dict[Any, list] = {}
        self._opened_at: dict[Any, float] = {}

    def pending(self) -> int:
        return sum(len(v) for v in self._open.values())

    def add(self, req: Request, key: Any, now: float) -> Optional[Bucket]:
        """Queue one request; returns the bucket if this filled it."""
        q = self._open.setdefault(key, [])
        if not q:
            self._opened_at[key] = now
        q.append(req)
        if len(q) >= self.max_batch:
            return self._close(key)
        return None

    def take_expired(self, now: float) -> list[Bucket]:
        """Buckets whose oldest request has waited past the deadline."""
        keys = [k for k, t in self._opened_at.items()
                if now - t >= self.flush_deadline_s and self._open.get(k)]
        return [self._close(k) for k in keys]

    def take_all(self) -> list[Bucket]:
        return [self._close(k) for k in list(self._open) if self._open[k]]

    def _close(self, key: Any) -> Bucket:
        reqs = self._open.pop(key)
        self._opened_at.pop(key, None)
        return Bucket(key, reqs)


# ---------------------------------------------------------------------------
# Model runners: how one bucket becomes one batched device program
# ---------------------------------------------------------------------------

def _collate(payloads, pad_to: int, dtype) -> np.ndarray:
    """Stack payloads into a (pad_to, ...) batch.  Padded rows are copies
    of row 0: real data keeps every lane's numerics in-range, and the
    engine slices padding off before results surface."""
    x = np.stack([np.asarray(p, dtype) for p in payloads])
    if pad_to > len(payloads):
        x = np.concatenate(
            [x, np.broadcast_to(x[:1], (pad_to - len(payloads),) + x.shape[1:])])
    return x


def _split_rows(host_out: np.ndarray, n: int) -> list[np.ndarray]:
    return [host_out[i] for i in range(n)]


class CNNRunner:
    """Batched CNN serve forward (image (H, W, C) -> logits row).

    Preferred construction is from a compiled plan
    (:func:`repro.core.plan.compile_model`): ``CNNRunner(None, spec, None,
    plan=plan)`` — params and quant come from the plan, every layer's
    engine is pinned ahead of dispatch, and the engine's program cache is
    keyed on the plan fingerprint.  The legacy form (explicit
    params/quant, per-trace structural planning) still works; float
    checkpoints prequantize at trace time.
    """

    def __init__(self, params, spec, quant, plan=None):
        self.plan = plan
        self.params = plan.params if plan is not None else params
        self.spec = spec
        self.quant = plan.quant if plan is not None else quant

    def plan_fingerprint(self):
        return None if self.plan is None else self.plan.fingerprint()

    def shape_key(self, payload) -> tuple:
        return ("cnn",) + tuple(payload.shape)

    def collate(self, payloads, pad_to: int) -> np.ndarray:
        return _collate(payloads, pad_to, np.float32)

    def make_forward(self, key) -> Callable:
        spec, quant, plan = self.spec, self.quant, self.plan

        if plan is not None:
            from repro.core.plan import plan_forward

            def fwd(params, x):
                # params arrive as jit arguments (device-put replicas);
                # the plan supplies structure + engines only
                return plan_forward(plan, x, params=params)

            return fwd
        from repro.models.cnn import cnn_forward

        def fwd(params, x):
            return cnn_forward(params, x, spec, quant, "serve")

        return fwd

    split = staticmethod(_split_rows)


class LMRunner:
    """Batched LM generate (tokens (S_p,) -> generated tokens (S_d,)).

    One device program per (prompt-len, horizon) bucket shape: jitted
    prefill + cache widening + the one-trace ``lax.scan`` greedy decode of
    ``launch/serve.py``, fused into a single dispatch per bucket.
    """

    def __init__(self, params, cfg, *, new_tokens: int, qmode: str = "serve",
                 plan=None, model_plan=None):
        from repro.configs import SINGLE

        self.model_plan = model_plan  # compiled ModelPlan (core/plan.py)
        self.params = model_plan.params if model_plan is not None else params
        self.cfg = cfg
        self.new_tokens = new_tokens
        self.qmode = qmode
        self.plan = plan or SINGLE    # sharding plan (configs.SINGLE-style)

    def plan_fingerprint(self):
        return (None if self.model_plan is None
                else self.model_plan.fingerprint())

    def shape_key(self, payload) -> tuple:
        return ("lm", int(np.asarray(payload).shape[-1]), self.new_tokens)

    def collate(self, payloads, pad_to: int) -> np.ndarray:
        return _collate(payloads, pad_to, np.int32)

    def make_forward(self, key) -> Callable:
        import contextlib

        from repro.launch.serve import (greedy_token, make_decode_step,
                                        widen_cache)
        from repro.models import transformer as T

        _, prompt_len, new_tokens = key
        cfg, plan, qmode = self.cfg, self.plan, self.qmode
        model_plan = self.model_plan
        slots = prompt_len + new_tokens

        def fwd(params, toks):
            # activate() covers jit TRACE time: projection GEMMs dispatch
            # through the plan's dense verdict table; the compiled program
            # keeps those engines for its lifetime
            ctx = (model_plan.activate() if model_plan is not None
                   else contextlib.nullcontext())
            with ctx:
                logits, cache = T.prefill(params, cfg, plan, tokens=toks,
                                          qmode=qmode)
                cache = widen_cache(cache, prompt_len, slots)
                first = greedy_token(logits, cfg.vocab)
                step = make_decode_step(params, cfg, plan, qmode)
                (_, _, _), toks_out = jax.lax.scan(
                    step, (cache, first, jnp.asarray(prompt_len, jnp.int32)),
                    None, length=new_tokens - 1)
                return jnp.concatenate([first, toks_out[:, :, 0].T], axis=1)

        return fwd

    split = staticmethod(_split_rows)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class ServeEngine:
    """Coalesce independent requests into batched, sharded device dispatches.

    Parameters
    ----------
    runner:           a :class:`CNNRunner`/:class:`LMRunner`-shaped adapter.
    max_batch:        bucket capacity = the largest dispatched batch.
    flush_deadline_s: max queueing delay before a partial bucket flushes.
    mesh:             1-D ``("data",)`` mesh (``launch/mesh.make_serve_mesh``)
                      or None for the single-device ``jit`` fallback.
    max_pending:      queue bound; :meth:`submit` raises :class:`QueueFull`
                      beyond it (backpressure, DESIGN.md §7).
    """

    def __init__(self, runner, *, max_batch: int = 8,
                 flush_deadline_s: float = 0.005, mesh=None,
                 max_pending: int = 4096,
                 clock: Callable[[], float] = time.perf_counter):
        self.runner = runner
        self.mesh = mesh
        self.clock = clock
        self.max_pending = max_pending
        self.batcher = BucketBatcher(max_batch, flush_deadline_s)
        self._ready: deque[Bucket] = deque()
        self._results: dict[int, Result] = {}
        self._fns: dict = {}
        self._rng = np.random.RandomState(0)  # submit_retry backoff jitter
        self._next_rid = 0
        self._n_data = 1 if mesh is None else int(np.prod(mesh.devices.shape))
        if mesh is not None:
            from repro.distributed.sharding import replicated
            self._params = jax.device_put(runner.params, replicated(mesh))
        else:
            self._params = jax.device_put(runner.params)
        self.stats = dict(dispatches=0, requests=0, padded_rows=0)

    # -- queue side ---------------------------------------------------------

    def _queued(self) -> int:
        """Requests waiting anywhere ahead of dispatch (open partial
        buckets + closed-but-undispatched buckets), in REQUESTS — the unit
        ``max_pending`` bounds."""
        return (self.batcher.pending()
                + sum(len(b.requests) for b in self._ready))

    def submit(self, payload, t_submit: float | None = None) -> int:
        """Enqueue one request; returns its rid.  Raises QueueFull when
        ``max_pending`` requests are already waiting (shed or retry).

        ``t_submit`` backdates the request's latency clock to its true
        arrival time (offered-load drivers running behind schedule must
        charge the client-side backlog wait to the request — coordinated
        omission otherwise hides exactly the latency overload creates).
        Flush-deadline bookkeeping always uses the actual clock.
        """
        if self._queued() >= self.max_pending:
            raise QueueFull(f"{self.max_pending} requests pending")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        bucket = self.batcher.add(
            Request(rid, payload, now if t_submit is None else t_submit),
            self.runner.shape_key(payload), now)
        if bucket is not None:
            self._ready.append(bucket)
        return rid

    def submit_retry(self, payload, t_submit: float | None = None, *,
                     attempts: int = 6, base_s: float = 1e-3,
                     max_s: float = 0.25,
                     sleep: Callable[[float], None] = time.sleep) -> int:
        """:meth:`submit` with bounded exponential backoff on QueueFull.

        Every open-loop caller used to hand-roll the shed/retry dance;
        this is the one blessed version: pump (dispatching is the only
        thing that relieves backpressure), sleep a jittered exponentially
        growing delay (capped at ``max_s``), retry — and re-raise
        QueueFull after ``attempts`` tries so overload still surfaces
        instead of blocking forever.  ``t_submit`` keeps the coordinated-
        omission contract: the request is charged from its true arrival
        time however long admission took.
        """
        for a in range(attempts):
            try:
                return self.submit(payload, t_submit=t_submit)
            except QueueFull:
                if a == attempts - 1:
                    raise
                self.pump()
                delay = min(base_s * (1 << a), max_s)
                sleep(delay * (0.5 + self._rng.uniform()))  # jitter [0.5,1.5)
        raise AssertionError("unreachable")

    def pump(self) -> None:
        """Dispatch full buckets plus any whose flush deadline expired."""
        self._ready.extend(self.batcher.take_expired(self.clock()))
        if self._ready:
            self._execute(list(self._ready))
            self._ready.clear()

    def _flush_all(self) -> None:
        """Dispatch EVERYTHING queued, partial buckets included — the only
        operation guaranteed to relieve backpressure (pump() can't help
        when the pressure is all in young partial buckets)."""
        self._ready.extend(self.batcher.take_all())
        if self._ready:
            self._execute(list(self._ready))
            self._ready.clear()

    def drain(self) -> list[Result]:
        """Flush everything (including partial buckets), run to idle, and
        return all accumulated results ordered by rid."""
        self._flush_all()
        out = [self._results[rid] for rid in sorted(self._results)]
        self._results.clear()
        return out

    def serve(self, payloads) -> list[Result]:
        """Closed-loop convenience: submit all, drain, results in order.

        Buckets accumulate and dispatch together in ``drain()`` so the
        double-buffered pipeline overlaps them (per-submit pumping would
        serialize stage->compute->harvest per bucket).  A full queue is
        flushed in place (partial buckets dispatch early) rather than
        surfacing QueueFull — closed loop means the caller IS the
        backpressure."""
        for p in payloads:
            try:
                self.submit(p)
            except QueueFull:
                self._flush_all()
                self.submit(p)
        return self.drain()

    # -- device side --------------------------------------------------------

    def _pad_to(self, n: int) -> int:
        # cap at max_batch itself (a full bucket never pads above its own
        # capacity); a non-pow2 cap still bounds the jit cache at
        # log2(max_batch)+1 programs per shape key.  The device-multiple
        # round-up may exceed max_batch when devices > max_batch — sharding
        # needs every device populated.
        padded = min(_pow2_ceil(n), self.batcher.max_batch)
        if self._n_data > 1:
            padded = -(-padded // self._n_data) * self._n_data
        return padded

    def _executable(self, key, padded: int):
        # program cache keyed on (shape key, padded batch, PLAN): two plans
        # over the same shapes (e.g. heuristic vs autotuned engines) must
        # never share a compiled program
        plan_fp = getattr(self.runner, "plan_fingerprint", lambda: None)()
        cache_key = (key, padded, plan_fp)
        if cache_key not in self._fns:
            fwd = self.runner.make_forward(key)
            # _pad_to guarantees device-divisible batches in mesh mode
            if self.mesh is not None:
                from repro.distributed.sharding import data_parallel
                fn = jax.jit(data_parallel(fwd, self.mesh))
            else:
                fn = jax.jit(fwd)
            self._fns[cache_key] = fn
        return self._fns[cache_key]

    def _stage(self, bucket: Bucket):
        """Start the host->device transfer for one bucket (async)."""
        padded = self._pad_to(len(bucket.requests))
        batch = self.runner.collate([r.payload for r in bucket.requests],
                                    padded)
        if self.mesh is not None:
            from repro.distributed.sharding import batch_sharding
            dev = jax.device_put(batch, batch_sharding(self.mesh))
        else:
            dev = jax.device_put(batch)
        return bucket, padded, dev

    def _execute(self, buckets: list[Bucket]) -> None:
        """Pipelined bucket loop: dispatch bucket i, then stage bucket i+1
        (H2D overlaps i's compute), then harvest bucket i-1 (its compute
        overlapped with i's dispatch).  At most two buckets in flight."""
        staged = self._stage(buckets[0]) if buckets else None
        inflight = None
        for i in range(len(buckets)):
            bucket, padded, dev = staged
            out = self._executable(bucket.key, padded)(self._params, dev)
            staged = self._stage(buckets[i + 1]) if i + 1 < len(buckets) else None
            if inflight is not None:
                self._harvest(*inflight)
            inflight = (bucket, padded, out)
        if inflight is not None:
            self._harvest(*inflight)

    def _harvest(self, bucket: Bucket, padded: int, out) -> None:
        host = np.asarray(out)  # blocks until this bucket's compute is done
        n = len(bucket.requests)
        t_done = self.clock()
        for req, val in zip(bucket.requests, self.runner.split(host, n)):
            self._results[req.rid] = Result(req.rid, val, req.t_submit,
                                            t_done, n, padded)
        self.stats["dispatches"] += 1
        self.stats["requests"] += n
        self.stats["padded_rows"] += padded - n


# ---------------------------------------------------------------------------
# Offered-load harness (shared by launch/serve.py --throughput and
# benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")

def warm_engine(engine: ServeEngine, payloads) -> ServeEngine:
    """Compile every padded bucket size the engine can dispatch (1, 2, 4,
    ..., max_batch) so measurements see a long-lived server's steady state
    — ragged final buckets hit the jit cache, not a cold compile."""
    size = 1
    while True:
        engine.serve(payloads[: min(size, len(payloads))])
        if size >= engine.batcher.max_batch:
            return engine
        size = min(size * 2, engine.batcher.max_batch)


def run_offered_load(engine: ServeEngine, payloads, rate_rps: float | None,
                     clock: Callable[[], float] = time.perf_counter) -> dict:
    """Drive the engine at a fixed offered rate (None = closed loop: all
    requests available immediately).  Returns throughput + latency stats;
    per-request latency is measured submit -> harvest (queueing included).
    Engine stats are reset at entry so one warmed engine can serve several
    measurement runs.
    """
    engine.stats.update(dispatches=0, requests=0, padded_rows=0)
    t0 = clock()
    for i, p in enumerate(payloads):
        t_arrive = None
        if rate_rps is not None:
            t_arrive = t0 + i / rate_rps
            while clock() < t_arrive:
                engine.pump()  # flush deadline-expired buckets while idle
                time.sleep(2e-4)
        # when the driver runs behind schedule (over-subscription), the
        # request still ARRIVED at t_arrive: charge the backlog wait to it.
        # submit_retry keeps the sweep honest at rates past saturation:
        # backpressure becomes bounded backoff instead of a crash, and the
        # admission wait lands in the request's latency via t_submit
        engine.submit_retry(p, t_submit=t_arrive)
        engine.pump()
    results = engine.drain()
    wall = clock() - t0
    lats = [r.latency_s for r in results]
    return dict(
        n_requests=len(results),
        offered_rps=(round(rate_rps, 1) if rate_rps is not None else "inf"),
        achieved_rps=round(len(results) / wall, 2),
        p50_ms=round(_percentile(lats, 50) * 1e3, 2),
        p99_ms=round(_percentile(lats, 99) * 1e3, 2),
        dispatches=engine.stats["dispatches"],
        mean_batch=round(engine.stats["requests"]
                         / max(engine.stats["dispatches"], 1), 2),
        padded_rows=engine.stats["padded_rows"],
        wall_s=round(wall, 4),
    )
