"""Post-compile HLO analysis: collective bytes + roofline terms.

``collective_bytes`` parses the optimized HLO module text: first pass
builds a symbol table of instruction result sizes, second pass sums the
*operand* sizes of every collective op, per the brief's §Roofline recipe.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")


def _shape_bytes(type_str: str) -> int:
    """bytes of 'bf16[256,4096]' or a tuple '(f32[8], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict[str, Any]:
    """Sum operand bytes of every collective in optimized HLO text."""
    sizes: dict[str, int] = {}
    per_kind: dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVES}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))
    opnd_re = re.compile(r"%([\w\.\-]+)")
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        kind = next((k for k in COLLECTIVES if op == k or op.startswith(k + ".")
                     or op.startswith(k + "-start")), None)
        if kind is None:
            continue
        # operands are inside the parens following the op name
        paren = ln[ln.index(op) + len(op):]
        args = paren[paren.find("(") + 1: _match_paren(paren)]
        total = 0
        for a in opnd_re.finditer(args):
            total += sizes.get(a.group(1), 0)
        if total == 0:  # fallback: use the result size
            total = sizes.get(m.group(1), 0)
        per_kind[kind] += total
        counts[kind] += 1
    return dict(bytes_by_kind=per_kind, counts=counts,
                total_bytes=sum(per_kind.values()))


def _match_paren(s: str) -> int:
    depth = 0
    for i, c in enumerate(s):
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s)


# ---------------------------------------------------------------------------
# Roofline (TPU v5e constants, per the brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12     # per chip
PEAK_FLOPS_INT8 = 394e12     # per chip (2x bf16)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip effective)


@dataclasses.dataclass
class Roofline:
    """Three-term roofline from the compiled SPMD program.

    MEASURED SEMANTICS (verified against a controlled sharded matmul):
    XLA ``cost_analysis()`` reports *per-device* true FLOPs (2*M*N*K for a
    dot) and *per-device* bytes for the SPMD program; collective operand
    sizes parsed from the HLO are likewise per-device shard sizes.  The
    brief's formulas divide global quantities by chips — per-device values
    are already divided, so:
        compute_s    = flops_dev / peak      (== HLO_FLOPs_global / (chips*peak))
        memory_s     = bytes_dev / hbm_bw
        collective_s = coll_bytes_dev / ici_bw
    MODEL_FLOPS stays global (6*N*D) and is divided by chips when compared.
    """

    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    collective_bytes: float   # per device
    chips: int
    model_flops: float = 0.0  # global (6*N*D / 2*N*D)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste indicator)."""
        if not self.hlo_flops:
            return 0.0
        return (self.model_flops / self.chips) / self.hlo_flops

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the step's lower bound spent on *useful* model math."""
        if self.bound_s == 0:
            return 0.0
        useful_s = (self.model_flops / self.chips) / PEAK_FLOPS_BF16
        return useful_s / self.bound_s

    def to_dict(self) -> dict[str, Any]:
        return dict(
            hlo_flops=self.hlo_flops, hlo_bytes=self.hlo_bytes,
            collective_bytes=self.collective_bytes, chips=self.chips,
            model_flops=self.model_flops,
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            useful_flops_frac=self.useful_flops_frac,
            roofline_frac=self.roofline_frac,
        )


def active_param_count(cfg) -> float:
    """Matmul-bearing (active) params: embeddings excluded, unembed included,
    MoE counting only top-k + shared experts (brief: N_active)."""
    d = cfg.d_model
    hd = cfg.hd
    n = 0.0
    for kind in cfg.blocks_pattern:
        if kind in ("attn", "moe", "attn_local"):
            n += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
            if kind == "moe":
                active = cfg.top_k + cfg.n_shared_experts
                n_mats = 3 if cfg.act == "swiglu" else 2
                n += active * n_mats * d * cfg.expert_d_ff + d * cfg.n_experts
            else:
                n += (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
        elif kind == "rec":
            W = cfg.lru_width or d
            n += 2 * d * W + 2 * W * W + W * d
            n += (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
        elif kind == "rwkv":
            n += 5 * d * d + 2 * d * cfg.d_ff + d * d
    n += d * cfg.padded_vocab  # unembed
    return n


def model_flops_estimate(cfg, cell) -> float:
    """Brief's convention: MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference),
    with N = active matmul params and D = processed tokens this step."""
    n_active = active_param_count(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cfg.n_patches and cell.kind != "decode":
        tokens += cell.global_batch * cfg.n_patches
    mult = 6 if cell.kind == "train" else 2
    return mult * n_active * tokens


def recurrence_flops_correction(cfg, cell) -> float:
    """Analytic GLOBAL flops for sequential-scan recurrences that XLA's
    cost model counts only once (loop bodies are not multiplied by trip
    count).  Only the RWKV wkv recurrence needs this: RG-LRU runs in
    associative-scan form during analysis (counted in HLO), and the state
    stays VMEM-resident on TPU so no bytes correction applies.
    """
    if cfg.family != "rwkv":
        return 0.0
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    K = V = cfg.rwkv_head_dim
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    fwd = 6.0 * tokens * H * K * V * cfg.n_layers
    return fwd * (3.0 if cell.kind == "train" else 1.0)
