"""Plan-cache smoke gate (CI): compile -> serialize -> FRESH-PROCESS reload
-> assert bit-identical serve output, with requantization forcibly disabled
in the reloading process.

  PYTHONPATH=src python -m repro.launch.plan_smoke [--out results/plan_cache/plan_smoke]

The parent process compiles a CNN ModelPlan (with a small autotune pass),
saves it plus the expected logits, then spawns a child interpreter that
reloads the plan from disk and serves.  The child patches
``repro.core.quant.weight_levels`` to raise — proving the reload path never
requantizes — and asserts the logits match bit-for-bit.  If ``--out``
already holds a valid plan for the same fingerprintable inputs (the CI
plan-artifact cache), compilation is skipped and only the reload gate runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

SEED = 0
IMG = 16
BATCH = 4
CHANNELS = 8


def _setup():
    import jax

    from repro.core.quant import W1A4
    from repro.models.cnn import init_cnn, svhn_cnn_spec

    spec = svhn_cnn_spec(CHANNELS)
    params, _ = init_cnn(jax.random.PRNGKey(SEED), spec)
    x = jax.random.uniform(jax.random.PRNGKey(SEED + 1),
                           (BATCH, IMG, IMG, 3))
    return spec, params, x, W1A4


def check(base: str) -> int:
    """Child: reload the plan, forbid requantization, compare bit-exactly."""
    import jax
    import numpy as np

    import repro.core.quant as quant_mod
    from repro.core.plan import load_plan, plan_forward

    _, _, x, _ = _setup()
    t0 = time.perf_counter()
    plan = load_plan(base)
    load_ms = (time.perf_counter() - t0) * 1e3

    def _forbidden(*a, **kw):
        raise AssertionError(
            "weight_levels called after plan reload — the plan path must "
            "never requantize")

    quant_mod.weight_levels = _forbidden
    # jitted whole, same composition as the parent's expected program
    out = np.asarray(jax.jit(lambda v: plan_forward(plan, v))(x))
    expected = np.load(base + ".expected.npy")
    np.testing.assert_array_equal(out, expected)
    print(f"PLAN SMOKE OK: reload {load_ms:.1f}ms, output bit-identical, "
          f"no requantization (fingerprint {plan.fingerprint()})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/plan_cache/plan_smoke")
    ap.add_argument("--check", default=None, metavar="BASE",
                    help="internal: run the fresh-process reload gate")
    args = ap.parse_args()
    if args.check:
        return check(args.check)

    import jax
    import numpy as np

    from repro.core.plan import compile_model, load_plan, plan_forward, \
        save_plan

    spec, params, x, quant = _setup()
    base = args.out
    reused = False
    recompile_reason = None
    if os.path.exists(base + ".json") and os.path.exists(
            base + ".expected.npy"):
        try:
            plan = load_plan(base)  # cached artifact from a previous CI run
            reused = True
        except Exception as e:  # repro-lint: disable=RL003 — reason recorded in the output JSON; any reload failure means recompile
            recompile_reason = f"{type(e).__name__}: {e}"
            print(f"cached plan unusable ({recompile_reason}); recompiling")
            plan = None
    else:
        plan = None
        recompile_reason = "no cached artifact"
    if plan is None:
        t0 = time.perf_counter()
        plan = compile_model(params, spec, quant, batch_hints=(1, BATCH),
                             img_hw=IMG, autotune=True, model="svhn_smoke")
        compile_ms = (time.perf_counter() - t0) * 1e3
        save_plan(plan, base)
        print(f"compiled plan (+autotune) in {compile_ms:.1f}ms -> "
              f"{base}.json")
    else:
        print(f"reusing cached plan artifact {base}.json "
              f"(fingerprint {plan.fingerprint()})")
    expected = np.asarray(jax.jit(lambda v: plan_forward(plan, v))(x))
    np.save(base + ".expected.npy", expected)
    # bit-identity vs the legacy auto-dispatch forward at the SAME program
    # composition (both jitted whole — jit-vs-eager flips activation
    # quantization levels at ulp boundaries, same as test_engine pins)
    from repro.models.cnn import cnn_forward

    legacy = np.asarray(jax.jit(
        lambda v: cnn_forward(plan.params, v, spec, quant, "serve"))(x))
    np.testing.assert_array_equal(expected, legacy)

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
        + os.pathsep + env.get("PYTHONPATH", ""))
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.plan_smoke", "--check", base],
        env=env, capture_output=True, text=True, timeout=600)
    sys.stdout.write(p.stdout)
    sys.stderr.write(p.stderr)
    if p.returncode != 0 or "PLAN SMOKE OK" not in p.stdout:
        print("PLAN SMOKE FAILED", file=sys.stderr)
        return 1
    print(json.dumps(dict(
        plan=base + ".json", reused_cached_artifact=reused,
        recompile_reason=recompile_reason,
        fingerprint=plan.fingerprint(),
        engines={lp.name: lp.engine for lp in plan.layers})))
    return 0


if __name__ == "__main__":
    sys.exit(main())
