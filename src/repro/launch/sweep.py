"""Resumable dry-run sweep driver: one subprocess per cell (fresh XLA state,
bounded memory), JSON result per cell, skips cells already done.

  PYTHONPATH=src python -m repro.launch.sweep --mesh single --out results/
  PYTHONPATH=src python -m repro.launch.sweep --mesh multi  --out results/
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import all_configs


def cell_list():
    cells = []
    for arch, cfg in all_configs().items():
        for cell in cfg.shapes():
            cells.append((arch, cell.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--only", default=None, help="comma list arch:shape")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--analysis", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = cell_list()
    if args.only:
        want = set(tuple(x.split(":")) for x in args.only.split(","))
        cells = [c for c in cells if c in want]

    mesh_tag = "2x16x16" if args.mesh == "multi" else "16x16"
    if args.analysis:
        mesh_tag += "-analysis"
    done = ok = 0
    for arch, shape in cells:
        out_file = os.path.join(args.out, f"{arch}__{shape}__{mesh_tag}.json")
        if os.path.exists(out_file):
            with open(out_file) as f:
                prev = json.load(f)
            if prev and prev[0].get("ok"):
                done += 1
                ok += 1
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", out_file]
        if args.mesh == "multi":
            cmd.append("--multi-pod")
        if args.analysis:
            cmd.append("--analysis")
        t0 = time.time()
        print(f"[sweep] {arch} x {shape} ({mesh_tag}) ...", flush=True)
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            status = "OK" if p.returncode == 0 else "FAIL"
            if p.returncode != 0:
                tail = (p.stdout + p.stderr)[-1500:]
                with open(out_file + ".err", "w") as f:
                    f.write(p.stdout + "\n==STDERR==\n" + p.stderr)
                print(f"[sweep]   FAIL tail: ...{tail[-400:]}", flush=True)
            else:
                ok += 1
        except subprocess.TimeoutExpired:
            status = "TIMEOUT"
            with open(out_file + ".err", "w") as f:
                f.write("timeout")
        done += 1
        print(f"[sweep] {arch} x {shape} ({mesh_tag}): {status} "
              f"({time.time()-t0:.0f}s) [{done}/{len(cells)}]", flush=True)
    print(f"[sweep] complete: {ok}/{len(cells)} OK")


if __name__ == "__main__":
    main()
