"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(model: int = 1):
    """Single-device (or few-device) mesh for CPU tests/examples."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(data: int | None = None):
    """Data-only mesh for the request-level serving engine.

    The engine shards only the request/batch axis (params are replicated:
    serve has no optimizer state, and the smoke-scale models fit per
    device), so the mesh is 1-D over however many devices exist — or
    ``None`` for the single-device fallback, where plain ``jit`` avoids
    any collective/partitioning machinery.
    """
    n = data or len(jax.devices())
    if n <= 1:
        return None
    return jax.make_mesh((n,), ("data",))
