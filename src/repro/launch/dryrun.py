import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — see the brief, MULTI-POD DRY-RUN step 0.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and dump memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, all_configs, get_config, make_plan
from repro.launch import hlo_analysis as ha
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh, mesh_shape_dict


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             overrides: dict | None = None, verbose: bool = True,
             analysis: bool = False, infer_plan: bool = False,
             quant: str | None = None, prequant: bool = False) -> dict:
    cfg = get_config(arch)
    if quant:
        import dataclasses
        from repro.core.quant import PAPER_CONFIGS
        cfg = dataclasses.replace(cfg, quant=PAPER_CONFIGS[quant])
    if analysis:
        # exact loop accounting: unroll layers, closed-form attention,
        # associative recurrences (see hlo_analysis + EXPERIMENTS.md)
        import dataclasses
        cfg = dataclasses.replace(cfg, scan_layers=False,
                                  full_attn_analysis=True, rglru_assoc=True)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh_shape_dict(mesh),
                     inference=infer_plan and cell.kind != "train")
    chips = mesh.devices.size
    t0 = time.time()
    from repro.models.layers import set_static_act_scale
    set_static_act_scale(getattr(cfg, "act_scale", 0.0))
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        built = steps_mod.build_cell(
            cfg, cell, plan, mesh,
            qmode="serve" if (quant and cell.kind != "train") else "train",
            prequant=prequant)
        jitted = jax.jit(
            built["fn"],
            in_shardings=built["in_shardings"],
            out_shardings=built["out_shardings"],
            donate_argnums=built["donate_argnums"],
        )
        lowered = jitted.lower(*built["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if os.environ.get("DUMP_HLO"):
        with open(os.environ["DUMP_HLO"], "w") as f:
            f.write(hlo)
    coll = ha.collective_stats(hlo)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    rec_corr = ha.recurrence_flops_correction(cfg, cell) / chips
    rl = ha.Roofline(
        hlo_flops=flops + rec_corr, hlo_bytes=byts,
        collective_bytes=float(coll["total_bytes"]), chips=chips,
        model_flops=ha.model_flops_estimate(cfg, cell),
    )
    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_d[attr] = int(v)
    res = dict(
        arch=arch, shape=shape, mesh="2x16x16" if multi_pod else "16x16",
        chips=chips, ok=True,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=mem_d, collectives=coll, roofline=rl.to_dict(),
        flops=flops, bytes_accessed=byts,
    )
    if verbose:
        print(f"[dryrun] {arch} x {shape} on {res['mesh']}:")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={flops:.3e} bytes={byts:.3e}")
        print(f"  collectives: {coll['counts']} -> {coll['total_bytes']:.3e} B")
        r = res["roofline"]
        print(f"  roofline: compute={r['compute_s']:.4e}s memory={r['memory_s']:.4e}s "
              f"collective={r['collective_s']:.4e}s dominant={r['dominant']} "
              f"useful={r['useful_flops_frac']:.2%} frac={r['roofline_frac']:.2%}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--analysis", action="store_true")
    ap.add_argument("--infer-plan", action="store_true")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--prequant", action="store_true")
    ap.add_argument("--set", default=None,
                    help="comma list of ArchConfig overrides key=val (bool/int)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, cfg in all_configs().items():
            for cell in cfg.shapes():
                cells.append((arch, cell.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    overrides = {}
    if args.set:
        for kv in args.set.split(","):
            k, v = kv.split("=")
            overrides[k] = (v == "1" if v in ("0", "1") else
                            int(v) if v.isdigit() else v)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    fails = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(
                    arch, shape, multi_pod=mp, analysis=args.analysis,
                    infer_plan=args.infer_plan, quant=args.quant,
                    prequant=args.prequant, overrides=overrides or None))
            except Exception as e:  # repro-lint: disable=RL003 — a failure here is a bug: structured-recorded below and the run exits nonzero
                fails += 1
                traceback.print_exc()
                results.append(dict(arch=arch, shape=shape,
                                    mesh="2x16x16" if mp else "16x16",
                                    ok=False, error=str(e)[-2000:],
                                    error_type=type(e).__name__,
                                    traceback=traceback.format_exc()[-2000:]))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"[dryrun] {len(results) - fails}/{len(results)} cells OK")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
