"""Step builders + abstract input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for each step kind; ``make_*_step`` return the
functions that launch/dryrun.py lowers under the production mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell, ShardPlan
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.train import optimizer as opt

S = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Abstract params / optimizer / cache
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig, plan: ShardPlan):
    """(params ShapeDtypeStructs, axes) without allocating anything."""
    box = {}

    def mk():
        p, a = T.init_lm(jax.random.PRNGKey(0), cfg, plan)
        box["axes"] = a
        return p

    params = jax.eval_shape(mk)
    return params, box["axes"]


def abstract_opt(params, opt_cfg: opt.OptConfig, param_axes):
    state = jax.eval_shape(lambda p: opt.init_opt_state(p, opt_cfg), params)
    axes = opt.opt_state_axes(param_axes, opt_cfg)
    return state, axes


def abstract_cache(cfg: ArchConfig, plan: ShardPlan, batch: int, max_len: int):
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, plan, batch, max_len, dtype=cfg.compute_dtype))
    return cache, T.cache_axes(cfg, plan)


def batch_specs(cfg: ArchConfig, cell: ShapeCell):
    """Abstract training/prefill batch for this arch's modality."""
    B, L = cell.global_batch, cell.seq_len
    b: dict[str, Any] = {}
    if cfg.frame_input:
        b["frame_feats"] = S((B, L, cfg.frame_dim), jnp.float32)
    else:
        b["tokens"] = S((B, L), jnp.int32)
    if cfg.n_patches:
        b["patch_embeds"] = S((B, cfg.n_patches, cfg.vit_dim), jnp.float32)
    if cell.kind == "train":
        b["labels"] = S((B, L), jnp.int32)
    return b


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, plan: ShardPlan, opt_cfg: opt.OptConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            T.lm_loss, has_aux=True)(params, batch, cfg, plan)
        params, opt_state, stats = opt.apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **stats}

    return train_step


def make_prefill_step(cfg: ArchConfig, plan: ShardPlan, qmode: str = "train"):
    if not cfg.causal:  # encoder: no KV cache exists; prefill == encode
        def encode_step(params, batch):
            logits, _, _ = T.forward(
                params, cfg, plan, tokens=batch.get("tokens"),
                frame_feats=batch.get("frame_feats"), mode="train", qmode=qmode)
            return logits[:, -1, :], {}

        return encode_step

    def prefill_step(params, batch):
        logits, cache = T.prefill(
            params, cfg, plan,
            tokens=batch.get("tokens"),
            patch_embeds=batch.get("patch_embeds"),
            frame_feats=batch.get("frame_feats"),
            qmode=qmode)
        # return last-position logits only (sampler input); full logits for
        # a 32k prefill would be O(100GB) of useless output traffic.
        return logits[:, -1, :], cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: ShardPlan, qmode: str = "train"):
    def decode_step(params, cache, token, pos):
        logits, new_cache = T.decode_step(params, cache, token, pos, cfg, plan,
                                          qmode=qmode)
        return logits[:, -1, :], new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Full cell assembly: (step_fn, abstract args, in/out shardings, donate)
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, cell: ShapeCell, plan: ShardPlan, mesh,
               opt_cfg: opt.OptConfig | None = None, qmode: str = "train",
               prequant: bool = False):
    """Everything dryrun.py needs to lower one (arch x shape x mesh) cell."""
    opt_cfg = opt_cfg or opt.OptConfig()
    params, p_axes = abstract_params(cfg, plan)
    if prequant and cell.kind != "train":
        from repro.models.layers import prequantize_axes, prequantize_params
        params = jax.eval_shape(lambda p: prequantize_params(p, cfg), params)
        p_axes = prequantize_axes(p_axes, cfg)
    p_sh = shd.tree_shardings(params, p_axes, plan, mesh, cfg)

    if cell.kind == "train":
        ostate, o_axes = abstract_opt(params, opt_cfg, p_axes)
        o_sh = shd.tree_shardings(ostate, o_axes, plan, mesh, cfg)
        batch = batch_specs(cfg, cell)
        b_sh = shd.batch_shardings(batch, plan, mesh)
        fn = make_train_step(cfg, plan, opt_cfg)
        metrics_sh = jax.tree.map(
            lambda _: shd.replicated(mesh),
            jax.eval_shape(fn, params, ostate, batch)[2])
        return dict(
            fn=fn, args=(params, ostate, batch),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, metrics_sh),
            donate_argnums=(0, 1),
        )

    if cell.kind == "prefill":
        batch = batch_specs(cfg, cell)
        b_sh = shd.batch_shardings(batch, plan, mesh)
        fn = make_prefill_step(cfg, plan, qmode)
        logits_s, cache_s = jax.eval_shape(fn, params, batch)
        c_axes = T.cache_axes(cfg, plan)
        # prefill emits a cache shaped like its outputs; shard like decode cache
        c_sh = shd.tree_shardings(cache_s, _match_cache_axes(cache_s, c_axes),
                                  plan, mesh, cfg)
        out_sh = (shd.batch_shardings(logits_s, plan, mesh), c_sh)
        return dict(fn=fn, args=(params, batch), in_shardings=(p_sh, b_sh),
                    out_shardings=out_sh, donate_argnums=())

    # decode
    B = cell.global_batch
    cache, c_axes = abstract_cache(cfg, plan, B, cell.seq_len)
    c_sh = shd.tree_shardings(cache, _match_cache_axes(cache, c_axes), plan,
                              mesh, cfg)
    token = S((B, 1), jnp.int32)
    pos = S((), jnp.int32)
    t_sh = shd.batch_shardings(token, plan, mesh)
    fn = make_decode_step(cfg, plan, qmode)
    logits_s = jax.eval_shape(fn, params, cache, token, pos)[0]
    return dict(
        fn=fn, args=(params, cache, token, pos),
        in_shardings=(p_sh, c_sh, t_sh, shd.replicated(mesh)),
        out_shardings=(shd.batch_shardings(logits_s, plan, mesh), c_sh),
        donate_argnums=(1,),
    )


def _match_cache_axes(cache_tree, cache_axes):
    """Prune the static axes tree to the kinds present in the cache tree."""
    return {k: cache_axes[k] for k in cache_tree}
