"""Paper-table reproduction on the target registry (§III-C/D/E).

The canonical implementation behind ``repro.pim.accelsim`` (now a
one-release deprecation shim over this module).  Calibration protocol
(DESIGN.md §2, honest-knobs policy):

  * Cycle structure is *structural* — derived from each design's dataflow
    (compressor vs serial counter vs ADC vs MAC array), never fitted.
  * One energy scale per design is fitted to the ImageNet column of
    Table II (the only absolute numbers the paper publishes) — it lives on
    the :class:`repro.api.targets.PIMTarget` instances.
  * SVHN / MNIST columns and the Fig. 9/10 ratios are then *predictions*
    of the model — the benchmarks assert them against the paper's claims.

Every function here compiles a structure-only :class:`ModelPlan` for the
dataset's CNN and prices it through a registered target —
``simulate(design, dataset)`` is literally
``build(spec, quant).compile(target="cpu").simulate(target=design)``.
"""
from __future__ import annotations

import functools

from repro.models.cnn import ConvSpec, alexnet_spec, svhn_cnn_spec
from .targets import AREA_MM2, ENERGY_SCALE, get_target  # noqa: F401 (re-export)

# Table II (paper): energy uJ/img and area mm2 per design per dataset.
TABLE2 = {
    "reram":    dict(imagenet=(2275.34, 9.19), svhn=(425.21, 0.085), mnist=(13.55, 0.060)),
    "imce":     dict(imagenet=(785.25, 2.12),  svhn=(135.26, 0.010), mnist=(0.92, 0.009)),
    "proposed": dict(imagenet=(471.8, 2.60),   svhn=(84.31, 0.039),  mnist=(0.68, 0.012)),
}

# Headline claims (abstract / §III-C,D).
CLAIMS = dict(
    imce=dict(energy=2.1, speed=3.0),
    reram=dict(energy=5.4, speed=9.0),
    asic=dict(energy=9.7, speed=13.5),
)


def lenet_spec() -> list[ConvSpec]:
    """LeNet-5-style MNIST model for the Table II MNIST column."""
    return [
        ConvSpec(1, 6, 5, role="first"),
        ConvSpec(6, 16, 5, pool=True),
        ConvSpec(16, 120, 5, pool=True, fc=True),
        ConvSpec(120, 84, 1, fc=True),
        ConvSpec(84, 10, 1, fc=True, role="last"),
    ]


# Table II's SVHN BCNN is larger than the Table I accuracy model (the paper
# reuses the BCNN of [8] for the energy rows); width chosen structurally so
# the MAC count sits between MNIST and ImageNet like the paper's.
TABLE2_SVHN_CHANNELS = 72

DATASETS = {
    "imagenet": dict(spec=alexnet_spec, img=224),
    "svhn": dict(spec=lambda: svhn_cnn_spec(TABLE2_SVHN_CHANNELS), img=40),
    "mnist": dict(spec=lenet_spec, img=28),
}


@functools.lru_cache(maxsize=64)
def _dataset_compiled(dataset: str, m_bits: int, n_bits: int):
    """Structure-only compiled session for one dataset at one W:I config
    (one compile per (dataset, bits) — every design prices the same plan)."""
    from repro.core.quant import QuantConfig
    from .session import build

    ds = DATASETS[dataset]
    quant = QuantConfig(w_bits=n_bits, a_bits=m_bits, g_bits=8)
    model = build(ds["spec"](), quant, img_hw=ds["img"], name=dataset)
    return model.compile(target="cpu")


def simulate(design: str, dataset: str, m_bits: int = 1, n_bits: int = 1) -> dict:
    """Energy/latency/area table row for one design on one dataset — the
    legacy ``accelsim.simulate`` signature, now a thin client of the
    compiled plan + target registry."""
    report = _dataset_compiled(dataset, m_bits, n_bits).simulate(target=design)
    return dict(
        energy_uj=report.energy_uj, latency_us=report.latency_us,
        fps=report.fps, macs=report.macs, row_ops=report.row_ops,
        area_mm2=report.area_mm2, fps_per_mm2=report.fps_per_mm2,
        gops_per_w=report.gops_per_w, eff_per_mm2=report.eff_per_mm2)


def table2(m_bits: int = 1, n_bits: int = 1) -> dict:
    """Reproduce Table II: energy/area per design per dataset (BCNN 1:1)."""
    out = {}
    for design in ("reram", "imce", "proposed"):
        area = get_target(design).area_mm2
        out[design] = {
            ds: dict(energy_uj=simulate(design, ds, m_bits, n_bits)["energy_uj"],
                     area_mm2=area)
            for ds in DATASETS
        }
    return out


def fig9_fig10(configs=((1, 1), (1, 4), (1, 8), (2, 2))) -> dict:
    """Area-normalized energy-efficiency (Fig. 9) and fps (Fig. 10) across
    W:I configs, averaged over datasets, ratios vs the proposed design."""
    designs = ("proposed", "imce", "reram", "asic")
    effs: dict[str, list] = {k: [] for k in designs}
    fpss: dict[str, list] = {k: [] for k in designs}
    for (n_b, m_b) in configs:  # (W, I)
        for ds in DATASETS:
            for design in designs:
                r = simulate(design, ds, m_b, n_b)
                effs[design].append(r["eff_per_mm2"])
                fpss[design].append(r["fps_per_mm2"])
    gmean = lambda xs: float(__import__("numpy").exp(
        __import__("numpy").mean(__import__("numpy").log(xs))))
    eff = {k: gmean(v) for k, v in effs.items()}
    fps = {k: gmean(v) for k, v in fpss.items()}
    return dict(
        eff_per_mm2=eff, fps_per_mm2=fps,
        energy_ratio={k: eff["proposed"] / eff[k] for k in designs if k != "proposed"},
        speed_ratio={k: fps["proposed"] / fps[k] for k in designs if k != "proposed"},
    )


def paper_claims(dataset: str = "imagenet", m_bits: int = 1,
                 n_bits: int = 1) -> list[dict]:
    """The acceptance-criteria rows: ONE compiled plan, priced on every PIM
    target; energy/speed ratios of the proposed design vs each rival next
    to the paper's headline claims (abstract / §III-C,D)."""
    compiled = _dataset_compiled(dataset, m_bits, n_bits)
    proposed = compiled.simulate(target="sot_mram")
    rows = []
    for rival, legacy in (("imce", "imce"), ("reram", "reram"),
                          ("cmos_asic", "asic")):
        r = compiled.simulate(target=rival)
        ratios = proposed.vs(r)
        rows.append(dict(
            name=f"claim_vs_{legacy}", dataset=dataset,
            fingerprint=compiled.fingerprint(),
            energy_ratio=round(ratios["energy"], 2),
            speed_ratio=round(ratios["speed"], 2),
            # the paper's headline form is area-normalized (Fig. 9/10) —
            # for the big-eDRAM ASIC the per-mm2 view IS the claim
            energy_ratio_per_mm2=round(
                proposed.eff_per_mm2 / r.eff_per_mm2, 2),
            speed_ratio_per_mm2=round(
                proposed.fps_per_mm2 / r.fps_per_mm2, 2),
            paper_energy_claim=CLAIMS[legacy]["energy"],
            paper_speed_claim=CLAIMS[legacy]["speed"]))
    return rows


def calibrate() -> dict[str, float]:
    """Refit the per-design energy scale to the Table II ImageNet column
    (dev utility; pinned values live on the PIMTarget instances)."""
    from repro.pim.mapper import works_from_layers

    scales = {}
    layers = _dataset_compiled("imagenet", 1, 1).plan.layers
    works = works_from_layers(layers)
    for design in ("proposed", "imce", "reram"):
        t = get_target(design)
        from repro.pim.mapper import accel_cost
        raw = accel_cost(t.device, works)["energy_uj"]
        scales[design] = TABLE2[design]["imagenet"][0] / raw
    scales["asic"] = ENERGY_SCALE["asic"]
    return scales
