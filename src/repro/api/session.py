"""Session facade: build -> compile -> serve / simulate / save.

One object model over the previously-scattered entry points
(``models/cnn`` free functions, ``core/plan.compile_model``,
``launch/serve`` CLI plumbing, ``pim/accelsim`` free functions):

    model    = build(spec, quant, params=params)      # CNN (ConvSpec list)
    model    = build(cfg, params=params)              # LM  (ArchConfig)
    compiled = model.compile(target="cpu", batch_hints=(1, 8),
                             autotune=True, cache="results/plan")
    engine   = compiled.serve(max_batch=8)            # Deployment handle
    report   = compiled.simulate(target="sot_mram")   # CostReport
    compiled.save("results/plan"); load("results/plan")

``compile`` wraps :func:`repro.core.plan.compile_model` /
:func:`~repro.core.plan.compile_lm` — the ModelPlan IR stays the single
compiled artifact; the facade only decides *which* compile pass runs and
wires the result into the serving engine and the cost models.  A compute
:class:`~repro.api.targets.HardwareTarget` parameterizes compilation (its
dispatch table picks the engines); any target parameterizes simulation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.core.quant import QuantConfig
from .targets import Cost, LayerGeometry, PIMTarget, get_target


def _is_lm(spec) -> bool:
    """An LM ArchConfig (has a transformer geometry + its own quant);
    anything sequence-like is a CNN ConvSpec list."""
    return hasattr(spec, "n_layers") and hasattr(spec, "quant")


# ---------------------------------------------------------------------------
# Cost report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostReport:
    """Per-model cost on one target, with the per-layer breakdown.

    PIM targets fill the area-normalized columns the paper reports
    (``fps_per_mm2``, ``eff_per_mm2``); compute targets report the roofline
    totals only.  ``vs(other)`` gives the paper's headline ratio form:
    energy-efficiency and speed of *this* report over ``other``.
    """

    target: str
    energy_uj: float
    latency_us: float
    fps: float
    macs: int
    row_ops: int
    bytes_moved: float
    layers: tuple                  # ((layer_name, Cost), ...)
    area_mm2: Optional[float] = None
    fps_per_mm2: Optional[float] = None
    gops_per_w: Optional[float] = None
    eff_per_mm2: Optional[float] = None

    def vs(self, other: "CostReport") -> dict:
        """Headline ratios: how much more efficient/faster this target is
        than ``other`` (paper abstract form: proposed-vs-rival)."""
        return dict(
            energy=other.energy_uj / self.energy_uj,
            speed=self.fps / other.fps,
        )

    def rows(self) -> list[dict]:
        """CSV-able per-layer rows (benchmarks convention)."""
        return [dict(layer=name, energy_pj=round(c.energy_pj, 1),
                     cycles=round(c.cycles, 1),
                     bytes_moved=round(c.bytes_moved))
                for name, c in self.layers]


# ---------------------------------------------------------------------------
# Deployment: the serve handle
# ---------------------------------------------------------------------------

class Deployment:
    """A live serving handle over :class:`repro.launch.engine.ServeEngine`.

    Thin by design — the engine's queue/bucket/dispatch semantics are the
    contract (DESIGN.md §7); this wrapper only ties its lifetime to the
    compiled plan and offers the closed-loop ``predict`` convenience.
    """

    def __init__(self, engine, compiled: "CompiledModel"):
        self.engine = engine
        self.compiled = compiled

    def predict(self, payloads) -> list[np.ndarray]:
        """Closed-loop serve: submit all payloads, drain, values in order."""
        return [r.value for r in self.engine.serve(list(payloads))]

    # queue-level passthroughs for open-loop drivers
    def submit(self, payload, t_submit=None) -> int:
        return self.engine.submit(payload, t_submit=t_submit)

    def pump(self) -> None:
        self.engine.pump()

    def drain(self):
        return self.engine.drain()

    @property
    def stats(self) -> dict:
        return self.engine.stats


# ---------------------------------------------------------------------------
# Model (the session) and CompiledModel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    """An uncompiled model: spec/config + quantization + (optional) params.

    The session object — holds everything ``compile`` needs.  ``params``
    may be a float checkpoint (prequantized during compile) or None for a
    structure-only session (engine-table inspection, cost simulation).
    """

    kind: str                       # "cnn" | "lm"
    spec: Any                       # ConvSpec list (cnn) | ArchConfig (lm)
    quant: QuantConfig
    params: Any = None
    img_hw: Any = 40                # cnn input size (int or (h, w))
    name: str = "cnn"

    def compile(self, *, target: str | None = None, batch_hints=(1,),
                autotune: bool = False, prompt_len: int = 16,
                cache: str | None = None,
                verify: bool = True) -> "CompiledModel":
        """Compile this model against a compute target.

        ``target`` names a registered compute target (``cpu``/``tpu``);
        None uses the live jax backend.  ``cache`` points at a plan file:
        if present it is reloaded (guarded by
        :func:`repro.core.plan.check_plan_matches` — requantization and
        autotune are skipped), otherwise the freshly compiled plan is
        saved there.  ``verify`` gates the static plan prover
        (:func:`repro.analysis.verify_plan`) on both the fresh-compile and
        the cache-reload path.
        """
        from repro.core import plan as P

        backend = None
        if target is not None:
            t = get_target(target)
            if t.kind != "compute":
                raise P.PlanError(
                    f"target {target!r} is a simulated PIM design — compile "
                    "against a compute target (cpu/tpu) and pass the PIM "
                    "target to .simulate() instead")
            backend = t.name
        t0 = time.perf_counter()
        if cache and P.plan_exists(cache):
            # the requested target (or, with none requested, the live
            # backend) must also hold for a cached plan — a TPU plan pins
            # Pallas-only engines that would only interpret on CPU;
            # check_plan_matches raises the readable recompile error
            import jax

            plan = P.check_plan_matches(
                P.load_plan(cache), quant=self.quant, model=self.name,
                backend=backend or jax.default_backend())
            if verify:
                from repro.analysis.prover import assert_plan_verified

                assert_plan_verified(plan)
            return CompiledModel(plan, model=self, cache_path=cache,
                                 reloaded=True,
                                 compile_s=time.perf_counter() - t0)
        if self.kind == "lm":
            plan = P.compile_lm(self.params, self.spec, backend=backend,
                                batch_hints=batch_hints,
                                prompt_len=prompt_len, autotune=autotune,
                                verify=verify)
        else:
            plan = P.compile_model(self.params, self.spec, self.quant,
                                   backend=backend, batch_hints=batch_hints,
                                   img_hw=self.img_hw, autotune=autotune,
                                   model=self.name, verify=verify)
        path = P.save_plan(plan, cache) if cache else None
        return CompiledModel(plan, model=self, cache_path=path,
                             reloaded=False,
                             compile_s=time.perf_counter() - t0)


@dataclasses.dataclass
class CompiledModel:
    """A compiled ModelPlan with the full lifecycle attached."""

    plan: Any                       # repro.core.plan.ModelPlan
    model: Optional[Model] = None
    cache_path: Optional[str] = None
    reloaded: bool = False
    compile_s: float = 0.0

    @property
    def params(self):
        return self.plan.params

    @property
    def quant(self) -> QuantConfig:
        return self.plan.quant

    def fingerprint(self) -> str:
        return self.plan.fingerprint()

    # -- execution ----------------------------------------------------------

    def forward(self, x):
        """One batched CNN forward through the plan (jit-compatible)."""
        from repro.core import plan as P

        if self.plan.kind != "cnn":
            raise P.PlanError("forward() executes CNN plans; use serve() "
                              "for LM generation")
        return P.plan_forward(self.plan, x)

    def serve(self, *, max_batch: int = 8, flush_deadline_s: float = 0.005,
              mesh=None, max_pending: int = 4096,
              new_tokens: int = 16, qmode: str = "serve",
              resilience=None, fallback: "CompiledModel | None" = None,
              ) -> Deployment:
        """Stand up the request-level serving engine on this plan.

        ``resilience`` (a :class:`repro.resilience.ResilienceConfig`)
        swaps in the fault-surviving engine: seeded fault injection,
        crash-consistent decode epoch checkpoints, retry/dead-letter
        recovery, and — with ``fallback`` (a lower-bit CompiledModel of
        the same architecture) — degraded-plan fallback (DESIGN.md §11).
        """
        from repro.core.plan import PlanError
        from repro.launch.engine import CNNRunner, LMRunner, ServeEngine

        if resilience is not None:
            from repro.resilience import build_resilient_engine

            engine = build_resilient_engine(
                self, resilience, fallback=fallback, new_tokens=new_tokens,
                qmode=qmode, max_batch=max_batch,
                flush_deadline_s=flush_deadline_s, max_pending=max_pending,
                mesh=mesh)
            return Deployment(engine, self)
        if self.plan.kind == "lm":
            if self.model is None:
                raise PlanError(
                    "serving an LM plan needs its ArchConfig (cache "
                    "geometry, vocab) — reload through "
                    "api.build(cfg, ...).compile(cache=...) or "
                    "api.load(path, spec=cfg)")
            runner = LMRunner(None, self.model.spec, new_tokens=new_tokens,
                              qmode=qmode, model_plan=self.plan)
        else:
            spec = self.model.spec if self.model is not None else None
            runner = CNNRunner(None, spec, None, plan=self.plan)
        engine = ServeEngine(runner, max_batch=max_batch,
                             flush_deadline_s=flush_deadline_s, mesh=mesh,
                             max_pending=max_pending)
        return Deployment(engine, self)

    # -- simulation ---------------------------------------------------------

    def simulate(self, target: str = "sot_mram") -> CostReport:
        """Price this compiled plan on a hardware target.

        PIM targets reproduce the legacy ``pim/accelsim`` arithmetic
        bit-for-bit (same works, same ``accel_cost``, same fitted energy
        scale); compute targets report the roofline annotation totals.
        """
        from repro.core import plan as P
        from repro.pim.mapper import effective_bits, works_from_layers

        if self.plan.kind != "cnn":
            raise P.PlanError("simulate() prices CNN plans (the paper's "
                              f"scope); this plan is {self.plan.kind!r}")
        t = get_target(target)
        layers = self.plan.layers
        if isinstance(t, PIMTarget):
            works = works_from_layers(layers)
            r = t.report(works)
            per_layer = tuple(
                (lp.name, t.cost(LayerGeometry(lp.out_h * lp.out_w, lp.k,
                                               lp.cout),
                                 *effective_bits(lp)))
                for lp in layers)
            return CostReport(
                target=t.name, energy_uj=r["energy_uj"],
                latency_us=r["latency_us"], fps=r["fps"], macs=r["macs"],
                row_ops=r["row_ops"],
                bytes_moved=sum(c.bytes_moved for _, c in per_layer),
                layers=per_layer, area_mm2=r["area_mm2"],
                fps_per_mm2=r["fps_per_mm2"], gops_per_w=r["gops_per_w"],
                eff_per_mm2=r["eff_per_mm2"])
        per_layer = []
        total = Cost(0.0, 0.0, 0.0)
        macs = 0
        for lp in layers:
            ab, wb = effective_bits(lp)
            geom = LayerGeometry(lp.out_h * lp.out_w, lp.k, lp.cout)
            c = t.cost(geom, ab, wb)
            macs += geom.macs
            per_layer.append((lp.name, c))
            total = total + c
        latency_us = total.cycles / (t.clock_ghz * 1e3)
        return CostReport(
            target=t.name, energy_uj=total.energy_pj * 1e-6,
            latency_us=latency_us,
            fps=1e6 / latency_us if latency_us else float("inf"),
            macs=macs, row_ops=0, bytes_moved=total.bytes_moved,
            layers=tuple(per_layer))

    # -- persistence --------------------------------------------------------

    def save(self, path: str) -> str:
        from repro.core.plan import save_plan

        self.cache_path = save_plan(self.plan, path)
        return self.cache_path


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def build(spec, quant: QuantConfig | None = None, *, params=None,
          img_hw=40, name: str | None = None) -> Model:
    """Open a session: ``spec`` is a ConvSpec list (CNN) or an ArchConfig
    (LM — its own ``quant`` is used unless overridden)."""
    if _is_lm(spec):
        q = quant if quant is not None else spec.quant
        cfg = spec if quant is None else dataclasses.replace(spec, quant=quant)
        return Model(kind="lm", spec=cfg, quant=q, params=params,
                     name=name or getattr(cfg, "name", "lm"))
    if quant is None:
        raise TypeError("build(spec, quant): CNN specs carry no quant "
                        "config of their own — pass one explicitly")
    return Model(kind="cnn", spec=tuple(spec), quant=quant, params=params,
                 img_hw=img_hw, name=name or "cnn")


def load(path: str, *, spec=None, quant: QuantConfig | None = None,
         model: str | None = None,
         backend: str | None = None) -> CompiledModel:
    """Reload a persisted plan as a CompiledModel (optionally guarded
    against the caller's live configuration — see
    :func:`repro.core.plan.check_plan_matches`).  Pass ``backend=`` when
    the plan will be executed (a plan compiled for another backend may pin
    engines that cannot run here); omit it for pure inspection."""
    from repro.core.plan import check_plan_matches, load_plan

    plan = check_plan_matches(load_plan(path), quant=quant, model=model,
                              backend=backend)
    m = None
    if spec is not None:
        m = build(spec, quant if quant is not None else plan.quant,
                  name=plan.model)
    return CompiledModel(plan, model=m, cache_path=path, reloaded=True)
