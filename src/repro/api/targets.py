"""HardwareTarget registry: one cost/dispatch abstraction per backend.

The paper's evaluation (§III-C/D) and the serving stack's engine selection
used to live in different worlds: ``pim/energy.DeviceModel`` +
``pim/mapper.accel_cost`` priced the four accelerator designs, while
``kernels/ops.cost_model_engine`` carried its own ad-hoc CPU/TPU crossover
constants.  This module unifies both behind one interface:

    target = get_target("sot_mram")          # or cpu / tpu / imce / ...
    cost   = target.cost(geom, a_bits, w_bits)   # Cost(energy_pj, cycles,
                                                 #      bytes_moved)

Two target families:

* :class:`ComputeTarget` (``cpu``, ``tpu``) — real serve backends.  Their
  *cost tables* are exactly the crossover constants the engine heuristic
  used to hard-code (``IMPLICIT_*`` in ``kernels/ops``); ``select_engine``
  is the same decision procedure, now owned by the target, and
  ``kernels/ops.cost_model_engine`` delegates here.  ``cost()`` is a
  roofline estimate (flops vs bytes) used to annotate compiled plans with
  per-layer energy/latency.
* :class:`PIMTarget` (``sot_mram``, ``imce``, ``reram``, ``cmos_asic``) —
  the paper's accelerators.  ``cost()`` prices one layer with the
  calibrated :class:`repro.pim.energy.DeviceModel`; ``report()`` prices a
  whole model bit-identically to the pre-registry ``pim/accelsim``
  pipeline (same ``accel_cost`` arithmetic, same fitted energy scale).

The registry is open: ``register_target`` adds new backends (the hook
every future scenario — new accelerators, energy-aware scheduling,
per-target intermittency budgets — plugs into).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.pim.energy import (CLOCK_GHZ, DESIGNS, SUBARRAY_COLS,
                              TABLE2_AREA_MM2, TABLE2_ENERGY_SCALE,
                              DeviceModel)
from repro.pim.mapper import LayerWork, accel_cost


@dataclasses.dataclass(frozen=True)
class Cost:
    """One layer's (or model's) cost on one target."""

    energy_pj: float
    cycles: float
    bytes_moved: float

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.energy_pj + other.energy_pj,
                    self.cycles + other.cycles,
                    self.bytes_moved + other.bytes_moved)


@dataclasses.dataclass(frozen=True)
class LayerGeometry:
    """The GEMM view of one layer: (m, k) x (k, n).

    For a conv layer m = out_h*out_w (per image), k = kh*kw*cin, n = cout;
    MACs = m*k*n.  Every target costs this view — the conv-specific
    eligibility bounds (``ConvShape``) stay on the dispatch side.
    """

    m: int
    k: int
    n: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


class HardwareTarget:
    """Base: a named backend with a layer cost model."""

    name: str = "?"
    kind: str = "?"          # "compute" (serve backend) | "pim" (simulated)

    def cost(self, geom: LayerGeometry, a_bits: int, w_bits: int) -> Cost:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debug nicety
        return f"<{type(self).__name__} {self.name!r} ({self.kind})>"


# ---------------------------------------------------------------------------
# Compute targets: the serve backends (cpu / tpu)
# ---------------------------------------------------------------------------

# shared implicit-conv eligibility: the kernel supports these strides, and
# a 1x1 conv has no patch blowup (im2col is the identity there)
IMPLICIT_STRIDES = (1, 2)
IMPLICIT_AMP_MIN = 4.0
IMPLICIT_PADDINGS = ("SAME", "VALID")


def _implicit_eligible(conv) -> bool:
    return (conv is not None and conv.kh * conv.kw > 1
            and conv.stride in IMPLICIT_STRIDES
            and conv.padding in IMPLICIT_PADDINGS
            # no blowup, nothing to save: full-window FC-as-conv layers
            # (oh=ow=1, amplification 1) stay on the dense fused GEMM
            and conv.read_amplification >= IMPLICIT_AMP_MIN)


@dataclasses.dataclass(frozen=True)
class ComputeTarget(HardwareTarget):
    """A real serve backend: engine dispatch table + roofline cost model.

    ``table`` holds every crossover constant ``select_engine`` consults —
    the numbers measured in ``benchmarks/bench_conv.py`` — so a dispatch
    retune is a target edit, not a heuristic rewrite.  The per-op physical
    constants are order-of-magnitude figures for plan annotation (serving
    decisions never depend on them; the PIM models are the calibrated
    ones).
    """

    name: str = "cpu"
    kind: str = dataclasses.field(default="compute", init=False)
    table: tuple = ()               # ((constant, value), ...) cost table
    clock_ghz: float = 3.0
    flops_per_cycle: float = 32.0   # sustained fused-multiply-add lanes
    bytes_per_cycle: float = 16.0   # sustained memory-system bandwidth
    pj_per_flop: float = 2.0
    pj_per_byte: float = 20.0

    def __getitem__(self, const: str) -> float:
        return dict(self.table)[const]

    def cost(self, geom: LayerGeometry, a_bits: int, w_bits: int) -> Cost:
        """Roofline estimate: compute-bound vs bandwidth-bound cycles."""
        itemsize = 1 if max(a_bits, w_bits) <= 7 else 4
        flops = 2.0 * geom.macs
        bytes_moved = float(itemsize * (geom.m * geom.k + geom.k * geom.n)
                            + 4 * geom.m * geom.n)
        cycles = max(flops / self.flops_per_cycle,
                     bytes_moved / self.bytes_per_cycle)
        return Cost(energy_pj=flops * self.pj_per_flop
                    + bytes_moved * self.pj_per_byte,
                    cycles=cycles, bytes_moved=bytes_moved)

    def select_engine(self, m: int, k: int, n: int, a_bits: int, w_bits: int,
                      conv=None) -> str:
        raise NotImplementedError

    def attn_cost(self, attn) -> Cost:
        """Roofline estimate for one attention layer (plan annotation).

        Scores + weighted values are two GEMMs over the *effective* kv
        extent (a sliding window bounds it; causal halves it), per head
        and batch row.  Same physical constants as :meth:`cost`.
        """
        if attn.window:
            eff_kv = min(attn.window, attn.seq_kv)
        elif attn.causal and attn.seq_q == attn.seq_kv:
            eff_kv = max(attn.seq_kv // 2, 1)
        else:
            eff_kv = attn.seq_kv
        geom = LayerGeometry(m=attn.batch * attn.heads * attn.seq_q,
                             k=attn.head_dim, n=eff_kv)
        qk = self.cost(geom, 8, 8)
        return qk + qk  # P @ V moves/computes the mirror of Q @ K^T

    def select_attn_engine(self, attn) -> str:
        """Pick the attention engine for one prefill/train geometry.

        Shared decision procedure over per-target table constants
        (``attn_*``); ``attn`` is a :class:`repro.kernels.ops.AttnShape`.
        Engines, all realized in ``models/layers.py`` /
        ``kernels/attn_flash.py``:

          ``full``     materialized S^2 logits + one softmax — fastest
                       while the logits fit cache/HBM;
          ``chunked``  online-softmax scan (O(S) memory), masked kv chunks
                       skipped;
          ``banded``   block-diagonal sliding-window evaluation — only
                       defined when a window bounds the band;
          ``flash``    the quantized flash kernel — only when the serve
                       path is quantized (it consumes level-quantized q/k,
                       so it would change train/full-precision numerics);
          ``paged``    the page-table gather engine — page-table
                       geometries (``attn.page_size`` set) ALWAYS dispatch
                       it: no other engine can read a paged pool.
        """
        from repro.kernels.attn_flash import flash_levels_exact

        if getattr(attn, "page_size", None):
            return "paged"
        t = dict(self.table)
        seq = max(attn.seq_q, attn.seq_kv)
        if (attn.quantized and seq >= t["attn_flash_seq_min"]
                and attn.seq_q > 1
                and flash_levels_exact(attn.head_dim, 8, 8)):
            return "flash"
        if (attn.window and attn.banded_ok
                and attn.seq_q > 2 * attn.window):
            return "banded"
        if seq >= t["attn_chunk_seq_min"]:
            return "chunked"
        return "full"


@dataclasses.dataclass(frozen=True)
class CpuTarget(ComputeTarget):
    """CPU (and any non-TPU jax backend): XLA lowers integer matmuls to
    scalar loops, so the float unit wins while exact; the implicit direct
    conv pays off once the batched problem moves enough amplified patch
    traffic (measured crossover, ``benchmarks/bench_conv.py`` batch 1-8).
    """

    name: str = "cpu"
    table: tuple = (
        # implicit wins once conv.m * amplification crosses this, amortized
        # over the batch (floored at 8 — beyond that the conv-loop cost is
        # fully amortized and only the per-element term is left)
        ("implicit_m_amp_min", 2500),
        ("implicit_batch_amortize_cap", 8),
        # shallow-K convs (cin=3 stems) lose at every batch size: each
        # (dy, dx) tap does too little dot work to cover its slice/reshape
        ("implicit_kdim_min", 128),
        # channel-EXPANDING convs (cout > cin) write cout/cin times the
        # patch bytes they save; measured (bench_conv.json) the direct
        # sweep only recovers that above cin=96 (svhn 64->128 runs at
        # 0.63x gemm, crossover 32->64 at 0.77x; 96->256 and all
        # non-expanding deep layers still win)
        ("implicit_expand_cin_min", 96),
        # online-softmax chunking beats materialized S^2 logits once the
        # sequence spills cache (the former CHUNK_ATTN_THRESHOLD)
        ("attn_chunk_seq_min", 8192),
        # the quantized flash kernel's block sweep needs enough kv blocks
        # to amortize its online-softmax state updates
        ("attn_flash_seq_min", 4096),
    )

    def select_engine(self, m, k, n, a_bits, w_bits, conv=None) -> str:
        from repro.core.and_accum import f32dot_exact
        from repro.kernels.conv_implicit import implicit_xla_exact

        if conv is not None:
            m = conv.m  # engine bounds always see the full batched rows
        t = dict(self.table)
        if (_implicit_eligible(conv) and k >= t["implicit_kdim_min"]
                and m * conv.read_amplification
                >= t["implicit_m_amp_min"]
                / min(conv.batch, t["implicit_batch_amortize_cap"])
                and (n <= k // max(conv.kh * conv.kw, 1)  # cout <= cin
                     or k // max(conv.kh * conv.kw, 1)
                     >= t["implicit_expand_cin_min"])
                and implicit_xla_exact(k, a_bits, w_bits)):
            return "implicit"
        return "f32dot" if f32dot_exact(k, a_bits, w_bits) else "int8"


@dataclasses.dataclass(frozen=True)
class TpuTarget(ComputeTarget):
    """TPU: the fused Pallas pipeline is the default; deep-K spatial convs
    route to the implicit-GEMM sweep while one image's levels fit VMEM;
    binary huge-K skinny-output problems take the VPU popcount kernel."""

    name: str = "tpu"
    clock_ghz: float = 0.94
    flops_per_cycle: float = 512.0
    bytes_per_cycle: float = 256.0
    pj_per_flop: float = 0.3
    pj_per_byte: float = 8.0
    table: tuple = (
        # only K-axes at least this deep amortize the halo'd-tile
        # bookkeeping of the implicit kernel
        ("implicit_kdim_min", 512),
        # one image's int8 levels stay VMEM-resident per batch index; leave
        # half of ~16 MiB for weight/output tiles and the double buffers
        ("implicit_vmem_bytes", 8 << 20),
        # binary, huge-K, output tile small enough that the 128x128 MXU
        # would idle: the 32x K-compressed VPU popcount path wins
        ("faithful_mn_max", 1 << 14),
        ("faithful_kdim_min", 1 << 15),
        # attention: same decision procedure as CPU; the native Pallas
        # flash kernel amortizes earlier (MXU int8 dots from block one)
        ("attn_chunk_seq_min", 8192),
        ("attn_flash_seq_min", 2048),
    )

    def select_engine(self, m, k, n, a_bits, w_bits, conv=None) -> str:
        from repro.core.prequant import level_dtype

        import jax.numpy as jnp

        if conv is not None:
            m = conv.m
        t = dict(self.table)
        if _implicit_eligible(conv) and k >= t["implicit_kdim_min"]:
            # feasibility: one image's activation LEVELS must stay
            # VMEM-resident — int8 up to 7 activation bits, int32 at 8
            # (level_dtype), so the budget is in bytes, not elements
            cin = k // max(conv.kh * conv.kw, 1)
            lvl_bytes = jnp.zeros((), level_dtype(a_bits)).dtype.itemsize
            if (conv.padded_image_elems(cin) * lvl_bytes
                    <= t["implicit_vmem_bytes"]):
                return "implicit"
        if (a_bits == 1 and w_bits == 1 and m * n <= t["faithful_mn_max"]
                and k >= t["faithful_kdim_min"]):
            return "faithful"
        return "fused"


# ---------------------------------------------------------------------------
# PIM targets: the paper's accelerator designs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PIMTarget(HardwareTarget):
    """One of the paper's accelerators, priced with the calibrated device
    model.  ``energy_scale`` is the single per-design constant fitted to
    the Table II ImageNet column (see ``pim/accelsim`` docstring — the
    honest-knobs policy); ``report()`` reproduces that pipeline exactly.
    """

    name: str = "sot_mram"
    kind: str = dataclasses.field(default="pim", init=False)
    device: DeviceModel = None
    energy_scale: float = 1.0
    area_mm2: float = 0.0

    def work(self, geom: LayerGeometry, a_bits: int, w_bits: int) -> LayerWork:
        """Bit products -> 512-cell row operations (paper Eq. 1 mapping)."""
        bitp = geom.macs * a_bits * w_bits
        return LayerWork(macs=geom.macs, bit_products=bitp,
                         row_ops=-(-bitp // SUBARRAY_COLS))

    def cost(self, geom: LayerGeometry, a_bits: int, w_bits: int) -> Cost:
        w = self.work(geom, a_bits, w_bits)
        d = self.device
        if d.e_mac_asic:  # CMOS ASIC path: MAC array + eDRAM traffic
            cycles = w.macs / max(d.c_macs_per_cycle, 1)
            energy = w.macs * d.e_mac_asic + cycles * d.e_static_per_cycle
        else:
            per_row = d.c_and + d.c_write + d.c_cmp + d.c_accum
            cycles = w.row_ops * per_row / max(d.n_parallel_subarrays, 1)
            energy = w.row_ops * (d.e_and_row + d.e_write_row + d.e_cmp_row
                                  + d.e_accum) + cycles * d.e_static_per_cycle
        # traffic: each row-op senses + writes back one 512-bit row
        return Cost(energy_pj=energy * self.energy_scale, cycles=cycles,
                    bytes_moved=w.row_ops * 2 * SUBARRAY_COLS / 8)

    def report(self, works: Sequence[LayerWork]) -> dict:
        """Whole-model cost, bit-identical to the legacy ``accelsim``
        pipeline: one ``accel_cost`` over the full works list (NOT a sum of
        per-layer costs — float summation order is part of the contract
        the Table II tests pin), then the fitted energy scale."""
        r = accel_cost(self.device, works)
        r["energy_uj"] *= self.energy_scale
        r["area_mm2"] = self.area_mm2
        r["fps_per_mm2"] = r["fps"] / self.area_mm2
        r["gops_per_w"] = (r["macs"] * 2e-9) / (r["energy_uj"] * 1e-6)
        r["eff_per_mm2"] = r["gops_per_w"] / self.area_mm2
        r["target"] = self.name
        return r


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, HardwareTarget] = {}

# legacy spellings (paper/accelsim design names, jax backend names)
_ALIASES = {"proposed": "sot_mram", "asic": "cmos_asic", "gpu": "cpu"}


def register_target(target: HardwareTarget) -> HardwareTarget:
    _REGISTRY[target.name] = target
    return target


def available_targets() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_target(name: str) -> HardwareTarget:
    """Resolve a target by name (aliases: proposed->sot_mram,
    asic->cmos_asic, gpu->cpu).  Unknown names raise a ValueError that
    lists every registered target."""
    key = _ALIASES.get(name, name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown hardware target {name!r}; available targets: "
            f"{', '.join(available_targets())}") from None


def target_for_backend(backend: str) -> ComputeTarget:
    """The compute target serving a jax backend string.  Unlike
    :func:`get_target` this never raises: any backend we have no dedicated
    table for (e.g. an exotic PJRT plugin) gets the conservative CPU
    dispatch rules, matching the historical non-TPU branch."""
    t = _REGISTRY.get(_ALIASES.get(backend, backend))
    if isinstance(t, ComputeTarget):
        return t
    return _REGISTRY["cpu"]


# Energy scale per PIM design + Table II / §III-E areas.  The values live
# in ``repro.pim.energy`` (single source of truth — the DeviceModel areas
# derive from the same dicts); these names stay as the public re-export
# spelling used by reports/accelsim.
ENERGY_SCALE = TABLE2_ENERGY_SCALE
AREA_MM2 = TABLE2_AREA_MM2

CPU = register_target(CpuTarget())
TPU = register_target(TpuTarget())
SOT_MRAM = register_target(PIMTarget(
    name="sot_mram", device=DESIGNS["proposed"],
    energy_scale=ENERGY_SCALE["proposed"], area_mm2=AREA_MM2["proposed"]))
IMCE = register_target(PIMTarget(
    name="imce", device=DESIGNS["imce"],
    energy_scale=ENERGY_SCALE["imce"], area_mm2=AREA_MM2["imce"]))
RERAM = register_target(PIMTarget(
    name="reram", device=DESIGNS["reram"],
    energy_scale=ENERGY_SCALE["reram"], area_mm2=AREA_MM2["reram"]))
CMOS_ASIC = register_target(PIMTarget(
    name="cmos_asic", device=DESIGNS["asic"],
    energy_scale=ENERGY_SCALE["asic"], area_mm2=AREA_MM2["asic"]))

PIM_CLOCK_GHZ = CLOCK_GHZ
