"""The public API surface: hardware targets + the Session facade.

Everything the system does — compile, serve, simulate — is parameterized
by a :class:`HardwareTarget` (DESIGN.md §9).  The facade is three calls:

    from repro import api
    model    = api.build(spec, quant, params=params)     # Session
    compiled = model.compile(target="cpu")               # ModelPlan under the hood
    engine   = compiled.serve(max_batch=8)               # Deployment handle
    report   = compiled.simulate(target="sot_mram")      # CostReport

``compiled.save(path)`` / ``api.load(path)`` persist the plan (the
intermittency-resume fast path).  The paper-table reproductions live in
:mod:`repro.api.reports` (``simulate``, ``table2``, ``fig9_fig10``) —
``repro.pim.accelsim`` is a one-release deprecation shim over them.
``api.fleet`` is the fleet-scale intermittency entry point (harvest
traces, the fluid node simulator, per-node plan co-design — DESIGN.md
§14): it re-exports :mod:`repro.fleet`, which prices nodes with the same
targets registered here via ``core/plan.plan_cost_on``.
"""
from .targets import (Cost, ComputeTarget, HardwareTarget, LayerGeometry,
                      PIMTarget, available_targets, get_target,
                      register_target, target_for_backend)
from .session import (CompiledModel, CostReport, Deployment, Model, build,
                      load)
from . import reports
from repro import fleet

__all__ = [
    "Cost", "ComputeTarget", "HardwareTarget", "LayerGeometry", "PIMTarget",
    "available_targets", "get_target", "register_target",
    "target_for_backend",
    "CompiledModel", "CostReport", "Deployment", "Model", "build", "load",
    "reports", "fleet",
]
