"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The production 2x16x16 mesh covers every assigned model with TP x DP (no
arch needs more than 16-way model sharding), so PP is an *optional* axis:
``make_pipeline_mesh(stages, data)`` builds ("pipe", "data") meshes and
``pipeline_apply`` runs a stage-partitioned layer stack with microbatched
1F1B-ish scheduling (forward-only steady state here; the backward pass is
driven by JAX AD through the shard_map).

Exercised by tests/test_pipeline.py on an 8-device host mesh (subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def make_pipeline_mesh(stages: int, data: int = 1):
    return jax.make_mesh((stages, data), ("pipe", "data"))


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh,
                   n_microbatches: int):
    """Run ``y = stage_L(...stage_1(x))`` over the "pipe" mesh axis.

    stage_params: pytree with leading stage axis (sharded over "pipe").
    x: (n_microbatches, mb, ...) activations (microbatch-major).
    Schedule: standard GPipe fill-drain of T = M + S - 1 ticks; at tick t,
    stage s processes microbatch t - s. Bubble fraction = (S-1)/(M+S-1).
    """
    S = mesh.shape["pipe"]
    M = n_microbatches

    def per_stage(params, xs):
        # params: this stage's params (leading axis 1); xs: (M, mb, ...)
        params = jax.tree.map(lambda t: t[0], params)
        stage_id = jax.lax.axis_index("pipe")
        mb_shape = xs.shape[1:]
        ticks = M + S - 1

        def tick(carry, t):
            buf, outs = carry  # buf: activation entering this stage
            # stage 0 feeds from xs[t] while t < M, others from the permuted buf
            feed = jnp.where(
                t < M,
                jax.lax.dynamic_index_in_dim(xs, jnp.minimum(t, M - 1), 0,
                                             keepdims=False),
                jnp.zeros(mb_shape, xs.dtype))
            inp = jnp.where(stage_id == 0, feed, buf)
            out = stage_fn(params, inp)
            # pass activations down the pipe: stage s -> s+1
            nxt = jax.lax.ppermute(
                out, "pipe", [(i, i + 1) for i in range(S - 1)])
            # last stage records its output for microbatch t - (S-1)
            mb_idx = t - (S - 1)
            outs = jax.lax.cond(
                mb_idx >= 0,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(mb_idx, 0), 0),
                lambda o: o, outs)
            return (nxt, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # only the LAST stage's record is meaningful; broadcast it to all
        # pipe shards (out_specs treats the pipe axis as replicated)
        outs = jax.lax.psum(
            jnp.where(stage_id == S - 1, outs, jnp.zeros_like(outs)), "pipe")
        return outs

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("pipe"), P(None, "data")),
        out_specs=P(None, "data"),
        check_rep=False,
    )
    return fn(stage_params, x)


def bubble_fraction(n_microbatches: int, stages: int) -> float:
    return (stages - 1) / (n_microbatches + stages - 1)
