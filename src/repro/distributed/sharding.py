"""Logical-axis sharding: (axes pytree, ShardPlan, mesh) -> NamedShardings.

Rules (DESIGN.md §6):
  vocab      -> model     (unembed column parallel; vocab padded to %256)
  heads      -> model     (Q heads padded to a TP multiple, zero-masked)
  kv_heads   -> model IF n_kv % tp == 0 else replicated
  mlp        -> model     (column/row parallel FFN)
  expert     -> model IF n_experts % tp == 0 else replicated (TP inside expert)
  embed      -> data      (FSDP/ZeRO param sharding; XLA all-gathers per use)
  batch      -> (pod, data)
  cache_seq  -> model     (decode KV cache sequence sharding; softmax/contraction
                           over the sharded axis lowers to all-reduces)
  vocab_in   -> replicated (embedding table gather stays local)

Every mapping is divisibility-guarded against the actual dim, so odd sizes
degrade to replication instead of failing to compile.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _is_axes(x):
    return isinstance(x, tuple) or x is None


def _resolve(logical: str, plan, cfg) -> Optional[Any]:
    if logical is None:
        return None
    if logical == "batch":
        return tuple(plan.batch_axes) if plan.batch_axes else None
    if logical == "vocab_in":
        return None
    if logical == "kv_heads":
        return "model" if (cfg is not None and plan.shard_kv(cfg.n_kv_heads)) else None
    if logical == "expert":
        return "model" if (cfg is not None and plan.shard_experts(cfg.n_experts)) else None
    if logical == "cache_seq":
        return "model"
    return plan.axis_for(logical)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def pspec_for(shape, axes, plan, mesh: Mesh, cfg=None) -> P:
    """PartitionSpec for one array, with divisibility + duplicate-axis guards."""
    if axes is None:
        return P()
    assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
    used: set[str] = set()
    out = []
    for dim, logical in zip(shape, axes):
        entry = _resolve(logical, plan, cfg)
        if entry is None:
            out.append(None)
            continue
        flat = entry if isinstance(entry, tuple) else (entry,)
        if any(a in used for a in flat):
            out.append(None)  # mesh axis already consumed by an earlier dim
            continue
        if dim % _axis_size(mesh, entry) != 0:
            out.append(None)  # not divisible -> replicate
            continue
        used.update(flat)
        out.append(entry)
    return P(*out)


def shardings_for(tree, axes_tree, plan, mesh: Mesh, cfg=None):
    """NamedSharding pytree for (params-like tree, parallel axes tree).

    ``tree`` may hold arrays or ShapeDtypeStructs (dry-run path).
    """
    def one(x, ax):
        return NamedSharding(mesh, pspec_for(x.shape, ax, plan, mesh, cfg))

    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: _is_axes(x) if x is not tree else False)


def tree_shardings(tree, axes_tree, plan, mesh: Mesh, cfg=None):
    """Like shardings_for but walks the two trees in lockstep explicitly
    (axes leaves are tuples/None, which jax.tree.map would descend into)."""
    if isinstance(tree, dict):
        return {k: tree_shardings(tree[k], axes_tree[k], plan, mesh, cfg)
                for k in tree}
    if isinstance(tree, (list,)):
        return [tree_shardings(t, a, plan, mesh, cfg)
                for t, a in zip(tree, axes_tree)]
    if _is_axes(axes_tree) and hasattr(tree, "shape"):
        return NamedSharding(mesh, pspec_for(tree.shape, axes_tree, plan, mesh, cfg))
    raise TypeError(f"mismatched trees: {type(tree)} vs {type(axes_tree)}")


def mesh_context(mesh: Mesh):
    """Portable ``with mesh:`` context across jax versions.

    ``jax.set_mesh`` only exists on newer jax; on older releases the Mesh
    object is itself the context manager that installs the global mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """Sharding for host->device staging of a dim-0-batched array."""
    return NamedSharding(mesh, P(axis))


def data_parallel(fn, mesh: Mesh, axis: str = "data"):
    """shard_map-wrap ``fn(params, batch) -> out`` over the mesh's data axis.

    The serve-engine layout (DESIGN.md §7): params replicated (P() prefix
    spec), dim 0 of every batch input and output sharded across ``axis`` —
    each device runs the per-shard forward on its slice of the co-batched
    requests, the direct analogue of the paper's §II-A independent kernel
    windows on parallel SOT-MRAM sub-arrays.  ``fn`` must be per-sample
    independent (no cross-batch reductions); the serve forwards guarantee
    that (per-sample norm statistics, per-request KV caches).

    The dispatched batch must be divisible by the axis size — the engine's
    padding buckets guarantee it (`_pad_to` rounds up to the device count).
    """
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, in_specs=(P(), P(axis)),
                     out_specs=P(axis), check_rep=False)


def batch_pspec(plan, ndim: int, batch_dim: int = 0) -> P:
    spec = [None] * ndim
    spec[batch_dim] = tuple(plan.batch_axes) if plan.batch_axes else None
    return P(*spec)


def batch_shardings(batch_tree, plan, mesh: Mesh):
    """Shard dim 0 of every leaf over the batch axes (divisibility-guarded)."""
    def one(x):
        bax = tuple(plan.batch_axes) if plan.batch_axes else None
        if bax is None or x.ndim == 0 or x.shape[0] % _axis_size(mesh, bax) != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(bax, *([None] * (x.ndim - 1))))

    return jax.tree.map(one, batch_tree)
