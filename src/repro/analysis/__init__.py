"""Static verification: plan prover + repro-lint (DESIGN.md §12).

Two entry points, also exposed as ``python -m repro.analysis``:

* :func:`verify_plan` / :func:`verify_plan_file` — interval/bit-range
  abstract interpretation over a compiled :class:`~repro.core.plan.ModelPlan`
  (PV101–PV107), run by default inside ``compile_model``/``compile_lm``.
* :func:`lint_paths` — the RL001–RL005 AST rule engine.

The lint half is import-light (stdlib ``ast`` only) so it runs in
environments without jax; the prover half imports the plan IR lazily.
"""
from repro.analysis.lint import (RULES, LintViolation, lint_file,  # noqa: F401
                                 lint_paths, lint_source)


def __getattr__(name):
    # prover symbols resolve lazily so `import repro.analysis` (and the
    # lint CLI) never pays the jax import
    if name in ("verify_plan", "verify_plan_file", "assert_plan_verified",
                "PlanVerificationError", "Violation"):
        from repro.analysis import prover

        return getattr(prover, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["RULES", "LintViolation", "lint_file", "lint_paths",
           "lint_source", "verify_plan", "verify_plan_file",
           "assert_plan_verified", "PlanVerificationError", "Violation"]
