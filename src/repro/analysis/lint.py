"""repro-lint: an AST rule engine for repo-specific invariants.

Rules ruff cannot express because they encode *this* codebase's contracts
(DESIGN.md §12):

* **RL001** — no wall-clock/ambient randomness in ``src/repro/resilience/``
  (the fault-clock code) or ``src/repro/fleet/`` (the intermittency
  simulator): ``time.time``/``time_ns``, stdlib ``random``,
  ``datetime.now`` and unseeded ``np.random`` calls all break the
  determinism contract that chaos runs and fleet studies are pure
  functions of (seed, mtbf/trace specs, submit order) on the logical
  work clock.
* **RL002** — no host syncs on traced values in ``src/repro``:
  ``float(jnp...)`` / ``int(jnp...)``, ``.item()``, ``np.asarray(jnp...)``
  force a device round trip; inside jitted serve dataflow they either
  fail to trace or silently serialize the pipeline.
* **RL003** — no broad ``except Exception``/``BaseException``/bare
  ``except`` that swallows without a ``raise``.  A non-raising handler
  must either narrow the exception type or record the failure and carry an
  inline suppression stating why swallowing is the contract.
* **RL004** — every ``pl.pallas_call`` with a literal ``grid=`` tuple must
  give each ``pl.BlockSpec`` index-map lambda exactly ``len(grid)``
  parameters, returning a tuple of the block-shape's rank (a mismatched
  arity fails at trace time on TPU only — off-TPU interpret mode can mask
  it).
* **RL005** — engine-private state (underscore attributes of a
  non-``self`` object) is mutated only by its owner in
  ``launch/engine.py`` / ``resilience/engine.py``: the engines are
  single-threaded by contract and external writes to ``engine._pending``
  et al. bypass the accounting that the resilience checkpoints replay.

Suppression: append ``# repro-lint: disable=RL00X`` (comma list allowed)
to the offending line; ``# repro-lint: disable-file=RL00X`` in the first
ten lines silences a rule for the whole file.  Every suppression should
say why.  CLI: ``python -m repro.analysis lint [paths...]``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

RULES = {
    "RL001": "no wall-clock / ambient randomness in resilience/fleet "
             "fault-clock code",
    "RL002": "no host sync (float()/int()/.item()/np.asarray) on traced jnp values",
    "RL003": "no broad except that swallows without re-raise or recorded reason",
    "RL004": "pallas_call grid / BlockSpec index-map arity consistency",
    "RL005": "engine-private state mutated only by its owning engine",
}

# matched anywhere after a '#' on the line, so the pragma can ride along
# other tags ('# noqa: BLE001  repro-lint: disable=RL003 — why')
_SUPPRESS_LINE = re.compile(r"#.*repro-lint:\s*disable=([A-Za-z0-9_,]+)")
_SUPPRESS_FILE = re.compile(r"#.*repro-lint:\s*disable-file=([A-Za-z0-9_,]+)")

# RL001 allow-list: explicitly seeded constructors (call must pass a seed
# argument — checked at the call site).
_SEEDED_CTORS = {"RandomState", "default_rng", "Generator", "PRNGKey"}

# RL005: container methods that mutate their receiver.
_MUTATORS = {"append", "appendleft", "extend", "update", "insert", "add",
             "remove", "discard", "pop", "popleft", "popitem", "clear",
             "setdefault"}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule} {self.message}"


def _dotted(node) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_jnp(node) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "jnp"
               for n in ast.walk(node))


# ---------------------------------------------------------------------------
# Rule checkers: (tree, rel) -> iterator of (node, message)
# ---------------------------------------------------------------------------

def _rl001(tree, rel):
    # fault-clock code AND the fleet simulator: a fleet study is a pure
    # function of (fleet seed, trace specs), same contract as chaos runs
    if not rel.startswith(("src/repro/resilience/", "src/repro/fleet/")):
        return
    banned_calls = {"time.time", "time.time_ns", "time.monotonic",
                    "datetime.now", "datetime.utcnow",
                    "datetime.datetime.now", "datetime.datetime.utcnow"}
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            mod = getattr(node, "module", None)
            names = [a.name for a in node.names]
            if mod == "random" or "random" in names:
                yield node, ("stdlib random imported — fault schedules "
                             "must come from a seeded np.random.RandomState")
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name is None:
            continue
        if name in banned_calls:
            yield node, (f"{name}() breaks the determinism contract: "
                         "chaos is a pure function of (seed, mtbf, submit "
                         "order) on the logical work clock")
        elif name.startswith("random."):
            yield node, (f"{name}() draws from ambient stdlib RNG state — "
                         "use the seeded fault-plan RandomState")
        elif (name.startswith(("np.random.", "numpy.random."))):
            leaf = name.rsplit(".", 1)[1]
            if leaf not in _SEEDED_CTORS:
                yield node, (f"{name}() uses the global numpy RNG — "
                             "construct a seeded RandomState instead")
            elif not (node.args or node.keywords):
                yield node, (f"{name}() without a seed argument is "
                             "entropy-seeded — pass the fault-plan seed")


def _rl002(tree, rel):
    if not rel.startswith("src/repro/"):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and node.args and _mentions_jnp(node.args[0])):
            yield node, (f"{node.func.id}() on a jnp expression is a host "
                         "sync — inside jit it fails to trace; outside it "
                         "serializes the pipeline.  Keep the value traced "
                         "or suppress if provably pre-jit")
        name = _dotted(node.func)
        if (name in ("np.asarray", "np.array", "numpy.asarray",
                     "numpy.array")
                and node.args and _mentions_jnp(node.args[0])):
            yield node, ("np.asarray on a jnp expression forces a device "
                         "round trip — keep serve dataflow traced")
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args
                and not node.keywords):
            yield node, (".item() is a host sync — keep the value traced "
                         "or suppress if provably pre-jit")


def _broad_handler(handler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = [_dotted(e) for e in t.elts] if isinstance(t, ast.Tuple) \
        else [_dotted(t)]
    return any(n in ("Exception", "BaseException") for n in names)


def _rl003(tree, rel):
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler) or not _broad_handler(node):
            continue
        if any(isinstance(n, ast.Raise)
               for stmt in node.body for n in ast.walk(stmt)):
            continue
        yield node, ("broad except swallows without re-raise — narrow the "
                     "exception type, re-raise, or record the failure and "
                     "suppress with the reason")


def _rl004(tree, rel):
    if not rel.startswith("src/"):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name is None or not name.endswith("pallas_call"):
            continue
        kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}
        grid = kwargs.get("grid")
        if grid is None:
            continue
        if isinstance(grid, ast.Tuple):
            g = len(grid.elts)
        elif isinstance(grid, ast.Constant) and isinstance(grid.value, int):
            g = 1
        else:
            continue  # computed grid: not statically decidable
        for spec_kw in ("in_specs", "out_specs"):
            holder = kwargs.get(spec_kw)
            if holder is None:
                continue
            for spec in ast.walk(holder):
                if not (isinstance(spec, ast.Call)
                        and (_dotted(spec.func) or "").endswith("BlockSpec")):
                    continue
                skw = {kw.arg: kw.value for kw in spec.keywords if kw.arg}
                shape = spec.args[0] if spec.args else skw.get("block_shape")
                imap = (spec.args[1] if len(spec.args) > 1
                        else skw.get("index_map"))
                if not isinstance(imap, ast.Lambda):
                    continue
                la = imap.args
                if la.vararg or la.kwarg:
                    continue
                arity = len(la.args) + len(la.posonlyargs)
                if arity != g:
                    yield spec, (f"BlockSpec index map takes {arity} "
                                 f"argument(s) but the pallas_call grid "
                                 f"has rank {g} — trace-time failure on "
                                 "TPU")
                elif (isinstance(imap.body, ast.Tuple)
                        and isinstance(shape, ast.Tuple)
                        and len(imap.body.elts) != len(shape.elts)):
                    yield spec, (f"BlockSpec index map returns "
                                 f"{len(imap.body.elts)} coordinate(s) for "
                                 f"a rank-{len(shape.elts)} block shape")


def _rl005(tree, rel):
    if rel not in ("src/repro/launch/engine.py",
                   "src/repro/resilience/engine.py"):
        return
    msg = ("mutates engine-private state outside the owning engine — the "
           "single-threaded ownership contract (DESIGN.md §7/§11) keeps "
           "checkpoint replay consistent; route through an engine method")

    def _foreign_private(attr_node) -> bool:
        """True for `<non-self>._name`."""
        return (isinstance(attr_node, ast.Attribute)
                and attr_node.attr.startswith("_")
                and not attr_node.attr.startswith("__")
                and not (isinstance(attr_node.value, ast.Name)
                         and attr_node.value.id in ("self", "cls")))

    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target] if isinstance(node, ast.AugAssign)
                       else node.targets)
            for t in targets:
                base = t.value if isinstance(t, ast.Subscript) else t
                if _foreign_private(base):
                    yield node, msg
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in _MUTATORS
              and _foreign_private(node.func.value)):
            yield node, msg


_CHECKERS = {"RL001": _rl001, "RL002": _rl002, "RL003": _rl003,
             "RL004": _rl004, "RL005": _rl005}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _parse_suppressions(source: str):
    """(file-level set, {line: set}) of disabled rule IDs."""
    per_line: dict[int, set] = {}
    file_level: set = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_LINE.search(text)
        if m:
            per_line[i] = {r.strip() for r in m.group(1).split(",")
                           if r.strip()}
        m = _SUPPRESS_FILE.search(text)
        if m and i <= 10:
            file_level |= {r.strip() for r in m.group(1).split(",")
                           if r.strip()}
    return file_level, per_line


def lint_source(source: str, rel: str, path: str | None = None
                ) -> list[LintViolation]:
    """Lint one file's source.  ``rel`` is the repo-relative posix path the
    rule scoping keys on; ``path`` is what violations display."""
    path = path or rel
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [LintViolation(path, e.lineno or 0, e.offset or 0, "RL000",
                              f"syntax error: {e.msg}")]
    file_sup, line_sup = _parse_suppressions(source)
    out = []
    for rule, checker in sorted(_CHECKERS.items()):
        if rule in file_sup:
            continue
        for node, message in checker(tree, rel):
            line = getattr(node, "lineno", 0)
            if rule in line_sup.get(line, ()):
                continue
            out.append(LintViolation(path, line,
                                     getattr(node, "col_offset", 0) + 1,
                                     rule, message))
    return sorted(out, key=lambda v: (v.path, v.line, v.col, v.rule))


def lint_file(path: str, root: str | None = None) -> list[LintViolation]:
    root = root or os.getcwd()
    rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        source = f.read()
    return lint_source(source, rel, path)


def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith((".", "__pycache__")))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def lint_paths(paths, root: str | None = None) -> list[LintViolation]:
    out = []
    for path in iter_py_files(paths):
        out.extend(lint_file(path, root))
    return out
