"""Plan prover: static bit-range verification of a compiled ModelPlan.

:func:`verify_plan` runs interval abstract interpretation (see
:mod:`repro.analysis.intervals`) over every (layer x batch_hint x engine)
row of a :class:`repro.core.plan.ModelPlan` and proves, ahead of the first
dispatch, the contracts the kernels assume:

* **PV101** — every float-unit integer dot fits the fp32 mantissa
  (``f32dot``, off-TPU ``implicit`` group products, flash centered-level
  score dots).  This subsumes the runtime ``ValueError`` guards in
  ``core/and_accum.bitgemm_f32dot`` and ``kernels/attn_flash.attn_flash_xla``
  and the feasibility reasons in ``kernels/ops.engine_feasible`` — those
  stay as defense-in-depth assertions the prover has already discharged.
* **PV102** — int32 accumulator, rowsum, and zero-point-correction
  magnitudes cannot overflow on the integer-accumulating engines.
* **PV103** — every serialized engine verdict is feasible per
  ``ops.engine_feasible`` / ``ops.attn_engine_feasible`` on the plan's
  backend (a hand-edited or bit-rotted row fails here, not at serve time).
* **PV104** — dispatch-table completeness/consistency: every dense row has
  its ``dense_plan_key`` entry (and agrees with it), every attention row
  its ``attn_table`` verdict, no orphan table entries.
* **PV105** — cost-annotation sanity: finite, non-negative, and strictly
  positive energy/cycles on quantized rows.
* **PV106** — serialization invariants: plan metadata survives a JSON
  round trip with an identical fingerprint (and, for
  :func:`verify_plan_file`, the on-disk metadata IS the reloaded plan's).
* **PV107** — structural invariants: version, batch hints, per-layer
  engine tables, conv GEMM-depth consistency.
* **PV108** — paged-attention feasibility: every paged verdict (10-tuple
  key, see ``ops.attn_plan_key``) proves its page geometry via
  ``ops.paged_attn_bounds`` at the plan's largest batch hint — page size
  tiles the table extent, the flat KV gather index stays in int32, and
  one grid step (q block + one KV page + scratch) fits the VMEM budget.

Wired into ``compile_model`` / ``compile_lm`` (on by default,
``verify=False`` escape hatch) and the ``python -m repro.analysis
check-plan`` CLI for saved artifacts.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

from repro.analysis.intervals import (FP32_MANTISSA, INT32_MAX, Interval,
                                      centered_range, dot_range, level_range)
from repro.core.plan import PlanError

# Engines that accumulate integer products in an int32 register (directly
# or as folded nibble-split partials summing to the same total).
_INT_ACC_ENGINES = frozenset(
    {"int8", "int8_planewise", "fused", "faithful", "planes", "packed"})

# The attention path quantizes q/k at 8 bits regardless of QuantConfig
# (kernels/attn_flash.attn_quant_scale); the prover mirrors that constant.
_ATTN_BITS = 8


@dataclasses.dataclass(frozen=True)
class Violation:
    """One failed proof obligation."""

    rule: str       # "PV101".."PV108"
    where: str      # plan coordinates: layer/batch/engine or table key
    message: str

    def __str__(self) -> str:
        return f"{self.rule} [{self.where}] {self.message}"


class PlanVerificationError(PlanError):
    """A compiled or reloaded plan failed static verification.

    Subclasses :class:`repro.core.plan.PlanError` so every existing
    ``except PlanError`` call site catches prover rejections too.
    """

    def __init__(self, violations):
        self.violations = tuple(violations)
        lines = "\n".join(f"  {v}" for v in self.violations)
        super().__init__(
            f"plan failed static verification "
            f"({len(self.violations)} violation(s)):\n{lines}\n"
            "(recompile the plan, or pass verify=False to bypass "
            "at your own risk)")


def _group_bits(bits: int) -> int:
    """Operand group width of the off-TPU implicit direct conv (mirrors
    ``kernels/conv_implicit._group_max``: whole operand up to 7 bits,
    4-bit nibble groups beyond)."""
    return bits if bits <= 7 else 4


def _check_exactness(lp, batch: int, engine: str, backend: str, where: str,
                     out) -> None:
    """PV101/PV102 for one (layer, batch_hint, engine) row."""
    a, w, k = level_range(lp.a_bits), level_range(lp.w_bits), lp.k
    if lp.op == "attn":
        if lp.fp:
            return
        lv = centered_range(_ATTN_BITS)
        acc = dot_range(lv, lv, k)
        if engine == "flash" and not acc.within(FP32_MANTISSA):
            out.append(Violation(
                "PV101", where,
                f"flash centered-level score dot reaches |{acc.mag}| at "
                f"head_dim={k} — exceeds the fp32 mantissa "
                f"(2^24 = {FP32_MANTISSA}); the attn_flash_xla runtime "
                "guard would raise on the first call"))
        # rowsum-corrected integer form: acc - z_k*rs_q - z_q*rs_k
        # + hd*z_q*z_k with unsigned 8-bit levels and z = 2^7
        ulv = level_range(_ATTN_BITS)
        z = Interval(1 << (_ATTN_BITS - 1), 1 << (_ATTN_BITS - 1))
        rs = ulv.scale(k)
        corr = dot_range(ulv, ulv, k) - z * rs - z * rs + (z * z).scale(k)
        if corr.mag > INT32_MAX:
            out.append(Violation(
                "PV102", where,
                f"attention zero-point correction reaches |{corr.mag}| at "
                f"head_dim={k} — overflows int32"))
        return
    if engine in ("fp", ""):
        return
    if engine == "f32dot":
        acc = dot_range(a, w, k)
        if not acc.within(FP32_MANTISSA):
            out.append(Violation(
                "PV101", where,
                f"f32dot accumulator reaches {acc.hi} at K={k}, "
                f"a_bits={lp.a_bits}, w_bits={lp.w_bits} — exceeds the "
                f"fp32 mantissa (2^24 = {FP32_MANTISSA}); the "
                "bitgemm_f32dot runtime guard would raise on the first "
                "call"))
    elif engine == "implicit" and backend != "tpu":
        ga, gw = level_range(_group_bits(lp.a_bits)), level_range(
            _group_bits(lp.w_bits))
        acc = dot_range(ga, gw, k)
        if not acc.within(FP32_MANTISSA):
            out.append(Violation(
                "PV101", where,
                f"off-TPU implicit group product reaches {acc.hi} at "
                f"K={k}, a_bits={lp.a_bits}, w_bits={lp.w_bits} — exceeds "
                f"the fp32 mantissa (2^24 = {FP32_MANTISSA})"))
    if engine in _INT_ACC_ENGINES or (engine == "implicit"
                                      and backend == "tpu"):
        acc = dot_range(a, w, k)
        if acc.mag > INT32_MAX:
            out.append(Violation(
                "PV102", where,
                f"integer accumulator reaches {acc.hi} at K={k}, "
                f"a_bits={lp.a_bits}, w_bits={lp.w_bits} — overflows "
                "int32"))
        rowsum = a.scale(k)
        if rowsum.mag > INT32_MAX:
            out.append(Violation(
                "PV102", where,
                f"activation rowsum reaches {rowsum.hi} at K={k}, "
                f"a_bits={lp.a_bits} — the dequant epilogue's int32 "
                "rowsum overflows"))


def _check_feasibility(lp, batch: int, engine: str, backend: str, where: str,
                       out) -> None:
    """PV103 for one (layer, batch_hint, engine) row."""
    from repro.kernels import ops

    if engine == "fp":
        return
    conv = None
    m = batch
    if lp.op == "conv":
        conv = ops.ConvShape(lp.in_h, lp.in_w, lp.kh, lp.kw, lp.stride,
                             lp.padding, batch=batch)
        m = conv.m
    if lp.op == "attn":
        return  # attention verdicts are checked through the attn_table
    ok, reason = ops.engine_feasible(engine, m, lp.k, lp.cout, lp.a_bits,
                                     lp.w_bits, backend, conv)
    if not ok:
        out.append(Violation(
            "PV103", where,
            f"serialized engine {engine!r} is infeasible on backend "
            f"{backend!r}: {reason}"))


def _check_tables(plan, backend: str, out) -> None:
    """PV104 (+ attention PV103): dispatch-table completeness."""
    from repro.core.plan import SIGNED_ENGINES
    from repro.kernels import ops

    dense_rows = [lp for lp in plan.layers if lp.op == "dense"]
    attn_rows = [lp for lp in plan.layers if lp.op == "attn"]
    if plan.kind != "lm":
        return
    seen_dense = set()
    for lp in dense_rows:
        key = ops.dense_plan_key(lp.k, lp.cout, lp.a_bits, lp.w_bits,
                                 backend)
        seen_dense.add(key)
        where = f"layer {lp.index} ({lp.name})"
        if key not in plan.dense_table:
            out.append(Violation(
                "PV104", where,
                f"dense row has no dense_table entry for key {key!r} — "
                "select_engine would fall through to the heuristic at "
                "serve time"))
        elif plan.dense_table[key] != lp.engine:
            out.append(Violation(
                "PV104", where,
                f"dense row pins engine {lp.engine!r} but the dispatch "
                f"table installs {plan.dense_table[key]!r} for its key"))
    for key, eng in sorted(plan.dense_table.items()):
        where = f"dense_table[{key!r}]"
        if eng not in SIGNED_ENGINES:
            out.append(Violation(
                "PV104", where,
                f"table engine {eng!r} is not in the signed serve set "
                f"{SIGNED_ENGINES}"))
        if tuple(key) not in seen_dense:
            out.append(Violation(
                "PV104", where,
                "orphan dense_table entry (no layer row produces this "
                "key)"))
    if len(attn_rows) != len(plan.attn_table):
        out.append(Violation(
            "PV104", "attn_table",
            f"{len(attn_rows)} attention row(s) but "
            f"{len(plan.attn_table)} attn_table verdict(s) — a missing "
            "row dispatches off-plan at serve time"))
    table_engines = set(plan.attn_table.values())
    for lp in attn_rows:
        where = f"layer {lp.index} ({lp.name})"
        if not lp.attn_engine or lp.attn_engine != lp.engine:
            out.append(Violation(
                "PV107", where,
                f"attention row engine {lp.engine!r} does not match its "
                f"attn_engine record {lp.attn_engine!r}"))
        elif lp.engine not in table_engines:
            out.append(Violation(
                "PV104", where,
                f"attention row pins {lp.engine!r} but no attn_table "
                "verdict installs it"))
    for key, eng in sorted(plan.attn_table.items()):
        where = f"attn_table[{key!r}]"
        # contiguous keys are 8-tuples; paged keys append (page_size,
        # seq_kv) — see ops.attn_plan_key
        if len(key) not in (8, 10) or key[0] != "attn":
            out.append(Violation("PV104", where, "malformed attn_plan_key"))
            continue
        if eng not in ops.ATTN_ENGINES:
            out.append(Violation(
                "PV104", where,
                f"unknown attention engine {eng!r} "
                f"(expected one of {ops.ATTN_ENGINES})"))
            continue
        paged = len(key) == 10
        attn = ops.AttnShape(
            seq_q=int(key[1]),
            seq_kv=int(key[9]) if paged else int(key[1]),
            heads=int(key[2]),
            head_dim=int(key[3]), causal=bool(key[4]),
            window=int(key[5]) or None, quantized=bool(key[6]),
            page_size=int(key[8]) if paged else None)
        ok, reason = ops.attn_engine_feasible(eng, attn, str(key[7]))
        if not ok:
            out.append(Violation(
                "PV103", where,
                f"attention verdict {eng!r} is infeasible: {reason}"))
        if paged:
            # PV108: the page-indexed gather must be provably addressable
            # (int32 flat index at the plan's largest batch hint) and one
            # grid step VMEM-resident — an engine built on this plan never
            # discovers an overflowing page table at serve time
            ok, reason = ops.paged_attn_bounds(attn,
                                               batch=max(plan.batch_hints))
            if not ok:
                out.append(Violation(
                    "PV108", where,
                    f"paged-attention geometry infeasible: {reason}"))


def _check_cost(lp, where: str, out) -> None:
    """PV105 for one layer row."""
    cost = tuple(lp.cost or ())
    if not cost:
        if not lp.fp:
            out.append(Violation(
                "PV105", where,
                "quantized row carries no cost annotation (plan compiled "
                "outside _annotate_costs?)"))
        return
    if len(cost) != 3:
        out.append(Violation(
            "PV105", where,
            f"cost annotation has {len(cost)} field(s), expected "
            "(energy_pj, cycles, bytes_moved)"))
        return
    energy, cycles, bytes_moved = (float(c) for c in cost)
    for name, v in (("energy_pj", energy), ("cycles", cycles),
                    ("bytes_moved", bytes_moved)):
        if not math.isfinite(v) or v < 0:
            out.append(Violation(
                "PV105", where, f"cost {name}={v!r} is not a finite "
                "non-negative number"))
            return
    if not lp.fp and (energy <= 0 or cycles <= 0):
        out.append(Violation(
            "PV105", where,
            f"quantized row annotated with energy_pj={energy}, "
            f"cycles={cycles} — zero/negative cost would corrupt the "
            "resilience energy budget and every simulate() report"))


def _check_structure(plan, out) -> None:
    """PV107 plus the PV106 metadata round-trip invariant."""
    from repro.core import plan as P

    if plan.version != P.PLAN_VERSION:
        out.append(Violation(
            "PV107", "plan",
            f"version {plan.version!r} != PLAN_VERSION {P.PLAN_VERSION}"))
    hints = tuple(plan.batch_hints)
    if not hints or any((not isinstance(b, int)) or b < 1 for b in hints):
        out.append(Violation(
            "PV107", "plan",
            f"batch_hints {hints!r} must be non-empty positive ints"))
    elif len(set(hints)) != len(hints):
        out.append(Violation(
            "PV107", "plan", f"duplicate batch_hints {hints!r}"))
    for lp in plan.layers:
        where = f"layer {lp.index} ({lp.name})"
        if lp.op not in ("conv", "dense", "attn"):
            out.append(Violation("PV107", where,
                                 f"unknown layer op {lp.op!r}"))
            continue
        row_hints = tuple(b for b, _ in lp.engines)
        if set(row_hints) != set(hints):
            out.append(Violation(
                "PV107", where,
                f"engine table covers batch hints {row_hints!r}, plan "
                f"declares {hints!r}"))
        elif lp.engine != dict(lp.engines)[row_hints[0]]:
            out.append(Violation(
                "PV107", where,
                f"primary engine {lp.engine!r} disagrees with the engine "
                f"table entry at hint {row_hints[0]}"))
        if lp.op == "conv":
            if lp.fp != (lp.engine == "fp"):
                out.append(Violation(
                    "PV107", where,
                    f"fp={lp.fp} inconsistent with engine {lp.engine!r}"))
            if lp.k != lp.kh * lp.kw * lp.cin:
                out.append(Violation(
                    "PV107", where,
                    f"GEMM depth k={lp.k} != kh*kw*cin = "
                    f"{lp.kh * lp.kw * lp.cin}"))
            if lp.out_h < 1 or lp.out_w < 1:
                out.append(Violation(
                    "PV107", where,
                    f"degenerate output extent {lp.out_h}x{lp.out_w}"))
        if not lp.fp and not (1 <= lp.a_bits <= 32 and 1 <= lp.w_bits <= 32):
            out.append(Violation(
                "PV107", where,
                f"bit widths a_bits={lp.a_bits}, w_bits={lp.w_bits} out "
                "of range [1, 32]"))
    # PV106: metadata must survive a JSON round trip fingerprint-identically
    # (the fingerprint is the serve engine's program-cache key — drift here
    # means a reloaded plan silently misses every compiled program).
    try:
        meta = json.loads(json.dumps(plan.meta(), sort_keys=True))
        rebuilt = P.ModelPlan(
            kind=meta["kind"], model=meta["model"], backend=meta["backend"],
            quant=P.QuantConfig(**meta["quant"]),
            batch_hints=tuple(meta["batch_hints"]),
            layers=tuple(P._layer_from_json(d) for d in meta["layers"]),
            dense_table={tuple(k): v for k, v in meta["dense_table"]},
            attn_table={tuple(k): v for k, v in meta["attn_table"]},
            autotune={tuple(k): (e, t) for k, e, t in meta["autotune"]},
            version=meta["version"])
        if rebuilt.fingerprint() != plan.fingerprint():
            out.append(Violation(
                "PV106", "plan",
                "metadata does not survive a JSON round trip: rebuilt "
                f"fingerprint {rebuilt.fingerprint()} != "
                f"{plan.fingerprint()}"))
    except Exception as e:  # repro-lint: disable=RL003 — recorded as PV106
        out.append(Violation(
            "PV106", "plan",
            f"metadata round trip failed: {type(e).__name__}: {e}"))


def verify_plan(plan, target: str | None = None) -> list[Violation]:
    """Statically verify a compiled plan; returns all violations found.

    ``target`` overrides the backend the proofs are stated against
    (default: the plan's own ``backend``).  Empty list == verified.
    """
    backend = target or plan.backend
    out: list[Violation] = []
    _check_structure(plan, out)
    for lp in plan.layers:
        _check_cost(lp, f"layer {lp.index} ({lp.name})", out)
        if lp.fp and lp.op != "attn":
            continue
        for b, eng in lp.engines:
            where = (f"layer {lp.index} ({lp.name}) batch={b} "
                     f"engine={eng}")
            _check_exactness(lp, b, eng, backend, where, out)
            _check_feasibility(lp, b, eng, backend, where, out)
    _check_tables(plan, backend, out)
    return out


def assert_plan_verified(plan, target: str | None = None) -> None:
    """Raise :class:`PlanVerificationError` unless the plan proves clean."""
    violations = verify_plan(plan, target)
    if violations:
        raise PlanVerificationError(violations)


def verify_plan_file(path: str, target: str | None = None) -> list[Violation]:
    """Verify a serialized plan artifact (``<base>.json`` [+ ``.npz``]).

    Adds the on-disk PV106 obligation: the file's metadata (params payload
    keys aside) must be exactly what the reloaded plan re-serializes to —
    a hand-edited or version-drifted artifact fails here instead of
    serving with a wrong program-cache identity.
    """
    from repro.core.plan import _plan_base, load_plan

    base = _plan_base(os.fspath(path))
    plan = load_plan(base)
    out = verify_plan(plan, target)
    with open(base + ".json") as f:
        ondisk = json.load(f)
    ondisk.pop("params_skel", None)
    ondisk.pop("params_npz", None)
    if (json.dumps(ondisk, sort_keys=True)
            != json.dumps(plan.meta(), sort_keys=True)):
        out.append(Violation(
            "PV106", base + ".json",
            "on-disk metadata differs from the reloaded plan's "
            "re-serialization (hand-edited or drifted artifact)"))
    return out
