"""Integer interval arithmetic for the plan prover (DESIGN.md §12).

The abstract domain is deliberately tiny: closed integer intervals
``[lo, hi]`` with exact (arbitrary-precision) Python int endpoints, plus
the two range constructors the quantized stack actually produces —
unsigned DoReFa levels ``[0, 2^bits - 1]`` and the signed/centered
attention levels ``[-2^(bits-1), 2^(bits-1) - 1]``.  Every bound the
prover states is the interval-semantics consequence of these ranges
propagated through the kernels' integer dataflow, so a proof here is a
proof about every possible input, not a sampled check.
"""
from __future__ import annotations

import dataclasses

# Contract constants the kernels are written against.
FP32_MANTISSA = 1 << 24       # exact-integer ceiling of an fp32 accumulator
INT32_MAX = (1 << 31) - 1     # int32 accumulator / rowsum ceiling


@dataclasses.dataclass(frozen=True)
class Interval:
    """A closed integer interval ``[lo, hi]`` (exact endpoints)."""

    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @property
    def mag(self) -> int:
        """Largest absolute value the interval contains."""
        return max(abs(self.lo), abs(self.hi))

    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __neg__(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def __sub__(self, other: "Interval") -> "Interval":
        return self + (-other)

    def __mul__(self, other: "Interval") -> "Interval":
        c = (self.lo * other.lo, self.lo * other.hi,
             self.hi * other.lo, self.hi * other.hi)
        return Interval(min(c), max(c))

    def scale(self, n: int) -> "Interval":
        """Sum of ``n`` independent values drawn from this interval (the
        reduction axis of a dot product)."""
        n = max(int(n), 1)
        return Interval(self.lo * n, self.hi * n)

    def within(self, bound: int) -> bool:
        """Does every value fit strictly below ``bound`` in magnitude?"""
        return self.mag < bound


def level_range(bits: int) -> Interval:
    """Unsigned DoReFa level range: ``[0, 2^bits - 1]``."""
    return Interval(0, (1 << int(bits)) - 1)


def centered_range(bits: int) -> Interval:
    """Signed/centered level range (attention path, z = 2^(bits-1))."""
    z = 1 << (int(bits) - 1)
    return Interval(-z, z - 1)


def dot_range(a: Interval, w: Interval, k: int) -> Interval:
    """Accumulator range of a depth-``k`` dot of ``a``-by-``w`` products."""
    return (a * w).scale(k)
