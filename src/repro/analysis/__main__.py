"""CLI for the static verification subsystem (DESIGN.md §12).

  python -m repro.analysis lint [paths...]          # RL001–RL005 AST rules
  python -m repro.analysis lint --list-rules
  python -m repro.analysis check-plan <plan.json>...  # PV101–PV107 prover
  python -m repro.analysis check-plan --golden      # compile + verify the
                                                    # golden svhn/alexnet/LM
                                                    # plans in-process

Both subcommands exit nonzero on any violation — the CI ``analysis`` lane
gates on them.
"""
from __future__ import annotations

import argparse
import sys
import tempfile


def _cmd_lint(args) -> int:
    from repro.analysis.lint import RULES, lint_paths

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0
    paths = args.paths or ["src"]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    n = len(violations)
    print(f"repro-lint: {n} violation(s) in {', '.join(paths)}"
          if n else f"repro-lint: clean ({', '.join(paths)})")
    return 1 if n else 0


def _golden_plans(tmp: str):
    """Compile the golden plans (structure-only CNNs + a smoke LM), save
    each, and yield (name, artifact base path) — mirrors the tier-1 golden
    dispatch/bit-identity setups so CI verifies exactly what tests pin."""
    import dataclasses

    import jax

    from repro.configs import SINGLE, all_configs
    from repro.configs.paper_cnn import ALEXNET_SPEC, SVHN_SPEC
    from repro.core.plan import compile_lm, compile_model, save_plan
    from repro.core.quant import W1A4, W1A8
    from repro.models import transformer as T

    for name, spec, img, quant in (("svhn", SVHN_SPEC, 40, W1A4),
                                   ("alexnet", ALEXNET_SPEC, 112, W1A8)):
        plan = compile_model(None, spec, quant, backend="cpu",
                             batch_hints=(1, 8), img_hw=img, model=name)
        yield name, save_plan(plan, f"{tmp}/{name}")
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(W1A8, engine="auto"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    plan = compile_lm(params, cfg, backend="cpu", batch_hints=(2,),
                      prompt_len=8)
    yield "lm-smoke", save_plan(plan, f"{tmp}/lm_smoke")


def _cmd_check_plan(args) -> int:
    from repro.analysis.prover import verify_plan_file

    targets: list[tuple[str, str]] = [(p, p) for p in args.plans]
    fails = 0
    with tempfile.TemporaryDirectory() as tmp:
        if args.golden:
            targets.extend(_golden_plans(tmp))
        if not targets:
            print("check-plan: no plans given (pass paths or --golden)",
                  file=sys.stderr)
            return 2
        for name, path in targets:
            violations = verify_plan_file(path, args.target)
            for v in violations:
                print(f"{name}: {v}")
            status = f"{len(violations)} violation(s)" if violations else "OK"
            print(f"check-plan {name}: {status}")
            fails += bool(violations)
    return 1 if fails else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lint = sub.add_parser("lint", help="run the RL001–RL005 AST rules")
    lint.add_argument("paths", nargs="*", help="files/dirs (default: src)")
    lint.add_argument("--list-rules", action="store_true")
    lint.set_defaults(fn=_cmd_lint)
    chk = sub.add_parser("check-plan",
                         help="verify serialized plan artifacts (PV101–107)")
    chk.add_argument("plans", nargs="*", help="plan .json paths")
    chk.add_argument("--golden", action="store_true",
                     help="compile + verify the golden svhn/alexnet/LM plans")
    chk.add_argument("--target", default=None,
                     help="override the backend the proofs are stated "
                          "against (default: each plan's own)")
    chk.set_defaults(fn=_cmd_check_plan)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
