"""Pallas TPU kernel: paper-faithful packed AND + popcount bit-GEMM.

Dataflow (paper Fig. 3, TPU-adapted per DESIGN.md §2):
  * activations / weights arrive as bit-planes packed 32/lane in uint32
    along the contraction axis K (``Kw = K/32`` words);
  * one grid step loads an (m, TM, TKw) activation tile and an
    (n, TN, TKw) weight tile into VMEM;
  * for every plane pair (m,n): VPU AND -> ``population_count`` (the 4:2
    compressor tree analogue) -> lane-sum -> ``<< (m+n)`` (the ASR
    analogue, a static integer weight) -> accumulate into the int32 out
    tile, revisited across the K grid dimension.

This kernel exists to make the paper's exact dataflow measurable on TPU;
`bitgemm_mxu.py` is the beyond-paper MXU mapping that wins on roofline
(see EXPERIMENTS.md §Perf hillclimb #1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per tile (see DESIGN.md): the (TM, TN, TKw) AND intermediate
# dominates: 64*64*32*4B = 512 KiB, well under ~16 MiB VMEM with
# double-buffered inputs (m,64,32)+(n,64,32) uint32 tiles.
TM, TN, TKW = 64, 64, 32


def _kernel(a_ref, w_ref, o_ref, *, a_bits: int, w_bits: int):
    """a_ref (a_bits, TM, TKw) u32 | w_ref (w_bits, TN, TKw) u32 | o (TM,TN) i32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.zeros((o_ref.shape[0], o_ref.shape[1]), jnp.int32)
    for m in range(a_bits):
        a_pl = a_ref[m]                                # (TM, TKw) uint32
        for n in range(w_bits):
            w_pl = w_ref[n]                            # (TN, TKw) uint32
            anded = a_pl[:, None, :] & w_pl[None, :, :]  # row-parallel AND
            cmp = jax.lax.population_count(anded).astype(jnp.int32)
            acc = acc + (jnp.sum(cmp, axis=-1) << (m + n))
    o_ref[...] += acc


def _pad(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit, static_argnames=("a_bits", "w_bits", "interpret", "tm", "tn", "tkw")
)
def bitgemm_packed_pallas(
    a_planes: jax.Array,  # (a_bits, M, Kw) uint32
    w_planes: jax.Array,  # (w_bits, N, Kw) uint32  (weights pre-transposed)
    *,
    a_bits: int,
    w_bits: int,
    interpret: bool = False,
    tm: int = TM,
    tn: int = TN,
    tkw: int = TKW,
) -> jax.Array:
    """Returns (M, N) int32 == sum_k popcount(a & w) weighted by 2^(m+n)."""
    _, M, Kw = a_planes.shape
    _, N, _ = w_planes.shape
    a_p = _pad(_pad(a_planes, tm, 1), tkw, 2)
    w_p = _pad(_pad(w_planes, tn, 1), tkw, 2)
    Mp, Kwp, Np = a_p.shape[1], a_p.shape[2], w_p.shape[1]
    grid = (Mp // tm, Np // tn, Kwp // tkw)
    out = pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_bits, tm, tkw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((w_bits, tn, tkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=interpret,
    )(a_p, w_p)
    return out[:M, :N]
