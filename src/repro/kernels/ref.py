"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the shape/dtype sweep tests: each kernel
must match its oracle exactly (integer ops) or to float tolerance (the
fused quantize kernel's float scales).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.and_accum import bitgemm_planes


def bitgemm_ref(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int) -> jax.Array:
    """Oracle for both bitgemm kernels: exact Eq. (1) on integer levels."""
    return bitgemm_planes(a_lv.astype(jnp.int32), w_lv.astype(jnp.int32), a_bits, w_bits)


def quantpack_ref(a: jax.Array, bits: int):
    """Oracle for the fused quantize+pack kernel.

    a (M, K) float in R -> (levels (M,K) int32, packed (bits, M, ceil(K/32)) uint32)
    """
    n = (1 << bits) - 1
    levels = jnp.clip(jnp.round(jnp.clip(a, 0.0, 1.0) * n), 0, n).astype(jnp.int32)
    packed = bitplane.decompose_packed(levels, bits, axis=-1)
    return levels, packed


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Oracle for the generic MXU matmul kernel (int8 -> int32 or bf16 -> f32)."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return jnp.dot(a, b, preferred_element_type=jnp.int32)
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
