"""Pallas TPU kernel: fused DoReFa activation quantize + bit-plane pack.

Fuses the EPU Quantizer (paper Fig. 2) with the data-organization step of
Fig. 3: one HBM read of the float activations produces both the integer
levels (for the MXU path) and the packed uint32 bit-planes (for the
faithful AND+popcount path), so the bit-plane layout never round-trips
through HBM unpacked (a 32x traffic saving over quantize-then-pack).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 32
TM, TK = 256, 512  # 256x512 f32 in-tile = 512 KiB VMEM; TK % 32 == 0


def _kernel(a_ref, lv_ref, pk_ref, *, bits: int):
    n = (1 << bits) - 1
    a = jnp.clip(a_ref[...], 0.0, 1.0)
    lv = jnp.clip(jnp.round(a * n), 0, n).astype(jnp.int32)
    lv_ref[...] = lv
    tm, tk = lv.shape
    lanes = lv.reshape(tm, tk // LANE, LANE).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))[None, None, :]
    for b in range(bits):
        plane = jax.lax.shift_right_logical(lanes, jnp.uint32(b)) & jnp.uint32(1)
        pk_ref[b] = jnp.sum(plane * weights, axis=-1, dtype=jnp.uint32)


def _pad(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.jit, static_argnames=("bits", "interpret", "tm", "tk"))
def quantize_pack_pallas(
    a: jax.Array,  # (M, K) float
    *,
    bits: int,
    interpret: bool = False,
    tm: int = TM,
    tk: int = TK,
):
    """Returns (levels (M,K) int32, packed (bits, M, ceil(K/32)) uint32)."""
    M, K = a.shape
    a_p = _pad(_pad(a, tm, 0), tk, 1)
    Mp, Kp = a_p.shape
    grid = (Mp // tm, Kp // tk)
    levels, packed = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tm, tk), lambda i, j: (i, j)),
            pl.BlockSpec((bits, tm, tk // LANE), lambda i, j: (0, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Kp), jnp.int32),
            jax.ShapeDtypeStruct((bits, Mp, Kp // LANE), jnp.uint32),
        ],
        interpret=interpret,
    )(a_p)
    kw = -(-K // LANE)
    return levels[:M, :K], packed[:, :M, :kw]
