"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` for
correctness validation; on TPU they compile natively. The wrappers also
own layout plumbing: bit-plane packing for the faithful kernel and
nibble-splitting for >7-bit operands on the MXU kernel.

Engine selection is layered (DESIGN.md §8): :func:`select_engine` first
consults an installed :class:`repro.core.plan.ModelPlan` dense-GEMM table,
then the measured-autotune cache, and only then falls back to the pure
heuristic :func:`cost_model_engine` — so a compiled plan turns every
per-call dispatch decision into a table lookup.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.and_accum import (_nibble_split, dequant_epilogue,
                                  f32dot_exact, quant_dense_pre_levels)
from .bitgemm import bitgemm_packed_pallas
from .bitgemm_mxu import int8_matmul_pallas
from .conv_implicit import (conv_implicit_pallas, conv_implicit_xla,
                            implicit_xla_exact)
from .fused_qgemm import fused_qgemm_pallas
from .quantpack import quantize_pack_pallas


def _interpret() -> bool:
    # the kernels use TPU memory spaces; interpret everywhere else (CPU/GPU)
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Engine dispatch — backend/shape-aware selection of the serve GEMM path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvShape:
    """Static conv geometry (including batch) for engine selection.

    ``batch`` entered in PR 3: the serving engine coalesces many requests
    into one dispatch, so feasibility and crossover bounds must see the
    whole co-batched problem, not a single image.
    """
    h: int
    w: int
    kh: int
    kw: int
    stride: int
    padding: str
    batch: int = 1

    @property
    def out_hw(self) -> tuple[int, int]:
        from repro.core.conv_lowering import _out_hw
        return _out_hw(self.h, self.w, self.kh, self.kw, self.stride,
                       self.padding)

    @property
    def m(self) -> int:
        """GEMM rows of the whole batched problem: batch * oh * ow."""
        oh, ow = self.out_hw
        return self.batch * oh * ow

    @property
    def read_amplification(self) -> float:
        """im2col HBM blowup: patch elements per input element (~kh*kw).

        A per-image ratio — batch scales patch and input bytes alike."""
        oh, ow = self.out_hw
        return self.kh * self.kw * oh * ow / max(self.h * self.w, 1)

    def padded_image_elems(self, cin: int) -> int:
        """Elements of ONE image plane as the implicit kernel stages it in
        VMEM (SAME-padded); the kernel is resident once per batch index, so
        this bound is per-image regardless of batch."""
        from repro.core.conv_lowering import pad_split
        (pt, pb), (pl, pr) = pad_split(self.h, self.w, self.kh, self.kw,
                                       self.stride, self.padding)
        return (self.h + pt + pb) * (self.w + pl + pr) * cin


@dataclasses.dataclass(frozen=True)
class AttnShape:
    """Static attention geometry for engine selection.

    The attention analogue of :class:`ConvShape`: everything the dispatch
    decision needs, nothing data-dependent.  ``quantized`` marks a serve
    path whose projections already run on integer levels — only then may
    the (approximating) quantized flash kernel be dispatched;
    ``banded_ok`` mirrors ``ArchConfig.banded_attn`` (the block-diagonal
    realization can be disabled for analysis runs).
    """
    seq_q: int
    seq_kv: int
    heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None
    batch: int = 1
    quantized: bool = False
    banded_ok: bool = True
    # Paged KV geometry (continuous-batching serve path): None = contiguous
    # KV; an int makes this a page-table dispatch — seq_kv is then the
    # table extent (table width * page_size), the per-slot KV capacity.
    page_size: int | None = None


# Attention engines: all realized off-TPU (full/chunked/banded are plain
# XLA; flash has an exact XLA realization, paged a gather realization), so
# none are backend-gated the way PALLAS_ENGINES are.
ATTN_ENGINES = ("full", "chunked", "banded", "flash", "paged")

# VMEM budget for one paged-attention grid step (q block + one KV page +
# online-softmax scratch), bytes.  Conservative half of a v4/v5 core's
# 16 MiB VMEM — the other half covers double-buffered pipelining.
PAGED_VMEM_BUDGET = 8 * 1024 * 1024


def attn_plan_key(attn: "AttnShape", backend: str) -> tuple:
    """Plan-table key for an attention dispatch.

    Unlike :func:`dense_plan_key` this keeps the sequence length: the
    engine crossover is *about* S.  Batch is dropped — the serving engine
    re-buckets batch per dispatch, and every engine verdict is
    batch-monotone (a bigger batch only favors the tiled engines more).

    Paged dispatches extend the key with (page_size, seq_kv) — a 10-tuple
    where contiguous keys stay 8-tuples — because the paged program is
    shaped by the page geometry, not just the query side.
    """
    key = ("attn", attn.seq_q, attn.heads, attn.head_dim,
           bool(attn.causal), attn.window or 0, bool(attn.quantized),
           backend)
    if attn.page_size:
        key = key + (attn.page_size, attn.seq_kv)
    return key


def paged_attn_bounds(attn: "AttnShape", batch: int = 1) -> tuple[bool, str]:
    """Static feasibility bounds for the paged engine (PV108's predicate).

    (1) the page size must tile the table extent exactly (the table is
    ``seq_kv / page_size`` whole pages); (2) the flat KV pool index
    ``batch * seq_kv * heads * head_dim`` must stay addressable in int32
    (the gather/scatter index dtype); (3) one grid step's VMEM residency
    (q block + one KV page + scratch, f32) must fit PAGED_VMEM_BUDGET.
    """
    ps = attn.page_size
    if not ps or ps < 1:
        return False, "paged needs a positive page_size"
    if attn.seq_kv % ps != 0:
        return False, (f"page_size={ps} does not tile the table extent "
                       f"seq_kv={attn.seq_kv}")
    flat = batch * attn.seq_kv * attn.heads * attn.head_dim
    if flat >= (1 << 31):
        return False, (f"flat KV index {flat} overflows int32 "
                       f"(batch={batch}, seq_kv={attn.seq_kv})")
    step_bytes = 4 * (attn.seq_q * attn.heads * attn.head_dim    # q block
                      + 2 * ps * attn.heads * attn.head_dim      # k+v page
                      + attn.heads * attn.seq_q * (256 + attn.head_dim))
    if step_bytes > PAGED_VMEM_BUDGET:
        return False, (f"paged grid step needs {step_bytes} B VMEM "
                       f"(> {PAGED_VMEM_BUDGET})")
    return True, ""


def attn_engine_feasible(engine: str, attn: "AttnShape",
                         backend: str | None = None) -> tuple[bool, str]:
    """Can ``engine`` legally realize this attention geometry?

    Mirrors :func:`engine_feasible` for the attention engine set; used by
    plan compilation to validate overrides before pinning them.
    """
    from repro.kernels.attn_flash import flash_levels_exact

    if engine == "banded":
        if not attn.window:
            return False, "banded is the sliding-window realization (no window here)"
        return True, ""
    if engine == "flash":
        if not attn.quantized:
            return False, ("flash consumes level-quantized q/k; dispatching"
                           " it on an unquantized path would change numerics")
        if attn.seq_q <= 1:
            return False, "flash tiles over q blocks (decode steps stay full)"
        if not flash_levels_exact(attn.head_dim, 8, 8):
            return False, (f"flash score dot inexact at head_dim="
                           f"{attn.head_dim} (exceeds the fp32 mantissa)")
        return True, ""
    if engine == "paged":
        ok, why = paged_attn_bounds(attn, batch=max(attn.batch, 1))
        if not ok:
            return False, why
        if attn.quantized and not flash_levels_exact(attn.head_dim, 8, 8):
            return False, (f"paged score dot inexact at head_dim="
                           f"{attn.head_dim} (exceeds the fp32 mantissa)")
        return True, ""
    if engine in ATTN_ENGINES:
        ok = attn.page_size is None
        return ok, "" if ok else (f"{engine} is a contiguous-KV engine; "
                                  "page-table geometries dispatch 'paged'")
    return False, f"unknown attention engine {engine!r}"


def select_attn_engine(attn: "AttnShape", backend: str | None = None) -> str:
    """Pick the attention engine, plan table first.

    Resolution order matches :func:`select_engine`: (1) an installed
    ModelPlan's attention table (``compile_lm`` verdicts keyed by
    :func:`attn_plan_key`), (2) the backend target's decision procedure
    (:meth:`repro.api.targets.ComputeTarget.select_attn_engine`).
    """
    from repro.api.targets import target_for_backend

    backend = backend or jax.default_backend()
    hit = _PLAN_TABLE.get(attn_plan_key(attn, backend))
    if hit is not None:
        return hit
    return target_for_backend(backend).select_attn_engine(attn)


# The implicit-engine eligibility bounds and CPU/TPU crossover constants
# (measured, benchmarks/bench_conv.py) moved to the HardwareTarget cost
# tables in repro.api.targets — each ComputeTarget owns the constants its
# select_engine consults; cost_model_engine below delegates there.


# ---------------------------------------------------------------------------
# Plan table + autotune cache: ahead-of-time verdicts consulted by
# select_engine before the heuristic cost model fires.
# ---------------------------------------------------------------------------

# Dense-GEMM verdicts installed by an active ModelPlan (core/plan.py).  Keys
# are :func:`dense_plan_key` tuples; installed/removed by ModelPlan.activate
# or .install.  Per-layer CONV verdicts never go through this table — the
# plan pins them as explicit ``engine=`` arguments on the conv call.
_PLAN_TABLE: dict = {}

# Measured verdicts from autotune passes: key -> (engine, {engine: us}).
# Populated by :func:`autotune_engine`; persisted/restored through plan
# serialization so a restarted node never re-measures.
_AUTOTUNE_CACHE: dict = {}

# Monotonic counter bumped whenever a cached verdict changes; structural
# plan caches (core/plan.py) key on it so stale engine choices never
# survive a plan install/removal or a new autotune measurement.
_DISPATCH_EPOCH = [0]


def dispatch_epoch() -> int:
    return _DISPATCH_EPOCH[0]


def dense_plan_key(k: int, n: int, a_bits: int, w_bits: int,
                   backend: str) -> tuple:
    """Plan-table key for a dense serve GEMM.

    Deliberately ``m``-free: a weight's engine verdict must hold for every
    batch/sequence the server dispatches (off-TPU the heuristic is already
    m-independent — ``f32dot_exact`` depends only on k and the bit widths)
    so one plan entry covers prefill and decode alike.
    """
    return ("dense", k, n, a_bits, w_bits, backend)


def autotune_key(m: int, k: int, n: int, a_bits: int, w_bits: int,
                 backend: str, conv: ConvShape | None) -> tuple:
    if conv is not None:
        return ("conv", conv.h, conv.w, conv.kh, conv.kw, conv.stride,
                conv.padding, conv.batch, k, n, a_bits, w_bits, backend)
    return ("dense", m, k, n, a_bits, w_bits, backend)


def install_plan_table(entries: dict) -> None:
    """Install a ModelPlan's dense engine verdicts (additive)."""
    _PLAN_TABLE.update(entries)
    _DISPATCH_EPOCH[0] += 1


def remove_plan_table(entries: dict) -> None:
    for key in entries:
        _PLAN_TABLE.pop(key, None)
    _DISPATCH_EPOCH[0] += 1


def clear_plan_state() -> None:
    """Drop every installed plan verdict and autotune measurement."""
    _PLAN_TABLE.clear()
    _AUTOTUNE_CACHE.clear()
    _DISPATCH_EPOCH[0] += 1


def select_engine(m: int, k: int, n: int, a_bits: int, w_bits: int,
                  backend: str | None = None,
                  conv: ConvShape | None = None) -> str:
    """Pick the serve engine for an (m, k) x (k, n) quantized GEMM.

    Resolution order: (1) an installed ModelPlan's dense table
    (:func:`install_plan_table`), (2) the measured autotune cache
    (:func:`autotune_engine` verdicts), (3) the pure heuristic
    :func:`cost_model_engine`.  With no plan active and no autotune run,
    this is exactly the heuristic — the no-autotune default.
    """
    backend = backend or jax.default_backend()
    if conv is None:
        hit = _PLAN_TABLE.get(dense_plan_key(k, n, a_bits, w_bits, backend))
        if hit is not None:
            return hit
    tuned = _AUTOTUNE_CACHE.get(autotune_key(m, k, n, a_bits, w_bits,
                                             backend, conv))
    if tuned is not None:
        return tuned[0]
    return cost_model_engine(m, k, n, a_bits, w_bits, backend, conv)


def cost_model_engine(m: int, k: int, n: int, a_bits: int, w_bits: int,
                      backend: str | None = None,
                      conv: ConvShape | None = None) -> str:
    """The pure heuristic cost model (no caches, no measurement).

    Returns one of:
      ``fused``     one-pass Pallas kernel (quantize + MXU matmul + rowsum +
                    dequant epilogue) — the TPU default;
      ``implicit``  implicit-GEMM conv (``conv`` geometry required): patch
                    extraction in-register, no im2col tensor in HBM —
                    Pallas kernel sweep on TPU, exact direct conv off-TPU;
      ``faithful``  the tiled VPU AND+popcount Pallas kernel — wins only
                    for binary, huge-K, skinny-output problems where the
                    32x K compression beats MXU occupancy;
      ``int8``      XLA int8 dot on the levels (nibble-split > 7 bits) —
                    the fallback wherever a Pallas kernel cannot run;
      ``f32dot``    exact float-unit realization — fastest off-TPU, valid
                    while the accumulator fits the fp32 mantissa.

    All five are exact; this is purely a performance decision, so the
    heuristic is deliberately coarse.  When ``conv`` is given its ``batch``
    field makes the bounds batch-aware (the serving engine dispatches
    co-batched buckets): ``m`` must describe the whole batched problem
    (``conv.m``), the CPU crossover scales with it, and the TPU kernel's
    VMEM-residency feasibility stays per-image (the grid revisits VMEM once
    per batch index).

    Since the HardwareTarget registry (repro.api.targets) the decision
    procedure and its crossover constants live on the backend's
    :class:`~repro.api.targets.ComputeTarget` — this function is the
    dispatch-side entry that resolves the backend string to its target.
    """
    from repro.api.targets import target_for_backend

    backend = backend or jax.default_backend()
    return target_for_backend(backend).select_engine(m, k, n, a_bits, w_bits,
                                                     conv)


# ---------------------------------------------------------------------------
# Feasibility + candidates: plan-time validation and autotune enumeration
# ---------------------------------------------------------------------------

# Engines the level-GEMM realization layer accepts everywhere (slow but
# exact on any backend) vs the Pallas kernels that only COMPILE on TPU
# (they still *run* off-TPU under interpret=True, which is a correctness
# harness, not a production engine — plan compilation rejects them there).
PORTABLE_ENGINES = ("planes", "packed", "int8", "int8_planewise", "f32dot")
PALLAS_ENGINES = ("fused", "faithful")


def engine_feasible(engine: str, m: int, k: int, n: int, a_bits: int,
                    w_bits: int, backend: str | None = None,
                    conv: ConvShape | None = None) -> tuple[bool, str]:
    """Can ``engine`` legally realize this problem on ``backend``?

    Returns ``(ok, reason)`` — ``reason`` explains a False verdict in plan
    error messages.  "Feasible" means *production-feasible*: exact AND
    natively compilable.  Pallas kernels off-TPU only interpret (orders of
    magnitude slow), so they are rejected here even though the permissive
    call-time path still accepts them for correctness testing.

    The mantissa bounds below (implicit off-TPU, f32dot) are the same
    contracts the static plan prover re-derives by interval analysis
    (repro.analysis, PV101) — the prover checks every serialized row
    against this function too (PV103), so a verified plan can never
    reach the runtime ``ValueError`` guards behind these reasons.
    """
    from repro.api.targets import IMPLICIT_PADDINGS, IMPLICIT_STRIDES, get_target

    backend = backend or jax.default_backend()
    if engine == "implicit":
        if conv is None:
            return False, "implicit is a conv engine (no conv geometry here)"
        if conv.kh * conv.kw <= 1:
            return False, "1x1 conv has no patch amplification (im2col is the identity)"
        if conv.stride not in IMPLICIT_STRIDES:
            return False, f"stride {conv.stride} unsupported (kernel sweep handles {IMPLICIT_STRIDES})"
        if conv.padding not in IMPLICIT_PADDINGS:
            return False, f"padding {conv.padding!r} unsupported"
        if backend == "tpu":
            from repro.core.prequant import level_dtype

            vmem_bytes = get_target("tpu")["implicit_vmem_bytes"]
            cin = k // max(conv.kh * conv.kw, 1)
            lvl_bytes = jnp.zeros((), level_dtype(a_bits)).dtype.itemsize
            if conv.padded_image_elems(cin) * lvl_bytes > vmem_bytes:
                return False, (
                    f"image levels ({conv.padded_image_elems(cin) * lvl_bytes}"
                    f" B) exceed the {vmem_bytes} B VMEM residency"
                    " budget")
            return True, ""
        if not implicit_xla_exact(k, a_bits, w_bits):
            return False, (
                f"off-TPU direct conv inexact at K={k}, a_bits={a_bits}, "
                f"w_bits={w_bits} (group product exceeds the fp32 mantissa)")
        return True, ""
    if engine in PALLAS_ENGINES:
        if backend != "tpu":
            return False, (f"'{engine}' is a Pallas TPU kernel "
                           f"(interpret-only on {backend})")
        return True, ""
    if engine == "f32dot":
        if not f32dot_exact(k, a_bits, w_bits):
            return False, (
                f"f32dot inexact at K={k}, a_bits={a_bits}, w_bits={w_bits} "
                "(accumulator exceeds the fp32 mantissa)")
        return True, ""
    if engine in PORTABLE_ENGINES:
        return True, ""
    return False, f"unknown engine {engine!r}"


def candidate_engines(m: int, k: int, n: int, a_bits: int, w_bits: int,
                      backend: str | None = None,
                      conv: ConvShape | None = None) -> list[str]:
    """Feasible engines worth timing for this problem, best-guess first.

    The bit-plane loop engines (planes/packed/int8_planewise) are excluded:
    they exist for paper fidelity and are never latency-competitive, so
    timing them would only slow the autotune pass down.
    """
    backend = backend or jax.default_backend()
    order = ("implicit", "fused", "faithful", "f32dot", "int8")
    out = []
    for eng in order:
        if eng == "faithful" and not (a_bits == 1 and w_bits == 1):
            continue  # competitive only for binary operands
        ok, _ = engine_feasible(eng, m, k, n, a_bits, w_bits, backend, conv)
        if ok:
            out.append(eng)
    return out


def _time_engine(fn, *args, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall microseconds for a compiled call."""
    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def autotune_engine(m: int, k: int, n: int, a_bits: int, w_bits: int,
                    backend: str | None = None,
                    conv: ConvShape | None = None,
                    repeats: int = 3) -> tuple[str, dict[str, float]]:
    """MEASURE candidate engines on the live backend; cache the verdict.

    Returns ``(best_engine, {engine: best_us})``.  Dummy integer levels at
    the real problem shape stand in for data (engine latency is
    value-independent).  Verdicts are cached per problem key — a plan
    compile touches each distinct layer shape once, and plan serialization
    persists the cache so a restarted node skips the measurement entirely.
    Only runs when the requested backend IS the live backend (you cannot
    measure a TPU from a CPU host); otherwise falls back to the cost model.
    """
    import numpy as np

    backend = backend or jax.default_backend()
    key = autotune_key(m, k, n, a_bits, w_bits, backend, conv)
    hit = _AUTOTUNE_CACHE.get(key)
    if hit is not None:
        return hit
    heuristic = cost_model_engine(m, k, n, a_bits, w_bits, backend, conv)
    if backend != jax.default_backend():
        return heuristic, {}
    cands = candidate_engines(m, k, n, a_bits, w_bits, backend, conv)
    if len(cands) < 2:
        verdict = (cands[0] if cands else heuristic, {})
        _AUTOTUNE_CACHE[key] = verdict
        _DISPATCH_EPOCH[0] += 1
        return verdict
    rng = np.random.RandomState(0)
    from repro.core.prequant import level_dtype

    w_lv = jnp.asarray(rng.randint(0, (1 << w_bits), size=(k, n)),
                       level_dtype(w_bits))
    s_w = jnp.asarray(2.0 / max((1 << w_bits) - 1, 1), jnp.float32)
    z_w = jnp.asarray(((1 << w_bits) - 1) / 2.0, jnp.float32)
    timings: dict[str, float] = {}
    for eng in cands:
        if conv is not None:
            cin = k // (conv.kh * conv.kw)
            x_lv = jnp.asarray(
                rng.randint(0, (1 << a_bits),
                            size=(conv.batch, conv.h, conv.w, cin)),
                level_dtype(a_bits))
            fn = jax.jit(functools.partial(
                quant_conv_serve, kh=conv.kh, kw=conv.kw, stride=conv.stride,
                padding=conv.padding, a_bits=a_bits, w_bits=w_bits,
                engine=eng))
        else:
            x_lv = jnp.asarray(rng.randint(0, (1 << a_bits), size=(m, k)),
                               level_dtype(a_bits))
            fn = jax.jit(functools.partial(
                quant_dense_serve, a_bits=a_bits, w_bits=w_bits, engine=eng))
        timings[eng] = _time_engine(fn, x_lv, w_lv, s_w, z_w, repeats=repeats)
    best = min(timings, key=timings.get)
    _AUTOTUNE_CACHE[key] = (best, timings)
    _DISPATCH_EPOCH[0] += 1
    return best, timings


def fused_qgemm(a: jax.Array, w_lv: jax.Array, s_w, z_w, *, a_bits: int,
                w_bits: int, a_is_levels: bool = False,
                interpret: bool | None = None) -> jax.Array:
    """Fused serve pipeline kernel (see :mod:`repro.kernels.fused_qgemm`)."""
    interpret = _interpret() if interpret is None else interpret
    return fused_qgemm_pallas(a, w_lv, s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                              a_is_levels=a_is_levels, interpret=interpret)


def quant_dense_serve(a_lv: jax.Array, w_lv: jax.Array, s_w, z_w, *,
                      a_bits: int, w_bits: int,
                      engine: str | None = None) -> jax.Array:
    """Serve dense on pre-quantized operands through the selected engine.

    ``a_lv`` (M, K) integer activation levels; ``w_lv`` (K, N) weight levels.
    ``engine=None`` dispatches via :func:`select_engine`.
    """
    m, k = a_lv.shape
    n = w_lv.shape[1]
    if engine is None:
        engine = select_engine(m, k, n, a_bits, w_bits)
    if engine == "fused":
        return fused_qgemm(a_lv, w_lv, s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                           a_is_levels=True)
    if engine == "faithful":
        acc = bitgemm_faithful(a_lv.astype(jnp.int32), w_lv.astype(jnp.int32),
                               a_bits, w_bits)
        return dequant_epilogue(acc, a_lv, s_w, z_w, a_bits)
    return quant_dense_pre_levels(a_lv, w_lv, s_w, z_w, a_bits, w_bits,
                                  engine=engine)


def quant_conv_serve(x_lv: jax.Array, w_lv: jax.Array, s_w, z_w, *,
                     kh: int, kw: int, stride: int = 1, padding: str = "SAME",
                     a_bits: int, w_bits: int,
                     engine: str | None = None) -> jax.Array:
    """Serve conv on pre-quantized operands through the selected engine.

    ``x_lv`` (B, H, W, Cin) integer activation levels; ``w_lv``
    (kh*kw*Cin, Cout) weight levels in (kh, kw, cin)-major layout.  The
    conv-native entry point: ``engine="implicit"`` never materializes
    patches (Pallas implicit-GEMM sweep on TPU, exact direct conv
    elsewhere); every other engine lowers through ``im2col_sliced`` to
    :func:`quant_dense_serve`.  All engines are bit-identical.
    """
    from repro.core.conv_lowering import _out_hw, im2col_sliced

    b, h, w, cin = x_lv.shape
    cout = w_lv.shape[1]
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    if engine is None:
        engine = select_engine(
            b * oh * ow, kh * kw * cin, cout, a_bits, w_bits,
            conv=ConvShape(h, w, kh, kw, stride, padding, batch=b))
    if engine == "implicit":
        if jax.default_backend() == "tpu":
            return conv_implicit_pallas(
                x_lv, w_lv, s_w, z_w, kh=kh, kw=kw, stride=stride,
                padding=padding, a_bits=a_bits, w_bits=w_bits,
                interpret=False)
        return conv_implicit_xla(
            x_lv, w_lv, s_w, z_w, kh=kh, kw=kw, stride=stride,
            padding=padding, a_bits=a_bits, w_bits=w_bits)
    patches = im2col_sliced(x_lv, kh, kw, stride, padding)
    out = quant_dense_serve(patches.reshape(-1, kh * kw * cin), w_lv,
                            s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                            engine=engine)
    return out.reshape(b, oh, ow, cout)


def bitgemm_faithful(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                     interpret: bool | None = None) -> jax.Array:
    """Paper-faithful kernel path: pack planes, AND+popcount on VPU tiles."""
    interpret = _interpret() if interpret is None else interpret
    a_planes = bitplane.decompose_packed(a_lv, a_bits, axis=-1)      # (m, M, Kw)
    w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)    # (n, N, Kw)
    return bitgemm_packed_pallas(
        a_planes, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=interpret
    )


def bitgemm_mxu(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                interpret: bool | None = None) -> jax.Array:
    """Optimized kernel path: folded int8 MXU matmul (nibble-split >7b)."""
    interpret = _interpret() if interpret is None else interpret
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for ga, sa in _nibble_split(a_lv, a_bits):
        for gw, sw in _nibble_split(w_lv, w_bits):
            d = int8_matmul_pallas(
                ga.astype(jnp.int8), gw.astype(jnp.int8), interpret=interpret
            )
            out = out + (d << (sa + sw))
    return out


def quantize_pack(a: jax.Array, bits: int, interpret: bool | None = None):
    """Fused DoReFa quantize + pack (kernel); returns (levels, planes)."""
    interpret = _interpret() if interpret is None else interpret
    return quantize_pack_pallas(a, bits=bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("a_bits", "w_bits", "path"))
def quant_dense_kernel(a: jax.Array, w: jax.Array, a_bits: int, w_bits: int,
                       path: str = "mxu") -> jax.Array:
    """End-to-end quantized dense on kernels: quantize+pack -> bitgemm -> dequant.

    Mirrors :func:`repro.core.and_accum.quant_dense_forward` but exercises
    the Pallas pipeline. a (..., K) in R; w (K, N).
    """
    from repro.core.quant import weight_levels

    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    a_lv, packed = quantize_pack(a2, a_bits)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    if path == "faithful":
        w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)
        acc = bitgemm_packed_pallas(
            packed, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=_interpret()
        )
    else:
        acc = bitgemm_mxu(a_lv, w_lv, a_bits, w_bits)
    out = dequant_epilogue(acc, a_lv, s_w, z_w, a_bits, a.dtype)
    return out.reshape(lead + (w.shape[-1],))
