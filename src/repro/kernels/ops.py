"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` for
correctness validation; on TPU they compile natively. The wrappers also
own layout plumbing: bit-plane packing for the faithful kernel and
nibble-splitting for >7-bit operands on the MXU kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.and_accum import _nibble_split
from .bitgemm import bitgemm_packed_pallas
from .bitgemm_mxu import int8_matmul_pallas
from .quantpack import quantize_pack_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def bitgemm_faithful(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                     interpret: bool | None = None) -> jax.Array:
    """Paper-faithful kernel path: pack planes, AND+popcount on VPU tiles."""
    interpret = _interpret() if interpret is None else interpret
    a_planes = bitplane.decompose_packed(a_lv, a_bits, axis=-1)      # (m, M, Kw)
    w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)    # (n, N, Kw)
    return bitgemm_packed_pallas(
        a_planes, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=interpret
    )


def bitgemm_mxu(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                interpret: bool | None = None) -> jax.Array:
    """Optimized kernel path: folded int8 MXU matmul (nibble-split >7b)."""
    interpret = _interpret() if interpret is None else interpret
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for ga, sa in _nibble_split(a_lv, a_bits):
        for gw, sw in _nibble_split(w_lv, w_bits):
            d = int8_matmul_pallas(
                ga.astype(jnp.int8), gw.astype(jnp.int8), interpret=interpret
            )
            out = out + (d << (sa + sw))
    return out


def quantize_pack(a: jax.Array, bits: int, interpret: bool | None = None):
    """Fused DoReFa quantize + pack (kernel); returns (levels, planes)."""
    interpret = _interpret() if interpret is None else interpret
    return quantize_pack_pallas(a, bits=bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("a_bits", "w_bits", "path"))
def quant_dense_kernel(a: jax.Array, w: jax.Array, a_bits: int, w_bits: int,
                       path: str = "mxu") -> jax.Array:
    """End-to-end quantized dense on kernels: quantize+pack -> bitgemm -> dequant.

    Mirrors :func:`repro.core.and_accum.quant_dense_forward` but exercises
    the Pallas pipeline. a (..., K) in R; w (K, N).
    """
    from repro.core.quant import weight_levels

    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    a_lv, packed = quantize_pack(a2, a_bits)
    s_a = jnp.asarray(1.0 / ((1 << a_bits) - 1), a.dtype)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    if path == "faithful":
        w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)
        acc = bitgemm_packed_pallas(
            packed, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=_interpret()
        )
    else:
        acc = bitgemm_mxu(a_lv, w_lv, a_bits, w_bits)
    acc = acc.astype(a.dtype)
    rowsum = jnp.sum(a_lv, axis=-1, dtype=jnp.int32).astype(a.dtype)
    out = (s_a * s_w) * acc - (s_a * s_w * z_w) * rowsum[:, None]
    return out.reshape(lead + (w.shape[-1],))
