"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` for
correctness validation; on TPU they compile natively. The wrappers also
own layout plumbing: bit-plane packing for the faithful kernel and
nibble-splitting for >7-bit operands on the MXU kernel.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.and_accum import (_nibble_split, dequant_epilogue,
                                  f32dot_exact, quant_dense_pre_levels)
from .bitgemm import bitgemm_packed_pallas
from .bitgemm_mxu import int8_matmul_pallas
from .conv_implicit import (conv_implicit_pallas, conv_implicit_xla,
                            implicit_xla_exact)
from .fused_qgemm import fused_qgemm_pallas
from .quantpack import quantize_pack_pallas


def _interpret() -> bool:
    # the kernels use TPU memory spaces; interpret everywhere else (CPU/GPU)
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Engine dispatch — backend/shape-aware selection of the serve GEMM path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvShape:
    """Static conv geometry (including batch) for engine selection.

    ``batch`` entered in PR 3: the serving engine coalesces many requests
    into one dispatch, so feasibility and crossover bounds must see the
    whole co-batched problem, not a single image.
    """
    h: int
    w: int
    kh: int
    kw: int
    stride: int
    padding: str
    batch: int = 1

    @property
    def out_hw(self) -> tuple[int, int]:
        from repro.core.conv_lowering import _out_hw
        return _out_hw(self.h, self.w, self.kh, self.kw, self.stride,
                       self.padding)

    @property
    def m(self) -> int:
        """GEMM rows of the whole batched problem: batch * oh * ow."""
        oh, ow = self.out_hw
        return self.batch * oh * ow

    @property
    def read_amplification(self) -> float:
        """im2col HBM blowup: patch elements per input element (~kh*kw).

        A per-image ratio — batch scales patch and input bytes alike."""
        oh, ow = self.out_hw
        return self.kh * self.kw * oh * ow / max(self.h * self.w, 1)

    def padded_image_elems(self, cin: int) -> int:
        """Elements of ONE image plane as the implicit kernel stages it in
        VMEM (SAME-padded); the kernel is resident once per batch index, so
        this bound is per-image regardless of batch."""
        from repro.core.conv_lowering import pad_split
        (pt, pb), (pl, pr) = pad_split(self.h, self.w, self.kh, self.kw,
                                       self.stride, self.padding)
        return (self.h + pt + pb) * (self.w + pl + pr) * cin


# implicit engine eligibility: the kernel supports these strides, and only
# K-axes at least this deep amortize the halo'd-tile bookkeeping (a 1x1
# conv has no patch blowup — im2col is the identity there)
IMPLICIT_STRIDES = (1, 2)
IMPLICIT_KDIM_MIN = 512
# the Pallas kernel keeps one image's int8 levels resident in VMEM per
# batch index; leave half of the ~16 MiB VMEM for weight/output tiles and
# the pipeline's double buffers
IMPLICIT_VMEM_BYTES = 8 << 20
# CPU crossover (measured, benchmarks/bench_conv.py, batch 1-8): the
# implicit direct conv pays off once the whole BATCHED problem moves
# enough amplified patch elements per Cin*Cout pair — conv.m (= B*oh*ow)
# times the per-image amplification.  The per-dispatch conv-loop overhead
# amortizes over the batch (measured: deep-cin layers flip to implicit by
# B=2-4 well below the single-image threshold), so the threshold divides
# by the batch (floored at B=8 — beyond that the loop cost is fully
# amortized and only the per-element term is left).  Shallow-K convs
# (e.g. cin=3 stem layers) lose at every batch size: each (dy, dx) tap
# does too little dot work to cover its slice/reshape, hence the K floor.
IMPLICIT_CPU_M_AMP_MIN = 2500
IMPLICIT_CPU_KDIM_MIN = 128


def select_engine(m: int, k: int, n: int, a_bits: int, w_bits: int,
                  backend: str | None = None,
                  conv: ConvShape | None = None) -> str:
    """Pick the serve engine for an (m, k) x (k, n) quantized GEMM.

    Returns one of:
      ``fused``     one-pass Pallas kernel (quantize + MXU matmul + rowsum +
                    dequant epilogue) — the TPU default;
      ``implicit``  implicit-GEMM conv (``conv`` geometry required): patch
                    extraction in-register, no im2col tensor in HBM —
                    Pallas kernel sweep on TPU, exact direct conv off-TPU;
      ``faithful``  the tiled VPU AND+popcount Pallas kernel — wins only
                    for binary, huge-K, skinny-output problems where the
                    32x K compression beats MXU occupancy;
      ``int8``      XLA int8 dot on the levels (nibble-split > 7 bits) —
                    the fallback wherever a Pallas kernel cannot run;
      ``f32dot``    exact float-unit realization — fastest off-TPU, valid
                    while the accumulator fits the fp32 mantissa.

    All five are exact; this is purely a performance decision, so the
    heuristic is deliberately coarse.  When ``conv`` is given its ``batch``
    field makes the bounds batch-aware (the serving engine dispatches
    co-batched buckets): ``m`` must describe the whole batched problem
    (``conv.m``), the CPU crossover scales with it, and the TPU kernel's
    VMEM-residency feasibility stays per-image (the grid revisits VMEM once
    per batch index).
    """
    backend = backend or jax.default_backend()
    if conv is not None:
        m = conv.m  # engine bounds always see the full batched rows
    impl_ok = (conv is not None and conv.kh * conv.kw > 1
               and conv.stride in IMPLICIT_STRIDES
               and conv.padding in ("SAME", "VALID")
               # no blowup, nothing to save: full-window FC-as-conv layers
               # (oh=ow=1, amplification 1) stay on the dense fused GEMM
               and conv.read_amplification >= 4.0)
    if backend == "tpu":
        # feasibility: one image's activation LEVELS must stay VMEM-resident
        # — int8 up to 7 activation bits, int32 at 8 (level_dtype), so the
        # budget is in bytes, not elements
        from repro.core.prequant import level_dtype

        cin = k // max(conv.kh * conv.kw, 1) if conv is not None else 0
        lvl_bytes = jnp.zeros((), level_dtype(a_bits)).dtype.itemsize
        if (impl_ok and k >= IMPLICIT_KDIM_MIN
                and conv.padded_image_elems(cin) * lvl_bytes
                <= IMPLICIT_VMEM_BYTES):
            return "implicit"
        # binary, huge-K, output tile small enough that the 128x128 MXU
        # would idle: the 32x K-compressed VPU popcount path wins
        if a_bits == 1 and w_bits == 1 and m * n <= (1 << 14) and k >= (1 << 15):
            return "faithful"
        return "fused"
    # CPU/GPU: XLA lowers integer matmuls to scalar loops; the float unit is
    # both faster and exact under the fp32-mantissa bound.  The implicit
    # direct conv wins (measured, benchmarks/bench_conv.py, batch 1-8) once
    # the batched problem moves enough amplified traffic to pay back the
    # conv-loop overhead: conv.m * amplification ~ the patch elements saved
    # per Cin*Cout pair.  Tiny-spatial layers (alexnet's 7x7 tail) stay on
    # the patch GEMM, and K beyond the off-TPU realization's exactness
    # bound falls back to the int8 engine (conv_implicit_xla would raise).
    if (impl_ok and k >= IMPLICIT_CPU_KDIM_MIN
            and m * conv.read_amplification
            >= IMPLICIT_CPU_M_AMP_MIN / min(conv.batch, 8)
            and implicit_xla_exact(k, a_bits, w_bits)):
        return "implicit"
    return "f32dot" if f32dot_exact(k, a_bits, w_bits) else "int8"


def fused_qgemm(a: jax.Array, w_lv: jax.Array, s_w, z_w, *, a_bits: int,
                w_bits: int, a_is_levels: bool = False,
                interpret: bool | None = None) -> jax.Array:
    """Fused serve pipeline kernel (see :mod:`repro.kernels.fused_qgemm`)."""
    interpret = _interpret() if interpret is None else interpret
    return fused_qgemm_pallas(a, w_lv, s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                              a_is_levels=a_is_levels, interpret=interpret)


def quant_dense_serve(a_lv: jax.Array, w_lv: jax.Array, s_w, z_w, *,
                      a_bits: int, w_bits: int,
                      engine: str | None = None) -> jax.Array:
    """Serve dense on pre-quantized operands through the selected engine.

    ``a_lv`` (M, K) integer activation levels; ``w_lv`` (K, N) weight levels.
    ``engine=None`` dispatches via :func:`select_engine`.
    """
    m, k = a_lv.shape
    n = w_lv.shape[1]
    if engine is None:
        engine = select_engine(m, k, n, a_bits, w_bits)
    if engine == "fused":
        return fused_qgemm(a_lv, w_lv, s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                           a_is_levels=True)
    if engine == "faithful":
        acc = bitgemm_faithful(a_lv.astype(jnp.int32), w_lv.astype(jnp.int32),
                               a_bits, w_bits)
        return dequant_epilogue(acc, a_lv, s_w, z_w, a_bits)
    return quant_dense_pre_levels(a_lv, w_lv, s_w, z_w, a_bits, w_bits,
                                  engine=engine)


def quant_conv_serve(x_lv: jax.Array, w_lv: jax.Array, s_w, z_w, *,
                     kh: int, kw: int, stride: int = 1, padding: str = "SAME",
                     a_bits: int, w_bits: int,
                     engine: str | None = None) -> jax.Array:
    """Serve conv on pre-quantized operands through the selected engine.

    ``x_lv`` (B, H, W, Cin) integer activation levels; ``w_lv``
    (kh*kw*Cin, Cout) weight levels in (kh, kw, cin)-major layout.  The
    conv-native entry point: ``engine="implicit"`` never materializes
    patches (Pallas implicit-GEMM sweep on TPU, exact direct conv
    elsewhere); every other engine lowers through ``im2col_sliced`` to
    :func:`quant_dense_serve`.  All engines are bit-identical.
    """
    from repro.core.conv_lowering import _out_hw, im2col_sliced

    b, h, w, cin = x_lv.shape
    cout = w_lv.shape[1]
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    if engine is None:
        engine = select_engine(
            b * oh * ow, kh * kw * cin, cout, a_bits, w_bits,
            conv=ConvShape(h, w, kh, kw, stride, padding, batch=b))
    if engine == "implicit":
        if jax.default_backend() == "tpu":
            return conv_implicit_pallas(
                x_lv, w_lv, s_w, z_w, kh=kh, kw=kw, stride=stride,
                padding=padding, a_bits=a_bits, w_bits=w_bits,
                interpret=False)
        return conv_implicit_xla(
            x_lv, w_lv, s_w, z_w, kh=kh, kw=kw, stride=stride,
            padding=padding, a_bits=a_bits, w_bits=w_bits)
    patches = im2col_sliced(x_lv, kh, kw, stride, padding)
    out = quant_dense_serve(patches.reshape(-1, kh * kw * cin), w_lv,
                            s_w, z_w, a_bits=a_bits, w_bits=w_bits,
                            engine=engine)
    return out.reshape(b, oh, ow, cout)


def bitgemm_faithful(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                     interpret: bool | None = None) -> jax.Array:
    """Paper-faithful kernel path: pack planes, AND+popcount on VPU tiles."""
    interpret = _interpret() if interpret is None else interpret
    a_planes = bitplane.decompose_packed(a_lv, a_bits, axis=-1)      # (m, M, Kw)
    w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)    # (n, N, Kw)
    return bitgemm_packed_pallas(
        a_planes, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=interpret
    )


def bitgemm_mxu(a_lv: jax.Array, w_lv: jax.Array, a_bits: int, w_bits: int,
                interpret: bool | None = None) -> jax.Array:
    """Optimized kernel path: folded int8 MXU matmul (nibble-split >7b)."""
    interpret = _interpret() if interpret is None else interpret
    out = jnp.zeros((a_lv.shape[0], w_lv.shape[1]), jnp.int32)
    for ga, sa in _nibble_split(a_lv, a_bits):
        for gw, sw in _nibble_split(w_lv, w_bits):
            d = int8_matmul_pallas(
                ga.astype(jnp.int8), gw.astype(jnp.int8), interpret=interpret
            )
            out = out + (d << (sa + sw))
    return out


def quantize_pack(a: jax.Array, bits: int, interpret: bool | None = None):
    """Fused DoReFa quantize + pack (kernel); returns (levels, planes)."""
    interpret = _interpret() if interpret is None else interpret
    return quantize_pack_pallas(a, bits=bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("a_bits", "w_bits", "path"))
def quant_dense_kernel(a: jax.Array, w: jax.Array, a_bits: int, w_bits: int,
                       path: str = "mxu") -> jax.Array:
    """End-to-end quantized dense on kernels: quantize+pack -> bitgemm -> dequant.

    Mirrors :func:`repro.core.and_accum.quant_dense_forward` but exercises
    the Pallas pipeline. a (..., K) in R; w (K, N).
    """
    from repro.core.quant import weight_levels

    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    a_lv, packed = quantize_pack(a2, a_bits)
    w_lv, s_w, z_w = weight_levels(w, w_bits)
    if path == "faithful":
        w_planes = bitplane.decompose_packed(w_lv.T, w_bits, axis=-1)
        acc = bitgemm_packed_pallas(
            packed, w_planes, a_bits=a_bits, w_bits=w_bits, interpret=_interpret()
        )
    else:
        acc = bitgemm_mxu(a_lv, w_lv, a_bits, w_bits)
    out = dequant_epilogue(acc, a_lv, s_w, z_w, a_bits, a.dtype)
    return out.reshape(lead + (w.shape[-1],))
