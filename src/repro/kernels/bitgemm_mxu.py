"""Pallas TPU kernel: MXU-mapped bit-GEMM (beyond-paper optimized path).

Insight (DESIGN.md §2): a {0,1} bit-plane dot product *is* an integer
matmul, so the MXU's 128x128 systolic adder tree subsumes the paper's 4:2
compressor tree; and because 2^(m+n) shifts distribute over the plane sum,
*all* plane pairs fold into one int8 matmul on the raw integer levels
(nibble-split when bits > 7, handled by the wrapper in ops.py).

Tiles are MXU-aligned (128 multiples); accumulation is int32 in the
revisited output block across the K grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TM, TN, TK = 128, 128, 512  # 128x512 int8 A-tile (64KiB) + 512x128 B + 128x128 i32 acc


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _pad(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.jit, static_argnames=("interpret", "tm", "tn", "tk"))
def int8_matmul_pallas(
    a: jax.Array,  # (M, K) int8 — integer levels (or a nibble group)
    b: jax.Array,  # (K, N) int8
    *,
    interpret: bool = False,
    tm: int = TM,
    tn: int = TN,
    tk: int = TK,
) -> jax.Array:
    """(M,K) @ (K,N) -> (M,N) int32, MXU-tiled."""
    M, K = a.shape
    _, N = b.shape
    a_p = _pad(_pad(a, tm, 0), tk, 1)
    b_p = _pad(_pad(b, tk, 0), tn, 1)
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    grid = (Mp // tm, Np // tn, Kp // tk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
