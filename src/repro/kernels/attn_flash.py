"""Quantized flash attention: the AND-Accumulation engine on the serve path.

The LM projections already serve through the paper's bit-wise engine
(``fused_qgemm``); this module extends it to the last unquantized hot
loop — the S^2 attention score GEMM.  One flash-style kernel computes

    out = softmax(dequant(Q_lv @ K_lv^T + affine correction) / sqrt(hd)) @ V

with online-softmax tiling over (q-block x kv-block), never materializing
the S^2 logits.  Q and K are affine-quantized per tensor to ``q_bits`` /
``k_bits`` levels (the same DoReFa level scheme as the dense path); the
score dot runs on integer levels through the nibble-split int8 MXU path of
``fused_qgemm``, and because *both* operands are activations the zero-point
correction needs both rowsums (cf. ``quant_dense_forward_signed_pre``,
which corrects one activation against a weight):

    q_hat @ k_hat^T = s_q s_k [QK^T - z_k rowsum(Q)1^T - z_q 1 rowsum(K)^T
                               + hd z_q z_k]

All four terms are exact int32, so the dequantized logits are *exact*
attention scores of the quantized q/k — the only approximation is the
quantization itself (bounded by s_q, s_k; see :func:`flash_error_bound`).
P @ V stays f32 (softmax weights are not level-valued).

Two realizations of the same arithmetic (mirroring ``conv_implicit``):

* :func:`attn_flash_pallas` — a single ``pallas_call``; grid
  (B*H, q-blocks, kv-blocks) with the (m, l, acc) online-softmax state in
  VMEM scratch carried across the innermost kv dimension.  Causal masking
  skips dead upper-triangle blocks with ``pl.when``; the sliding-window
  variant uses a *banded grid* — the kv grid axis only spans the
  ``ceil((W-1)/t)+1`` blocks that can intersect the window band, with the
  BlockSpec index map sliding the band along the diagonal.
* :func:`attn_flash_xla` — exact off-TPU realization: the centered-level
  identity ``(Q-z_q)(K-z_k)^T`` equals the rowsum-corrected form, and the
  centered levels are integer-valued f32, so a float dot is bit-exact
  while ``2^(q_bits-1) * 2^(k_bits-1) * hd < 2^24``
  (:func:`flash_levels_exact` — holds for every supported head dim).
  Blocked as scan-over-q-blocks with a ``fori_loop`` over exactly the
  valid kv-block range (causal upper triangle and out-of-window bands are
  never visited), and only boundary blocks pay the masking arithmetic —
  interior blocks run mask-free.  Measured at S=32k causal on CPU this is
  ~2.4x over the skip-enabled ``attn_chunked`` scan.

:func:`attn_flash` picks the realization for the live backend (the engine
entry the dispatch layer calls).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.and_accum import _nibble_split

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Quantization helpers (per-tensor affine, the dense path's level scheme)
# ---------------------------------------------------------------------------

def attn_quant_scale(x: jax.Array, bits: int):
    """Per-tensor (scale, zero_point) for signed affine quantization.

    Matches ``core.quant.activation_levels_signed``: z = 2^(bits-1),
    s = absmax / z; levels = clip(round(x/s) + z, 0, 2^bits - 1).
    """
    z = float(1 << (bits - 1))
    s = jnp.max(jnp.abs(x)).astype(jnp.float32) / z + 1e-12
    return s, z


def _levels(x: jax.Array, s, bits: int) -> jax.Array:
    z = float(1 << (bits - 1))
    n = float((1 << bits) - 1)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s) + z, 0.0, n)


def flash_levels_exact(head_dim: int, q_bits: int, k_bits: int) -> bool:
    """Can the centered-level score dot run exactly on the f32 unit?

    The centered levels are bounded by 2^(bits-1); the dot accumulates
    ``head_dim`` products, so the accumulator magnitude is below
    2^(q_bits-1) * 2^(k_bits-1) * head_dim — exact while under the fp32
    mantissa (2^24).  At 8/8 bits this holds for head_dim < 1024."""
    return (1 << (q_bits - 1)) * (1 << (k_bits - 1)) * head_dim < (1 << 24)


def flash_error_bound(q, k, q_bits: int, k_bits: int) -> float:
    """Worst-case absolute LOGIT error vs unquantized attention.

    Each operand rounds by at most s/2, so a length-hd dot differs by at
    most hd*(s_q*|k|_max + s_k*|q|_max + s_q*s_k/2)/2 before the 1/sqrt(hd)
    scale.  Useful for test tolerances; the post-softmax output error is
    further damped by softmax's 1-Lipschitz property (in the inf-norm,
    scaled by the value range)."""
    hd = q.shape[-1]
    # Host-side helper: callers pass concrete arrays to derive test
    # tolerances, never traced serve values, so these syncs are
    # intentional (the serve path keeps scales traced — attn_quant_scale).
    qm = float(jnp.max(jnp.abs(q)))  # repro-lint: disable=RL002 — pre-jit tolerance helper
    km = float(jnp.max(jnp.abs(k)))  # repro-lint: disable=RL002 — pre-jit tolerance helper
    s_q = qm / (1 << (q_bits - 1)) + 1e-12
    s_k = km / (1 << (k_bits - 1)) + 1e-12
    return hd * (s_q * km + s_k * qm + s_q * s_k / 2) / (2 * math.sqrt(hd))


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _pad_axis(x: jax.Array, target: int, axis: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# XLA realization (CPU/GPU engine)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_bits", "k_bits", "block_q", "block_kv"))
def attn_flash_xla(q, k, v, *, causal: bool = True,
                   window: Optional[int] = None, q_bits: int = 8,
                   k_bits: int = 8, block_q: int = 512,
                   block_kv: int = 512) -> jax.Array:
    """Exact XLA realization of the quantized flash kernel.

    q (B,Sq,H,hd); k,v (B,Skv,H,hd) with KV pre-expanded for GQA
    (``models.layers.expand_kv``).  Positions are the contiguous
    0..S-1 prefill positions (causal/window masks only consume position
    *differences*, so any common offset cancels).  Requires
    :func:`flash_levels_exact` — checked, raises ValueError beyond it.
    """
    # defense-in-depth: plan-dispatched flash verdicts arrive with this
    # already proven statically (repro.analysis prover, PV101)
    if not flash_levels_exact(q.shape[-1], q_bits, k_bits):
        raise ValueError(
            f"flash centered-level dot inexact at head_dim={q.shape[-1]}, "
            f"q_bits={q_bits}, k_bits={k_bits} (accumulator exceeds the "
            "fp32 mantissa)")
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    s_q, z_q = attn_quant_scale(q, q_bits)
    s_k, z_k = attn_quant_scale(k, k_bits)
    # centered levels: (lv - z) in [-2^(b-1), 2^(b-1)-1]; the centered dot
    # IS the rowsum-corrected form (expand (Q-z_q)(K-z_k)^T), kept as
    # integer-valued f32 so XLA uses the fast float unit exactly
    qc = _levels(q, s_q, q_bits) - z_q
    kc = _levels(k, s_k, k_bits) - z_k
    scale = s_q * s_k / math.sqrt(hd)

    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    Sq_p, Skv_p = _ceil_to(Sq, bq), _ceil_to(Skv, bk)
    qc = _pad_axis(qc, Sq_p, 1)
    kc = _pad_axis(kc, Skv_p, 1)
    vp = _pad_axis(v, Skv_p, 1)
    Nq, Nk = Sq_p // bq, Skv_p // bk
    qt = qc.reshape(B, Nq, bq, H, hd).transpose(1, 0, 3, 2, 4)
    kt = kc.reshape(B, Nk, bk, H, hd).transpose(1, 0, 3, 2, 4)
    vt = vp.reshape(B, Nk, bk, H, hd).transpose(1, 0, 3, 2, 4).astype(
        jnp.float32)
    # the last kv block holding real rows: blocks past it exist only when
    # causal padding makes the diagonal reach them, and stay masked
    j_pad = (Skv - 1) // bk

    def q_body(_, qx):
        qi, i = qx  # (B,H,bq,hd), scalar block index
        jhi = (jnp.minimum(((i + 1) * bq - 1) // bk, Nk - 1)
               if causal else Nk - 1)
        jlo = (jnp.maximum((i * bq - (window - 1)) // bk, 0)
               if window is not None else 0)

        def kv_step(j, carry):
            m_run, l_run, acc = carry
            kj = jax.lax.dynamic_index_in_dim(kt, j, 0, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vt, j, 0, keepdims=False)
            s = jnp.einsum("bhqd,bhsd->bhqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale

            def masked(s):
                iq = i * bq + jnp.arange(bq)
                jk = j * bk + jnp.arange(bk)
                m = (jk < Skv)[None, :] & jnp.ones((bq, 1), bool)
                if causal:
                    m &= jk[None, :] <= iq[:, None]
                if window is not None:
                    m &= jk[None, :] > iq[:, None] - window
                s = jnp.where(m[None, None], s, NEG_INF)
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                return m_new, jnp.exp(s - m_new[..., None]) * m[None, None]

            def plain(s):
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                return m_new, jnp.exp(s - m_new[..., None])

            # only boundary blocks pay the mask arithmetic: the causal
            # diagonal (j == jhi), the window's trailing edge (j == jlo),
            # and the kv padding block.  Interior blocks are fully valid.
            boundary = j >= j_pad
            if causal:
                boundary |= j == jhi
            if window is not None:
                boundary |= j == jlo
            m_new, p = jax.lax.cond(boundary, masked, plain, s)
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqs,bhsd->bhqd", p, vj, preferred_element_type=jnp.float32)
            return (m_new, l_run, acc)

        init = (jnp.full((B, H, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, H, bq), jnp.float32),
                jnp.zeros((B, H, bq, hd), jnp.float32))
        m_run, l_run, acc = jax.lax.fori_loop(jlo, jhi + 1, kv_step, init)
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_body, None, (qt, jnp.arange(Nq)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq_p, H, hd)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Pallas realization (TPU engine; interpret-mode correctness off-TPU)
# ---------------------------------------------------------------------------

def _flash_kernel(scal_ref, zint_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, q_bits, k_bits, causal, window,
                  tq, tk, seq_kv, nj, nwin):
    """One (bh, i, j) grid step of the online-softmax sweep.

    scal_ref (SMEM f32): [s_q*s_k/sqrt(hd)]; zint_ref (SMEM i32):
    [z_q, z_k].  Scratch m/l (tq, 128) f32 (lane-replicated row stats),
    acc (tq, hd) f32 — carried across the innermost kv grid dim.
    """
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute kv block: the banded (window) grid slides j's nwin-wide
    # band along the diagonal; the causal grid visits the full row
    jb = i - (nwin - 1) + j if nwin is not None else j
    hd = q_ref.shape[-1]
    active = jb * tk < seq_kv
    if nwin is not None:
        active &= jb >= 0
    if causal:
        active &= jb * tk <= (i + 1) * tq - 1

    @pl.when(active)
    def _compute():
        z_q, z_k = zint_ref[0], zint_ref[1]
        ql = q_ref[0].astype(jnp.int32)   # (tq, hd) levels
        kl = k_ref[0].astype(jnp.int32)   # (tk, hd)
        acc = jnp.zeros((tq, tk), jnp.int32)
        # nibble-split int8 MXU dots, folded with shifts (fused_qgemm's
        # accumulation); contraction over the head dim of both operands
        for gq, sq in _nibble_split(ql, q_bits):
            for gk, sk in _nibble_split(kl, k_bits):
                d = jax.lax.dot_general(
                    gq.astype(jnp.int8), gk.astype(jnp.int8),
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc += d << (sq + sk)
        # both operands are activations: both rowsums enter the correction
        rs_q = jnp.sum(ql, axis=1)        # (tq,)
        rs_k = jnp.sum(kl, axis=1)        # (tk,)
        corr = (acc - z_k * rs_q[:, None] - z_q * rs_k[None, :]
                + hd * z_q * z_k)
        logits = corr.astype(jnp.float32) * scal_ref[0]

        iq = jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0) + i * tq
        jk = jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1) + jb * tk
        msk = jk < seq_kv
        if causal:
            msk &= jk <= iq
        if window is not None:
            msk &= jk > iq - window
        logits = jnp.where(msk, logits, NEG_INF)

        m_old = m_ref[:, :1]                                   # (tq, 1)
        m_new = jnp.maximum(m_old, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new) * msk                      # (tq, tk)
        cf = jnp.exp(m_old - m_new)                            # (tq, 1)
        l_new = l_ref[:, :1] * cf + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * cf + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def attn_flash_pallas(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, q_bits: int = 8,
                      k_bits: int = 8, block_q: int = 1024,
                      block_kv: int = 1024,
                      interpret: bool = True) -> jax.Array:
    """Single-``pallas_call`` quantized flash attention (shapes as
    :func:`attn_flash_xla`).  The sliding-window variant requires
    ``block_q == block_kv`` (the banded grid slides in whole blocks)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    s_q, z_q = attn_quant_scale(q, q_bits)
    s_k, z_k = attn_quant_scale(k, k_bits)
    ql = _levels(q, s_q, q_bits).astype(jnp.int32)
    kl = _levels(k, s_k, k_bits).astype(jnp.int32)

    tq = min(block_q, Sq)
    tk = min(block_kv, Skv)
    if window is not None:
        tq = tk = min(tq, tk)
    Sq_p, Skv_p = _ceil_to(Sq, tq), _ceil_to(Skv, tk)
    ql = _pad_axis(ql, Sq_p, 1)
    kl = _pad_axis(kl, Skv_p, 1)
    vp = _pad_axis(v, Skv_p, 1)
    Nq, Nk = Sq_p // tq, Skv_p // tk

    # (B,S,H,hd) -> (B*H, S, hd): one grid row per (batch, head)
    ql = ql.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, hd)
    kl = kl.transpose(0, 2, 1, 3).reshape(B * H, Skv_p, hd)
    vp = vp.transpose(0, 2, 1, 3).reshape(B * H, Skv_p, hd)

    nwin = None
    if window is not None:
        # blocks that can intersect the (W-1)-deep band plus the diagonal
        nwin = min(Nk, -(-(window - 1) // tk) + 1)
        nj = nwin
        kv_index = lambda b, i, j: (b, jnp.maximum(i - (nwin - 1) + j, 0), 0)
    else:
        nj = Nk
        kv_index = lambda b, i, j: (b, j, 0)

    scal = jnp.asarray([s_q * s_k / math.sqrt(hd)], jnp.float32)
    zint = jnp.asarray([int(z_q), int(z_k)], jnp.int32)

    kernel = functools.partial(
        _flash_kernel, q_bits=q_bits, k_bits=k_bits, causal=causal,
        window=window, tq=tq, tk=tk, seq_kv=Skv, nj=nj, nwin=nwin)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Nq, nj),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, hd), kv_index),
            pl.BlockSpec((1, tk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 128), jnp.float32),
            pltpu.VMEM((tq, 128), jnp.float32),
            pltpu.VMEM((tq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(scal, zint, ql, kl, vp)
    out = out.reshape(B, H, Sq_p, hd).transpose(0, 2, 1, 3)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Paged attention (continuous-batching serve path)
# ---------------------------------------------------------------------------
#
# KV lives in a shared block pool (NP+1, ps, Hkv, hd) — NP fixed-size pages
# plus one reserved, never-written null page — and each decode slot owns an
# ordered page-table row (P page indices, padded with the null page).  The
# engine gathers a slot's KV through its table row and attends with the
# device-side position buffer ``ppos`` ((NP+1, ps), -1 = never written) as
# the validity mask, so ragged final pages and table padding cost a mask,
# not a copy.  All reductions are SLOT-LOCAL by construction (per-slot
# quantization scales, per-slot softmax): a slot's output bits depend only
# on its own row content — the property that makes step-granular join/
# leave bit-identical to running the same engine one request at a time.


def _paged_slot_scales(q, pool_k, ppos, table, bits: int):
    """Per-SLOT affine scales for the quantized paged dot.

    s_q[b] from slot b's own query rows; s_k[b] from slot b's gathered K
    masked by ``ppos >= 0`` — stale content in freed-and-reused pages (and
    the null page) can never perturb a live slot's scale."""
    z = float(1 << (bits - 1))
    s_q = jnp.max(jnp.abs(q).astype(jnp.float32), axis=(1, 2, 3)) / z + 1e-12
    kg = jnp.abs(pool_k[table]).astype(jnp.float32)    # (B, P, ps, Hkv, hd)
    valid = (ppos[table] >= 0)[..., None, None]
    s_k = jnp.max(jnp.where(valid, kg, 0.0), axis=(1, 2, 3, 4)) / z + 1e-12
    return s_q, s_k


def _paged_expand_idx(n_q_real: int, n_q_padded: int, hkv: int):
    """GQA head map for the gathered KV (layers.expand_kv's rule, inlined —
    importing it from models.layers would be circular)."""
    g = max(n_q_real // hkv, 1)
    return jnp.minimum(jnp.arange(n_q_padded) // g, hkv - 1)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "quantized", "bits", "n_q_heads"))
def attn_paged_xla(q, pool_k, pool_v, ppos, table, q_pos, *,
                   causal: bool = True, window: Optional[int] = None,
                   quantized: bool = False, bits: int = 8,
                   n_q_heads: Optional[int] = None) -> jax.Array:
    """Gather realization of paged attention (CPU/GPU engine; the oracle
    for the Pallas kernel).

    q (B, S, Hp, hd); pool_k/pool_v (NP+1, ps, Hkv, hd); ppos (NP+1, ps);
    table (B, P) page indices; q_pos (B, S) absolute query positions with
    -1 marking invalid (padding) rows.  Logits are materialized at
    (B, Hp, S, P*ps) — the paged geometries are decode steps and prefill
    chunks, so S and P*ps are both small by design.
    """
    B, S, Hp, hd = q.shape
    NP1, ps, Hkv, _ = pool_k.shape
    P = table.shape[1]
    n_q = n_q_heads or Hp
    kg = pool_k[table].reshape(B, P * ps, Hkv, hd)
    vg = pool_v[table].reshape(B, P * ps, Hkv, hd)
    pos_g = ppos[table].reshape(B, P * ps)
    if quantized:
        if not flash_levels_exact(hd, bits, bits):
            raise ValueError(
                f"paged centered-level dot inexact at head_dim={hd}, "
                f"bits={bits}")
        z = float(1 << (bits - 1))
        s_q, s_k = _paged_slot_scales(q, pool_k, ppos, table, bits)
        qc = _levels(q, s_q[:, None, None, None], bits) - z
        kc = _levels(kg, s_k[:, None, None, None], bits) - z
    else:
        qc = q.astype(jnp.float32)
        kc = kg.astype(jnp.float32)
    if Hkv != Hp:
        idx = _paged_expand_idx(n_q, Hp, Hkv)
        kc = jnp.take(kc, idx, axis=2)
        vg = jnp.take(vg, idx, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", qc, kc,
                        preferred_element_type=jnp.float32)
    if quantized:
        logits = logits * (s_q * s_k / math.sqrt(hd))[:, None, None, None]
    else:
        logits = logits / math.sqrt(hd)
    m = (pos_g >= 0)[:, None, None, :]
    if causal:
        m = m & (pos_g[:, None, None, :] <= q_pos[:, None, :, None])
    if window is not None:
        m = m & (pos_g[:, None, None, :] > q_pos[:, None, :, None] - window)
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def _paged_kernel(tbl_ref, scal_ref, zint_ref, qpos_ref, q_ref, k_ref,
                  v_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bits, causal, window, n_q_heads, n_pages):
    """One (slot b, table column p) grid step.

    The KV BlockSpecs are *page-indexed through the scalar-prefetched
    table* (``tbl[b, p]``), so the kernel sees slot b's p-th page as a
    contiguous block; the null page arrives fully masked (its ppos is all
    -1).  Online-softmax (m, l, acc) scratch is carried across the inner
    page dimension, one (S, 128)/(S, hd) row band per query head.
    """
    b, p = pl.program_id(0), pl.program_id(1)
    S, Hp, hd = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    ps, Hkv = k_ref.shape[1], k_ref.shape[2]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z_q, z_k = zint_ref[0], zint_ref[1]
    scal = scal_ref[b]
    pos = pos_ref[0]                      # (ps,) absolute positions, -1 dead
    iq = qpos_ref[0]                      # (S,) query positions, -1 dead
    msk = jnp.broadcast_to(pos[None, :] >= 0, (S, ps))
    if causal:
        msk &= pos[None, :] <= iq[:, None]
    if window is not None:
        msk &= pos[None, :] > iq[:, None] - window

    g = max(n_q_heads // Hkv, 1)
    for j in range(Hp):                   # unrolled: Hp is small & static
        jkv = min(j // g, Hkv - 1)
        ql = q_ref[0, :, j].astype(jnp.int32)      # (S, hd) levels
        kl = k_ref[0, :, jkv].astype(jnp.int32)    # (ps, hd)
        acc = jnp.zeros((S, ps), jnp.int32)
        for gq, sq in _nibble_split(ql, bits):
            for gk, sk in _nibble_split(kl, bits):
                d = jax.lax.dot_general(
                    gq.astype(jnp.int8), gk.astype(jnp.int8),
                    dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc += d << (sq + sk)
        rs_q = jnp.sum(ql, axis=1)
        rs_k = jnp.sum(kl, axis=1)
        corr = (acc - z_k * rs_q[:, None] - z_q * rs_k[None, :]
                + hd * z_q * z_k)
        logits = jnp.where(msk, corr.astype(jnp.float32) * scal, NEG_INF)

        r0 = j * S
        m_old = m_ref[r0:r0 + S, :1]
        m_new = jnp.maximum(m_old, jnp.max(logits, axis=1, keepdims=True))
        pw = jnp.exp(logits - m_new) * msk
        cf = jnp.exp(m_old - m_new)
        l_new = l_ref[r0:r0 + S, :1] * cf + jnp.sum(pw, axis=1,
                                                    keepdims=True)
        acc_ref[r0:r0 + S] = acc_ref[r0:r0 + S] * cf + jax.lax.dot_general(
            pw, v_ref[0, :, jkv].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[r0:r0 + S] = jnp.broadcast_to(m_new, (S, 128))
        l_ref[r0:r0 + S] = jnp.broadcast_to(l_new, (S, 128))

    @pl.when(p == n_pages - 1)
    def _epilogue():
        for j in range(Hp):
            r0 = j * S
            l = jnp.maximum(l_ref[r0:r0 + S, :1], 1e-30)
            o_ref[0, :, j] = (acc_ref[r0:r0 + S] / l).astype(o_ref.dtype)


def attn_paged_pallas(q, pool_k, pool_v, ppos, table, q_pos, *,
                      causal: bool = True, window: Optional[int] = None,
                      bits: int = 8, n_q_heads: Optional[int] = None,
                      interpret: bool = True) -> jax.Array:
    """Pallas realization (quantized path only; shapes as
    :func:`attn_paged_xla`).

    ``PrefetchScalarGridSpec`` prefetches the page table so the KV
    BlockSpec index maps can select blocks *through* it — the gather never
    materializes on the host side of the kernel.  Per-slot scales are a
    cheap host prepass: s_k is scattered onto the pages through the table
    (each real page has exactly one owner; the null page's winner is
    irrelevant — its ppos keeps it fully masked).
    """
    B, S, Hp, hd = q.shape
    NP1, ps, Hkv, _ = pool_k.shape
    P = table.shape[1]
    if not flash_levels_exact(hd, bits, bits):
        raise ValueError(
            f"paged centered-level dot inexact at head_dim={hd}, bits={bits}")
    z = float(1 << (bits - 1))
    s_q, s_k = _paged_slot_scales(q, pool_k, ppos, table, bits)
    page_scale = jnp.ones((NP1,), jnp.float32).at[table.reshape(-1)].set(
        jnp.repeat(s_k, P), mode="drop")
    ql = _levels(q, s_q[:, None, None, None], bits).astype(jnp.int32)
    kl = _levels(pool_k, page_scale[:, None, None, None], bits
                 ).astype(jnp.int32)
    scal = (s_q * s_k / math.sqrt(hd)).astype(jnp.float32)       # (B,)
    zint = jnp.asarray([int(z), int(z)], jnp.int32)

    kernel = functools.partial(
        _paged_kernel, bits=bits, causal=causal, window=window,
        n_q_heads=n_q_heads or Hp, n_pages=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # scal (B,)
            pl.BlockSpec(memory_space=pltpu.SMEM),                # zint (2,)
            pl.BlockSpec((1, S), lambda tbl, b, p: (b, 0)),
            pl.BlockSpec((1, S, Hp, hd), lambda tbl, b, p: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, hd),
                         lambda tbl, b, p: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, hd),
                         lambda tbl, b, p: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps), lambda tbl, b, p: (tbl[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, S, Hp, hd), lambda tbl, b, p: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hp * S, 128), jnp.float32),
            pltpu.VMEM((Hp * S, 128), jnp.float32),
            pltpu.VMEM((Hp * S, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, Hp, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), scal, zint, q_pos.astype(jnp.int32),
      ql, kl, pool_v, ppos)
    return out


def attn_paged(q, pool_k, pool_v, ppos, table, q_pos, *,
               causal: bool = True, window: Optional[int] = None,
               quantized: bool = False, bits: int = 8,
               n_q_heads: Optional[int] = None) -> jax.Array:
    """Backend-dispatched paged attention (the ``paged`` engine entry):
    native Pallas kernel on TPU when quantized, the gather realization
    elsewhere (and always for fp configs — the Pallas kernel is the
    integer-levels path)."""
    n_q = n_q_heads or q.shape[2]
    if quantized and jax.default_backend() == "tpu":
        return attn_paged_pallas(q, pool_k, pool_v, ppos, table, q_pos,
                                 causal=causal, window=window, bits=bits,
                                 n_q_heads=n_q, interpret=False)
    return attn_paged_xla(q, pool_k, pool_v, ppos, table, q_pos,
                          causal=causal, window=window, quantized=quantized,
                          bits=bits, n_q_heads=n_q)


def attn_flash(q, k, v, *, causal: bool = True, window: Optional[int] = None,
               q_bits: int = 8, k_bits: int = 8,
               block_q: Optional[int] = None,
               block_kv: Optional[int] = None) -> jax.Array:
    """Backend-dispatched quantized flash attention (the engine entry):
    native Pallas kernel on TPU, the exact XLA realization elsewhere.

    ``block_q/block_kv=None`` takes each realization's tuned default
    (MXU-sized 1024 for the Pallas grid; cache-sized 512 for the XLA
    scan — measured on the S=32k CPU sweep, ``benchmarks/bench_attn.py``).
    """
    if jax.default_backend() == "tpu":
        return attn_flash_pallas(q, k, v, causal=causal, window=window,
                                 q_bits=q_bits, k_bits=k_bits,
                                 block_q=block_q or 1024,
                                 block_kv=block_kv or 1024,
                                 interpret=False)
    return attn_flash_xla(q, k, v, causal=causal, window=window,
                          q_bits=q_bits, k_bits=k_bits,
                          block_q=block_q or 512, block_kv=block_kv or 512)
