"""Implicit-GEMM quantized conv: the AND-Accumulation conv without im2col.

The im2col lowering (``core/conv_lowering``) materializes patches of shape
(B*OH*OW, kh*kw*Cin) in HBM before the GEMM runs — every input pixel is
written kh*kw times (9x for 3x3), exactly the inter-array data movement the
paper's sub-array kernel mapping (§II-A) avoids: the SOT-MRAM engine sweeps
the kernel over rows *in place*, reading each input row once.  This kernel
is the TPU realization of that dataflow:

  * grid = (batch, output-row tiles, Cout tiles); the integer activation
    levels for one image load into VMEM once per batch index (the index map
    depends only on ``b``, so Pallas's pipelined double-buffering keeps the
    tile resident across every output-row/Cout step — patches never exist
    in HBM);
  * patch extraction happens *in register*: for each (dy, dx) kernel tap
    the halo'd row span is sliced and de-strided (a reshape, no strided
    memory op) into the (TOH*OW, Cin) operand of one MXU dot against the
    matching Cin-row slab of the pre-quantized weight levels — the same
    dy/dx sweep ``im2col_sliced`` performs, minus the concatenate/HBM
    round-trip;
  * the PR-1 fused chain rides along unchanged: nibble-split int8 MXU dots
    (operands < 2^7), the in-loop ``rowsum(A)`` EPU pass, and the affine
    dequant epilogue ``out = s*acc - t*rowsum`` — all inside the same
    ``pallas_call``, one HBM pass over activations.

``conv_implicit_xla`` is the off-TPU realization of the same contract: the
level GEMM *is* an integer convolution, so ``lax.conv_general_dilated`` on
the f32-cast levels (exact under the fp32-mantissa bound, nibble-split when
not) computes the accumulator with zero materialized patch bytes — the
CPU/GPU counterpart of the in-place kernel sweep.

Both realizations are bit-identical to ``im2col_sliced`` + the fused qGEMM:
quantization is elementwise so it commutes with patch extraction, zero
padding maps to level 0 (contributing 0 to both the accumulator and the
rowsum), and the integer contraction is order-invariant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.and_accum import _nibble_split, f32dot_exact
from repro.core.conv_lowering import _out_hw, pad_split

TOH, TCOUT = 8, 128


def _group_max(bits: int) -> int:
    """Largest level in a ``_nibble_split`` group: unsplit up to 7 bits,
    4-bit nibbles beyond."""
    return (1 << (bits if bits <= 7 else 4)) - 1


def implicit_xla_exact(k: int, a_bits: int, w_bits: int) -> bool:
    """Can :func:`conv_implicit_xla` run exactly for this K?  Every
    group-pair f32 conv must fit the mantissa (``_nibble_split`` only
    splits past 7 bits, so 5-7 bit operands stay whole).  The dispatcher
    must not select the off-TPU implicit engine when this is False."""
    return _group_max(a_bits) * _group_max(w_bits) * max(k, 1) < (1 << 24)


def _kernel(s_ref, x_ref, w_ref, o_ref, *, kh: int, kw: int, cin: int,
            stride: int, ow: int, toh: int, a_bits: int, w_bits: int):
    t = pl.program_id(1)
    # halo'd row span for this output-row tile: toh*stride + (kh-1) rows,
    # de-strided below by reshape (no strided memory access)
    span = toh * stride + kh - 1
    xt = x_ref[0, pl.ds(t * toh * stride, span)]        # (span, Wp, Cin)

    tn = o_ref.shape[-1]
    acc = jnp.zeros((toh * ow, tn), jnp.int32)
    rs = jnp.zeros((toh * ow, 1), jnp.int32)
    for dy in range(kh):
        for dx in range(kw):
            rows = xt[dy: dy + toh * stride]            # (toh*stride, Wp, C)
            rows = rows.reshape(toh, stride, -1, cin)[:, 0]
            cols = rows[:, dx: dx + ow * stride]
            patch = cols.reshape(toh, ow, stride, cin)[:, :, 0]
            p = patch.reshape(toh * ow, cin).astype(jnp.int32)
            # in-K rowsum(A) — the paper's extra EPU popcount pass, fused
            rs = rs + jnp.sum(p, axis=1, dtype=jnp.int32)[:, None]
            wk = w_ref[(dy * kw + dx) * cin: (dy * kw + dx + 1) * cin, :]
            wk = wk.astype(jnp.int32)
            for ga, sa in _nibble_split(p, a_bits):
                for gw, sw in _nibble_split(wk, w_bits):
                    d = jax.lax.dot_general(
                        ga.astype(jnp.int8), gw.astype(jnp.int8),
                        dimension_numbers=(((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    )
                    acc = acc + (d << (sa + sw))
    s, z = s_ref[0], s_ref[1]
    out = s * acc.astype(jnp.float32) - z * rs.astype(jnp.float32)
    o_ref[...] = out.reshape(1, toh, ow, tn)


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "a_bits", "w_bits",
                     "interpret", "toh", "tcout"),
)
def conv_implicit_pallas(
    x_lv: jax.Array,   # (B, H, W, Cin) integer activation levels
    w_lv: jax.Array,   # (kh*kw*Cin, Cout) pre-quantized weight levels
    s_w: jax.Array,
    z_w: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    a_bits: int,
    w_bits: int,
    interpret: bool = False,
    toh: int = TOH,
    tcout: int = TCOUT,
) -> jax.Array:
    """Implicit-GEMM conv on pre-quantized operands.  Returns f32 NHWC.

    Weight layout is (kh, kw, cin)-major on the K axis — the layout
    ``core.prequant.prequantize_conv_weight`` stores and ``im2col_sliced``
    emits, so the kernel is a drop-in for the patch-GEMM path.
    """
    b, h, w, cin = x_lv.shape
    cout = w_lv.shape[1]
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    (ph0, _), (pw0, _) = pad_split(h, w, kh, kw, stride, padding)

    toh = min(toh, max(oh, 1))
    ohp = -(-oh // toh) * toh
    tcout = min(tcout, cout)
    coutp = -(-cout // tcout) * tcout
    # halo'd canvas: every in-kernel slice (incl. the padded tail rows whose
    # outputs are cropped) stays in bounds
    hp = ohp * stride + kh - 1
    wp = ow * stride + kw - 1
    x_p = jnp.pad(x_lv, ((0, 0), (ph0, hp - h - ph0), (pw0, wp - w - pw0),
                         (0, 0)))
    w_p = jnp.pad(w_lv, ((0, 0), (0, coutp - cout)))

    s_a = jnp.asarray(1.0 / ((1 << a_bits) - 1), jnp.float32)
    s = s_a * s_w.astype(jnp.float32)
    scales = jnp.stack([s, s * z_w.astype(jnp.float32)])  # (2,) SMEM

    grid = (b, ohp // toh, coutp // tcout)
    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, cin=cin, stride=stride,
                          ow=ow, toh=toh, a_bits=a_bits, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            # whole image per batch index: index map ignores (t, j), so the
            # pipelined buffer is fetched once per image and stays resident
            pl.BlockSpec((1, hp, wp, cin), lambda i, t, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh * kw * cin, tcout), lambda i, t, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, toh, ow, tcout),
                               lambda i, t, j: (i, t, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ohp, ow, coutp), jnp.float32),
        interpret=interpret,
    )(scales, x_p, w_p)
    return out[:, :oh, :, :cout]


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "a_bits", "w_bits"),
)
def conv_implicit_xla(
    x_lv: jax.Array,
    w_lv: jax.Array,
    s_w: jax.Array,
    z_w: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: str = "SAME",
    a_bits: int,
    w_bits: int,
) -> jax.Array:
    """Off-TPU implicit realization: the level GEMM as a direct convolution.

    ``conv_general_dilated`` on the f32-cast levels is exact while every
    partial sum fits the fp32 mantissa (the ``f32dot_exact`` bound with
    K = kh*kw*cin); beyond it the operands nibble-split into <2^4 groups —
    the same folding the MXU kernels use — and each group-pair conv is
    exact.  No patch tensor is ever materialized: XLA's conv loops read
    each input row once per kernel tap from cache, not kh*kw copies from
    memory.
    """
    b, h, w, cin = x_lv.shape
    cout = w_lv.shape[1]
    k = kh * kw * cin
    (ph0, _), (pw0, _) = pad_split(h, w, kh, kw, stride, padding)
    oh, ow = _out_hw(h, w, kh, kw, stride, padding)
    # leading pads are im2col's SAME split; the trailing side covers the
    # full window sweep exactly (negative = crop, matching how the sliced
    # im2col's strided slices simply never read past the last window)
    pads = ((ph0, (oh - 1) * stride + kh - h - ph0),
            (pw0, (ow - 1) * stride + kw - w - pw0))

    w4 = w_lv.reshape(kh, kw, cin, cout)

    def _conv(x, w_):
        return jax.lax.conv_general_dilated(
            x, w_, (stride, stride), pads,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            precision=jax.lax.Precision.HIGHEST,
        )

    x32 = x_lv.astype(jnp.int32)
    if f32dot_exact(k, a_bits, w_bits):
        acc_pairs = [(x32, 0, w4.astype(jnp.int32), 0)]
    else:
        # nibble-split both sides; each exact group-pair partial is cast to
        # int32 below so the shifted ACCUMULATION is integer arithmetic too
        # (summing the partials in f32 would round again past 2^24).  The
        # bound uses the ACTUAL group widths — _nibble_split leaves 5-7 bit
        # operands whole, so assuming 4-bit groups would under-guard.
        if not implicit_xla_exact(k, a_bits, w_bits):
            raise ValueError(f"implicit xla conv inexact even nibble-split "
                             f"(K={k}, a_bits={a_bits}, w_bits={w_bits}); "
                             "use the int8 engine or the Pallas kernel")
        acc_pairs = [(ga, sa, gw, sw)
                     for ga, sa in _nibble_split(x32, a_bits)
                     for gw, sw in _nibble_split(w4.astype(jnp.int32), w_bits)]

    acc = jnp.zeros((b, oh, ow, cout), jnp.int32)
    for ga, sa, gw, sw in acc_pairs:
        d = _conv(ga.astype(jnp.float32), gw.astype(jnp.float32))
        acc = acc + (d.astype(jnp.int32) << (sa + sw))
    ones = jnp.ones((kh, kw, cin, 1), jnp.float32)
    rs_groups = ([(x32, 0)] if f32dot_exact(k, a_bits, 1)
                 else _nibble_split(x32, a_bits))
    rowsum = jnp.zeros((b, oh, ow, 1), jnp.int32)
    for ga, sa in rs_groups:
        rowsum = rowsum + (_conv(ga.astype(jnp.float32),
                                 ones).astype(jnp.int32) << sa)

    # same expression (and the same int32 -> f32 accumulator cast) as
    # core.and_accum.dequant_epilogue, so the COMPILED paths round
    # identically.  (Eager execution can differ by FMA-contraction ulps —
    # XLA:CPU fuses this mult/mult/sub into one LLVM loop under jit — so
    # bit-identity is a jitted-vs-jitted property, which is what serve
    # runs; tests compare accordingly.)
    s_a = jnp.asarray(1.0 / ((1 << a_bits) - 1), jnp.float32)
    s = s_a * s_w.astype(jnp.float32)
    return (s * acc.astype(jnp.float32)
            - (s * z_w.astype(jnp.float32)) * rowsum.astype(jnp.float32))
