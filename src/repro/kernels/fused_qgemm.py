"""Pallas TPU kernel: fused quantize -> bit-GEMM -> affine-dequant serve path.

The serve-side analogue of the paper's in-memory pass (DESIGN.md §2.3): the
SOT-MRAM engine keeps the weight bit-planes C_n(W) resident in the sub-array
and performs AND -> CMP -> shift-accumulate without the operands ever leaving
the array.  On TPU the same locality argument applies to VMEM: the unfused
serve path (``and_accum.quant_dense_forward``) round-trips the int32
activation levels and the EPU rowsum through HBM between three separate
passes (quantize, GEMM, epilogue).  This kernel does all of it in one
``pallas_call``:

  1. DoReFa activation quantization of the float tile (VPU), skipped when the
     caller already holds integer levels (``a_is_levels`` — the conv path
     quantizes once per *image*, before im2col);
  2. the int8 MXU matmul on the integer levels — all 2^(m+n) plane pairs
     folded, nibble-split in-register when a bit-width exceeds 7 (W1A8);
  3. the in-K-loop ``rowsum(A)`` accumulation (the paper's extra EPU popcount
     pass, here a VPU reduction riding the same VMEM residency);
  4. the affine-correction + dequant epilogue
     ``out = (s_a*s_w) * acc - (s_a*s_w*z_w) * rowsum`` on the last K step.

Weights arrive PRE-QUANTIZED as int8 levels (``core/prequant.py`` — the
checkpoint-resident C_n(W)); the float weights, the per-call
``weight_levels`` re-quantization, and two HBM round-trips (a_lv int32 +
the separate rowsum reduction) of the unfused path are all gone.

VMEM budget per grid step (defaults, DESIGN.md §2.3): 128x512 f32 A-tile
(256 KiB) + 512x128 int8 W-tile (64 KiB) + two 128x128 int32 scratches
(acc, rowsum; 128 KiB) + 128x128 f32 out (64 KiB) — ~0.5 MiB, leaving room
for double-buffered inputs well under the ~16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.and_accum import _nibble_split

TM, TN, TK = 128, 128, 512


def _kernel(s_ref, a_ref, w_ref, o_ref, acc_ref, rs_ref, *,
            a_bits: int, w_bits: int, a_is_levels: bool, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rs_ref[...] = jnp.zeros_like(rs_ref)

    # (1) quantize: float tile -> DoReFa integer levels (identity if the
    # caller pre-quantized; zero-padding maps to level 0 either way)
    if a_is_levels:
        lv = a_ref[...].astype(jnp.int32)
    else:
        n = (1 << a_bits) - 1
        a = jnp.clip(a_ref[...], 0.0, 1.0)
        lv = jnp.clip(jnp.round(a * n), 0, n).astype(jnp.int32)

    # (3) in-K-loop rowsum(A) — the EPU pass fused into the same VMEM
    # residency; stored lane-broadcast so the epilogue subtract is shaped
    rs_ref[...] += jnp.sum(lv, axis=1, dtype=jnp.int32)[:, None]

    # (2) MXU matmul on the levels; in-register nibble split keeps every
    # operand < 2^7 so the systolic array runs int8 x int8 -> int32
    w = w_ref[...].astype(jnp.int32)
    acc = acc_ref[...]
    for ga, sa in _nibble_split(lv, a_bits):
        for gw, sw in _nibble_split(w, w_bits):
            d = jax.lax.dot_general(
                ga.astype(jnp.int8), gw.astype(jnp.int8),
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            acc = acc + (d << (sa + sw))
    acc_ref[...] = acc

    # (4) affine-correction + dequant epilogue, once per output tile
    @pl.when(k == nk - 1)
    def _epilogue():
        s, t = s_ref[0], s_ref[1]
        o_ref[...] = (s * acc_ref[...].astype(jnp.float32)
                      - t * rs_ref[...].astype(jnp.float32))


def _pad(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(
    jax.jit,
    static_argnames=("a_bits", "w_bits", "a_is_levels", "interpret",
                     "tm", "tn", "tk"),
)
def fused_qgemm_pallas(
    a: jax.Array,      # (M, K) float acts in R  (or int levels, a_is_levels)
    w_lv: jax.Array,   # (K, N) int8/int32 pre-quantized weight levels
    s_w: jax.Array,    # weight scale   (w_q = s_w * (levels - z_w))
    z_w: jax.Array,    # weight zero point
    *,
    a_bits: int,
    w_bits: int,
    a_is_levels: bool = False,
    interpret: bool = False,
    tm: int = TM,
    tn: int = TN,
    tk: int = TK,
) -> jax.Array:
    """Fused quantize -> int8 GEMM -> rowsum -> dequant.  Returns f32 (M, N).

    Bit-exact (integer accumulator) w.r.t. ``and_accum.bitgemm_int8`` with
    the same f32 epilogue as ``quant_dense_forward``.
    """
    M, K = a.shape
    N = w_lv.shape[1]
    s_a = jnp.asarray(1.0 / ((1 << a_bits) - 1), jnp.float32)
    s = s_a * s_w.astype(jnp.float32)
    scales = jnp.stack([s, s * z_w.astype(jnp.float32)])  # (2,) SMEM
    a_p = _pad(_pad(a, tm, 0), tk, 1)
    w_p = _pad(_pad(w_lv, tk, 0), tn, 1)
    Mp, Kp = a_p.shape
    Np = w_p.shape[1]
    nk = Kp // tk
    grid = (Mp // tm, Np // tn, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, a_bits=a_bits, w_bits=w_bits,
                          a_is_levels=a_is_levels, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tm, tn), jnp.int32),  # int32 accumulator
            pltpu.VMEM((tm, tn), jnp.int32),  # lane-broadcast rowsum(A)
        ],
        interpret=interpret,
    )(scales, a_p, w_p)
    return out[:M, :N]
