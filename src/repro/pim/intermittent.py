"""Power-intermittency simulation (paper §II-B3, Fig. 7).

Models a battery-less node computing frame-by-frame under random power
failures (exponential MTBF).  With NV-FA retention (checkpoint period P
frames), a failure loses only the work since the last NV write plus the
in-flight adds (~(m+n)*58 ps — negligible); without it (P=0), a failure
restarts the whole current frame sequence (volatile accumulators).
"""
from __future__ import annotations


import numpy as np

from repro.core.compressor import NVFATiming


def forward_progress(n_frames: int, frame_time_us: float, mtbf_us: float,
                     checkpoint_period_frames: int, nv_write_us: float = 1.0,
                     m_bits: int = 1, n_bits: int = 8, seed: int = 0,
                     resume_us: float = 0.0) -> dict:
    """Simulate until n_frames complete; returns progress statistics.

    checkpoint_period_frames = 0 -> no NV retention (volatile baseline):
    a power failure discards ALL frames since the sequence start.

    ``resume_us`` models the RESTART overhead paid after every power
    failure before the first post-failure frame can run — the software
    analogue of re-deriving the execution mapping.  A node without a
    persisted ModelPlan re-quantizes weights, re-runs engine
    selection/autotune, and recompiles (large ``resume_us``); a node with
    a plan on disk (``core/plan.save_plan``) just reloads it (small).
    :func:`plan_resume_study` sweeps exactly this comparison.
    """
    # validate before the simulation loop: mtbf_us <= 0 would make every
    # exponential draw zero (an infinite failure loop inside the budget),
    # and the others silently return nonsense statistics
    if n_frames <= 0:
        raise ValueError(f"n_frames must be positive, got {n_frames}")
    if frame_time_us <= 0:
        raise ValueError(f"frame_time_us must be positive, "
                         f"got {frame_time_us}")
    if mtbf_us <= 0:
        raise ValueError(f"mtbf_us must be positive, got {mtbf_us}")
    if checkpoint_period_frames < 0:
        raise ValueError(f"checkpoint_period_frames must be >= 0 "
                         f"(0 = volatile), got {checkpoint_period_frames}")
    if nv_write_us < 0:
        raise ValueError(f"nv_write_us must be >= 0, got {nv_write_us}")
    if resume_us < 0:
        raise ValueError(f"resume_us must be >= 0, got {resume_us}")
    rng = np.random.RandomState(seed)
    t = 0.0
    committed = 0          # frames durably retained
    in_flight = 0          # frames since last NV write
    failures = 0
    wasted_us = 0.0
    budget_us = n_frames * frame_time_us * 50  # hard stop
    nvfa = NVFATiming()
    while committed + in_flight < n_frames and t < budget_us:
        next_fail = rng.exponential(mtbf_us)
        frame_cost = frame_time_us
        if checkpoint_period_frames and (in_flight + 1) % checkpoint_period_frames == 0:
            frame_cost += nv_write_us
        if next_fail < frame_cost:
            # power lost mid-frame: lose in-flight work (plus the current
            # frame), then pay the restart/replan overhead — which runs on
            # the SAME failure-prone supply, so a long replan can itself be
            # interrupted and must restart from scratch (this compounding
            # is exactly why persisting the plan matters)
            failures += 1
            lost = in_flight if checkpoint_period_frames else committed + in_flight
            wasted_us += lost * frame_time_us + next_fail
            t += next_fail
            while resume_us > 0.0 and t < budget_us:
                resume_fail = rng.exponential(mtbf_us)
                if resume_fail >= resume_us:
                    t += resume_us
                    wasted_us += resume_us
                    break
                failures += 1
                t += resume_fail
                wasted_us += resume_fail
            if checkpoint_period_frames:
                in_flight = 0
            else:
                committed, in_flight = 0, 0
            continue
        t += frame_cost
        in_flight += 1
        if checkpoint_period_frames and in_flight >= checkpoint_period_frames:
            committed += in_flight
            in_flight = 0
    # Frames surviving at the end: if the sequence COMPLETED, the volatile
    # tail is read out while still powered and counts.  If the budget_us
    # hard-stop fired, only NV-committed frames are durable — volatile
    # in_flight work dies with the next power cycle, and counting it would
    # overstate the no-retention (P=0) baseline, which keeps *everything*
    # volatile until the sequence end.
    finished = committed + in_flight >= n_frames
    done = min(committed + in_flight, n_frames) if finished else committed
    useful_us = done * frame_time_us
    return dict(
        completed_frames=int(done),
        failures=failures,
        total_time_us=t,
        wasted_us=wasted_us,
        efficiency=useful_us / t if t else 0.0,
        vulnerable_window_ps=nvfa.vulnerable_window_ps(m_bits, n_bits),
    )


def _study_rng(seed, rng) -> np.random.RandomState:
    """One RNG discipline for every multi-draw study: an explicit
    ``RandomState`` wins, else a fresh one from ``seed`` — never ambient
    global state, so every study is a pure function of its arguments."""
    if rng is not None:
        if not isinstance(rng, np.random.RandomState):
            raise TypeError(f"rng must be a numpy RandomState, "
                            f"got {type(rng).__name__}")
        return rng
    return np.random.RandomState(seed)


def _aggregate(runs: list[dict]) -> dict:
    """Mean ± 95% CI over repeated simulations.  Keeps the single-draw key
    names (``efficiency``, ``completed_frames``, ``failures``, ...) as the
    means so existing table/benchmark consumers read the same fields."""
    out: dict = {}
    n = len(runs)
    for key in ("completed_frames", "failures", "total_time_us",
                "wasted_us", "efficiency"):
        vals = np.asarray([r[key] for r in runs], float)
        out[key] = float(vals.mean())
        # normal-approximation 95% CI half-width; 0 for a single draw
        out[key + "_ci95"] = float(1.96 * vals.std(ddof=1) / np.sqrt(n)
                                   if n > 1 else 0.0)
    out["repeats"] = n
    out["vulnerable_window_ps"] = runs[0]["vulnerable_window_ps"]
    return out


def sweep_checkpoint_period(periods=(0, 1, 2, 5, 10, 20, 50),
                            mtbf_us: float = 500.0, n_frames: int = 500,
                            frame_time_us: float = 100.0, seed: int = 0,
                            repeats: int = 8, rng=None) -> dict[int, dict]:
    """Fig.-7-style study: efficiency vs NV write period (20 frames is the
    paper's default; higher periods trade resilience for write energy).

    Each period is simulated ``repeats`` times on seeds drawn from one
    explicit RNG (``seed`` or a caller-supplied ``rng``); every reported
    statistic is a mean with a ``*_ci95`` half-width alongside.
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    r = _study_rng(seed, rng)
    # one seed block per period, drawn up front so adding a period never
    # perturbs the seeds of the ones before it
    seeds = {p: r.randint(0, 2**31 - 1, size=repeats) for p in periods}
    return {p: _aggregate([forward_progress(n_frames, frame_time_us,
                                            mtbf_us, p, seed=int(s))
                           for s in seeds[p]])
            for p in periods}


def plan_resume_study(compile_us: float, plan_load_us: float,
                      checkpoint_period_frames: int = 20,
                      mtbf_us: float = 500.0, n_frames: int = 500,
                      frame_time_us: float = 100.0, seed: int = 0,
                      repeats: int = 16, rng=None) -> dict:
    """Restart-cost study: persisted ModelPlan vs full replan per failure.

    The paper's node resumes instantly because its execution mapping lives
    in non-volatile sub-arrays; our software analogue only matches that
    when the compiled plan (prequantized levels + engine verdicts) is on
    disk.  ``compile_us`` is the measured cold compile+autotune cost,
    ``plan_load_us`` the measured ``load_plan`` cost — both come from
    ``benchmarks/bench_serve.plan_rows``.

    The study is ``repeats`` paired simulations: each repeat draws one
    failure seed from an explicit RNG (``seed`` or ``rng``) and runs BOTH
    arms on it, so the per-pair delta is purely the resume overhead.
    Reported efficiencies are means with 95% CIs (``efficiency_ci95``);
    ``efficiency_gain`` is the ratio of the arm means.
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    r = _study_rng(seed, rng)
    pair_seeds = [int(s) for s in r.randint(0, 2**31 - 1, size=repeats)]
    kw = dict(n_frames=n_frames, frame_time_us=frame_time_us,
              mtbf_us=mtbf_us,
              checkpoint_period_frames=checkpoint_period_frames)
    recompile = _aggregate([forward_progress(resume_us=compile_us, seed=s,
                                             **kw) for s in pair_seeds])
    reload_ = _aggregate([forward_progress(resume_us=plan_load_us, seed=s,
                                           **kw) for s in pair_seeds])
    return dict(
        recompile=recompile, plan_reload=reload_,
        efficiency_gain=(reload_["efficiency"]
                         / max(recompile["efficiency"], 1e-12)))
