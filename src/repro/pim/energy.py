"""Device/circuit energy-latency-area models for the four accelerators the
paper compares (§III-C/D/E): the proposed SOT-MRAM AND-Accumulation design,
IMCE (SOT-MRAM, serial counters), a ReRAM PIM (PRIME-like), and a CMOS ASIC
(YodaNN-like).

The paper reports ratios and Table II absolutes but not its raw circuit
constants (Cadence/NVSim outputs).  We therefore build the *structural*
cycle/op model from the paper's dataflow description and calibrate the
per-op energy/latency constants within literature-plausible ranges (45 nm,
SOT-MRAM sensing ~fJ/bit, ReRAM ADC ~pJ/sample, eDRAM access ~pJ/byte) so
that the headline claims emerge from the model:

  vs IMCE : ~2.1x energy-efficiency, ~3x speed   (compressor vs serial counter)
  vs ReRAM: ~5.4x energy-efficiency, ~9x speed   (matrix splitting + ADC)
  vs ASIC : ~9.7x energy-efficiency, ~13.5x speed (data movement wall)

CALIBRATED constants are marked below; the benchmark asserts the emergent
end-to-end ratios against the paper's claims.
"""
from __future__ import annotations

import dataclasses

SUBARRAY_ROWS = 256
SUBARRAY_COLS = 512          # paper: 256 rows x 512 cols per mat
MATS_PER_BANK = 4            # 2x2
BANKS_PER_GROUP = 64         # 8x8
GROUPS = 16                  # 512 Mb total
CLOCK_GHZ = 1.0

# Table II absolutes — the single source of truth (api/targets.py imports
# these; they used to be mirrored there).  TABLE2_ENERGY_SCALE is the
# per-design energy scale fitted ONCE to the Table II ImageNet column
# (repro.api.reports.calibrate refits; values pinned for determinism).
# TABLE2_AREA_MM2 holds the Table II / §III-E computational areas; ASIC is
# YodaNN-like logic + 33 MB eDRAM @ ~0.1 um^2/bit (45 nm) ~= 30 mm^2.
TABLE2_ENERGY_SCALE = dict(proposed=0.6602, imce=0.5586, reram=0.3662,
                           asic=0.661)
TABLE2_AREA_MM2 = dict(proposed=2.60, imce=2.12, reram=9.19, asic=30.0)


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Per-operation energy (pJ) and latency (cycles) for one design."""

    name: str
    # energy, pJ per 512-bit row operation unless noted
    e_and_row: float          # in-memory AND sense of one row pair
    e_write_row: float        # write one 512-bit row (result write-back)
    e_cmp_row: float          # bitcount of one row (compressor or counter)
    e_accum: float            # shift+add of one partial sum (ASR + NV-FA)
    e_static_per_cycle: float # leakage + peripheral, pJ/cycle
    # latency, cycles
    c_and: int
    c_write: int
    c_cmp: int                # compressor: O(1); serial counter: O(bits)
    c_accum: int
    # area
    area_mm2_per_macro: float # one computational sub-array + periphery
    n_parallel_subarrays: int # sub-arrays usable in parallel (area-normalized)
    # fixed per-MAC path for non-PIM (ASIC): pJ per MAC including SRAM/eDRAM
    e_mac_asic: float = 0.0
    c_macs_per_cycle: int = 0


# --- CALIBRATED MODELS (see module docstring) ------------------------------

PROPOSED = DeviceModel(
    name="proposed",
    e_and_row=2.0,       # SOT-MRAM dual-row sense ~4 fJ/bit x 512
    e_write_row=26.0,    # SOT write ~50 fJ/bit x 512 (result write-back)
    e_cmp_row=14.0,      # one in-memory XOR update + MUX tree settle
    e_accum=1.5,         # ASR (MUX) + NV-FA add, amortized per row
    e_static_per_cycle=0.8,
    c_and=1, c_write=1, c_cmp=2, c_accum=1,   # 5 cycles / row-op
    # Table II ImageNet config, per 1024-macro chip
    area_mm2_per_macro=TABLE2_AREA_MM2["proposed"] / 1024,
    n_parallel_subarrays=64,
)

IMCE = DeviceModel(
    name="imce",
    e_and_row=2.0,
    e_write_row=26.0,
    # serial counter: 8 shift+add sub-ops per resultant row (footnote 1:
    # "determined by the memory array size, i.e. 8 bits")
    e_cmp_row=8 * 7.0,
    e_accum=1.5,
    e_static_per_cycle=0.8,
    c_and=1, c_write=1, c_cmp=12, c_accum=1,  # 15 cycles / row-op (~3x)
    area_mm2_per_macro=TABLE2_AREA_MM2["imce"] / 1024,
    n_parallel_subarrays=64,
)

RERAM = DeviceModel(
    name="reram",
    # analog MAC but ADC-dominated; matrix splitting for multi-bit weights
    # occupies extra sub-arrays and serializes (paper: "excessive sub-arrays
    # are occupied... can further limit parallelism")
    e_and_row=4.0,       # DAC drive + bitline settle
    e_write_row=210.0,   # ReRAM SET/RESET ~0.4 pJ/bit x 512
    e_cmp_row=160.0,     # 8-bit ADC x 64 samples/row @ ~0.3 pJ
    e_accum=3.0,
    e_static_per_cycle=2.4,
    c_and=2, c_write=4, c_cmp=8, c_accum=1,   # 15 cycles, and
    area_mm2_per_macro=TABLE2_AREA_MM2["reram"] / 1024,
    n_parallel_subarrays=64 // 3,             # matrix splitting occupancy
)

ASIC = DeviceModel(
    name="asic",
    e_and_row=0.0, e_write_row=0.0, e_cmp_row=0.0, e_accum=0.0,
    e_static_per_cycle=30.0,   # eDRAM refresh + SRAM banks + NoC
    c_and=0, c_write=0, c_cmp=0, c_accum=0,
    area_mm2_per_macro=0.0,
    n_parallel_subarrays=0,
    # YodaNN-like: binary-weight MACs; energy dominated by eDRAM traffic.
    e_mac_asic=0.48,           # pJ per (binary) MAC incl. memory movement
    c_macs_per_cycle=784,      # 8x8 tiles x ~12 MAC lanes sustained
)

DESIGNS = {d.name: d for d in (PROPOSED, IMCE, RERAM, ASIC)}
