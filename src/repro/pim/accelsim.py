"""DEPRECATED shim (one release): end-to-end accelerator simulation.

The Table II / Fig. 9 / Fig. 10 reproductions now live in
:mod:`repro.api.reports`, built on the HardwareTarget registry
(:mod:`repro.api.targets`) — ``simulate(design, dataset)`` there compiles
a ModelPlan for the dataset's CNN and prices it on the named target
instead of re-walking specs.  This module re-exports the old names
bit-identically and will be removed next release; importing it emits one
:class:`DeprecationWarning`.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.pim.accelsim is deprecated; use repro.api (build(...).compile()"
    ".simulate(target=...)) or repro.api.reports (simulate/table2/"
    "fig9_fig10) — removal in the next release",
    DeprecationWarning, stacklevel=2)

from repro.api.reports import (  # noqa: E402,F401 (re-exported legacy names)
    CLAIMS, DATASETS, TABLE2, TABLE2_SVHN_CHANNELS, calibrate, fig9_fig10,
    lenet_spec, simulate, table2)
from repro.api.targets import AREA_MM2, ENERGY_SCALE  # noqa: E402,F401
