"""End-to-end accelerator simulation reproducing the paper's tables.

Calibration protocol (DESIGN.md §2, honest-knobs policy):
  * Cycle structure is *structural* — derived from each design's dataflow
    (compressor vs serial counter vs ADC vs MAC array), never fitted.
  * One energy scale per design is fitted to the ImageNet column of
    Table II (the only absolute numbers the paper publishes).
  * SVHN / MNIST columns and the Fig. 9/10 ratios are then *predictions*
    of the model — the benchmarks assert them against the paper's claims.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.models.cnn import ConvSpec, alexnet_spec, svhn_cnn_spec
from .energy import DESIGNS, DeviceModel
from .mapper import accel_cost, model_work

# Table II (paper): energy uJ/img and area mm2 per design per dataset.
TABLE2 = {
    "reram":    dict(imagenet=(2275.34, 9.19), svhn=(425.21, 0.085), mnist=(13.55, 0.060)),
    "imce":     dict(imagenet=(785.25, 2.12),  svhn=(135.26, 0.010), mnist=(0.92, 0.009)),
    "proposed": dict(imagenet=(471.8, 2.60),   svhn=(84.31, 0.039),  mnist=(0.68, 0.012)),
}

# Headline claims (abstract / §III-C,D).
CLAIMS = dict(
    imce=dict(energy=2.1, speed=3.0),
    reram=dict(energy=5.4, speed=9.0),
    asic=dict(energy=9.7, speed=13.5),
)

AREA_MM2 = dict(proposed=2.60, imce=2.12, reram=9.19, asic=30.0)
# ASIC area: YodaNN-like logic + 33 MB eDRAM @ ~0.1 um^2/bit (45 nm) ~= 30 mm^2.


def lenet_spec() -> list[ConvSpec]:
    """LeNet-5-style MNIST model for the Table II MNIST column."""
    return [
        ConvSpec(1, 6, 5, role="first"),
        ConvSpec(6, 16, 5, pool=True),
        ConvSpec(16, 120, 5, pool=True, fc=True),
        ConvSpec(120, 84, 1, fc=True),
        ConvSpec(84, 10, 1, fc=True, role="last"),
    ]


# Table II's SVHN BCNN is larger than the Table I accuracy model (the paper
# reuses the BCNN of [8] for the energy rows); width chosen structurally so
# the MAC count sits between MNIST and ImageNet like the paper's.
TABLE2_SVHN_CHANNELS = 72

DATASETS = {
    "imagenet": dict(spec=alexnet_spec, img=224),
    "svhn": dict(spec=lambda: svhn_cnn_spec(TABLE2_SVHN_CHANNELS), img=40),
    "mnist": dict(spec=lenet_spec, img=28),
}

# Energy scale per design, fitted ONCE to the ImageNet column (see
# calibrate() below; values reproduced here so the sim is deterministic).
ENERGY_SCALE = dict(proposed=0.6602, imce=0.5586, reram=0.3662, asic=0.661)


def simulate(design: str, dataset: str, m_bits: int = 1, n_bits: int = 1) -> dict:
    d = DESIGNS[design]
    ds = DATASETS[dataset]
    works = model_work(ds["spec"](), ds["img"], m_bits, n_bits)
    r = accel_cost(d, works)
    r["energy_uj"] *= ENERGY_SCALE[design]
    r["area_mm2"] = AREA_MM2[design]
    r["fps_per_mm2"] = r["fps"] / r["area_mm2"]
    r["gops_per_w"] = (r["macs"] * 2e-9) / (r["energy_uj"] * 1e-6)
    r["eff_per_mm2"] = r["gops_per_w"] / r["area_mm2"]
    return r


def table2(m_bits: int = 1, n_bits: int = 1) -> dict:
    """Reproduce Table II: energy/area per design per dataset (BCNN 1:1)."""
    out = {}
    for design in ("reram", "imce", "proposed"):
        out[design] = {
            ds: dict(energy_uj=simulate(design, ds, m_bits, n_bits)["energy_uj"],
                     area_mm2=AREA_MM2[design])
            for ds in DATASETS
        }
    return out


def fig9_fig10(configs=((1, 1), (1, 4), (1, 8), (2, 2))) -> dict:
    """Area-normalized energy-efficiency (Fig. 9) and fps (Fig. 10) across
    W:I configs, averaged over datasets, ratios vs the proposed design."""
    effs: dict[str, list] = {k: [] for k in DESIGNS}
    fpss: dict[str, list] = {k: [] for k in DESIGNS}
    for (n_b, m_b) in configs:  # (W, I)
        for ds in DATASETS:
            for design in DESIGNS:
                r = simulate(design, ds, m_b, n_b)
                effs[design].append(r["eff_per_mm2"])
                fpss[design].append(r["fps_per_mm2"])
    gmean = lambda xs: float(__import__("numpy").exp(
        __import__("numpy").mean(__import__("numpy").log(xs))))
    eff = {k: gmean(v) for k, v in effs.items()}
    fps = {k: gmean(v) for k, v in fpss.items()}
    return dict(
        eff_per_mm2=eff, fps_per_mm2=fps,
        energy_ratio={k: eff["proposed"] / eff[k] for k in DESIGNS if k != "proposed"},
        speed_ratio={k: fps["proposed"] / fps[k] for k in DESIGNS if k != "proposed"},
    )


def calibrate() -> dict[str, float]:
    """Refit ENERGY_SCALE to the Table II ImageNet column (dev utility)."""
    scales = {}
    for design in ("proposed", "imce", "reram"):
        d = DESIGNS[design]
        works = model_work(alexnet_spec(), 224, 1, 1)
        raw = accel_cost(d, works)["energy_uj"]
        scales[design] = TABLE2[design]["imagenet"][0] / raw
    scales["asic"] = ENERGY_SCALE["asic"]
    return scales
