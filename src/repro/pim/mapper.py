"""Map bit-wise CNN layers onto computational sub-arrays (paper Fig. 3) and
count row-operations/cycles/energy per design.

For a conv layer with K = kh*kw*Cin inputs per output, m-bit activations and
n-bit weights:
  bit products    = out_elems * K * m * n
  row operations  = bit products / 512           (one row-AND covers 512 cells)
  per row-op      : AND sense -> result write-back -> CMP -> shift/accum
The proposed design's CMP is the in-memory 4:2 compressor (O(1) passes);
IMCE's is a serial counter (O(8) passes) — that single difference is the
paper's 2.1x/3x claim over IMCE and is structural here, not calibrated.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.models.cnn import ConvSpec
from .energy import CLOCK_GHZ, DESIGNS, SUBARRAY_COLS, DeviceModel


@dataclasses.dataclass
class LayerWork:
    macs: int
    bit_products: int
    row_ops: int


def layer_work(spec: ConvSpec, in_hw: int, m_bits: int, n_bits: int) -> tuple[LayerWork, int]:
    """Returns (work, out_hw)."""
    if spec.fc:
        oh = 1
    else:
        oh = max(-(-in_hw // spec.stride), 1)
    macs = oh * oh * spec.k * spec.k * spec.cin * spec.cout
    bitp = macs * m_bits * n_bits
    return LayerWork(macs=macs, bit_products=bitp,
                     row_ops=-(-bitp // SUBARRAY_COLS)), (oh // 2 if spec.pool else oh)


def model_work(specs: Sequence[ConvSpec], img: int, m_bits: int, n_bits: int,
               quant_first_last_fp: bool = True):
    """Per-layer work; first/last layers run at 8-bit fp-ish precision."""
    hw = img
    works = []
    for s in specs:
        mb, nb = m_bits, n_bits
        if quant_first_last_fp and s.role in ("first", "last"):
            mb, nb = 8, 8  # fp layers execute as 8-bit fixed point in-memory
        w, hw = layer_work(s, hw, mb, nb)
        works.append(w)
    return works


def accel_cost(design: DeviceModel, works: Sequence[LayerWork]) -> dict:
    """Energy (uJ) and latency (us) for one image on one design."""
    total_macs = sum(w.macs for w in works)
    total_rows = sum(w.row_ops for w in works)
    if design.e_mac_asic:  # CMOS ASIC path
        cycles = total_macs / max(design.c_macs_per_cycle, 1)
        energy_pj = total_macs * design.e_mac_asic + cycles * design.e_static_per_cycle
    else:
        per_row_cycles = design.c_and + design.c_write + design.c_cmp + design.c_accum
        par = max(design.n_parallel_subarrays, 1)
        cycles = total_rows * per_row_cycles / par
        energy_pj = total_rows * (
            design.e_and_row + design.e_write_row + design.e_cmp_row + design.e_accum
        ) + cycles * design.e_static_per_cycle
    latency_us = cycles / (CLOCK_GHZ * 1e3)
    return dict(
        energy_uj=energy_pj * 1e-6,
        latency_us=latency_us,
        fps=1e6 / latency_us if latency_us else float("inf"),
        macs=total_macs,
        row_ops=total_rows,
    )


def compare_designs(specs, img: int, m_bits: int, n_bits: int,
                    area_mm2: dict[str, float] | None = None) -> dict[str, dict]:
    """Run all four designs over one model; optionally area-normalize."""
    out = {}
    for name, d in DESIGNS.items():
        works = model_work(specs, img, m_bits, n_bits)
        r = accel_cost(d, works)
        if area_mm2 and name in area_mm2 and area_mm2[name]:
            r["fps_per_mm2"] = r["fps"] / area_mm2[name]
            r["eff_per_mm2"] = (r["macs"] * 2 / (r["energy_uj"] * 1e-6)) / area_mm2[name]
        r["gops_per_w"] = (r["macs"] * 2e-9) / (r["energy_uj"] * 1e-6)
        out[name] = r
    return out
