"""Map bit-wise CNN layers onto computational sub-arrays (paper Fig. 3) and
count row-operations/cycles/energy per design.

For a conv layer with K = kh*kw*Cin inputs per output, m-bit activations and
n-bit weights:
  bit products    = out_elems * K * m * n
  row operations  = bit products / 512           (one row-AND covers 512 cells)
  per row-op      : AND sense -> result write-back -> CMP -> shift/accum
The proposed design's CMP is the in-memory 4:2 compressor (O(1) passes);
IMCE's is a serial counter (O(8) passes) — that single difference is the
paper's 2.1x/3x claim over IMCE and is structural here, not calibrated.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.models.cnn import ConvSpec
from .energy import CLOCK_GHZ, DESIGNS, SUBARRAY_COLS, DeviceModel


@dataclasses.dataclass
class LayerWork:
    macs: int
    bit_products: int
    row_ops: int


def layer_work(spec: ConvSpec, in_hw: int, m_bits: int, n_bits: int) -> tuple[LayerWork, int]:
    """Returns (work, out_hw).

    Spatial bookkeeping mirrors the paper's Fig. 3 walk (and
    ``models/cnn.count_macs``): the conv output is the ceil-div of the
    input extent by the stride FIRST, and the 2x2 average-pool halving
    applies to that output afterwards, floored at 1 so a pooled 1x1 map
    (LeNet's pooled FC stage) cannot collapse downstream layers to zero
    extent.  FC layers reduce to 1x1 regardless of input extent.
    """
    if in_hw < 1:
        raise ValueError(f"layer_work: input extent must be >= 1, got {in_hw}")
    if spec.fc:
        oh = 1
    else:
        oh = max(-(-in_hw // spec.stride), 1)
    macs = oh * oh * spec.k * spec.k * spec.cin * spec.cout
    bitp = macs * m_bits * n_bits
    return LayerWork(macs=macs, bit_products=bitp,
                     row_ops=-(-bitp // SUBARRAY_COLS)), \
        (max(oh // 2, 1) if spec.pool else oh)


def model_work(specs: Sequence[ConvSpec], img: int, m_bits: int, n_bits: int,
               quant_first_last_fp: bool = True):
    """Per-layer work; first/last layers run at 8-bit fp-ish precision."""
    hw = img
    works = []
    for s in specs:
        mb, nb = m_bits, n_bits
        if quant_first_last_fp and s.role in ("first", "last"):
            mb, nb = 8, 8  # fp layers execute as 8-bit fixed point in-memory
        w, hw = layer_work(s, hw, mb, nb)
        works.append(w)
    return works


def effective_bits(lp) -> tuple[int, int]:
    """(a_bits, w_bits) a layer *executes* at: full-precision layers run
    as 8-bit fixed point in-memory (``model_work``'s quant_first_last_fp
    policy).  The single source for every cost/works computation — plan
    annotations (`core/plan._annotate_costs`), works derivation below, and
    `repro.api.session.CompiledModel.simulate` all price with this."""
    return (8, 8) if lp.fp else (lp.a_bits, lp.w_bits)


def works_from_layers(layers: Sequence) -> list[LayerWork]:
    """Per-layer work from compiled ``LayerPlan`` records (duck-typed:
    anything with ``out_h/out_w/kh/kw/cin/cout/fp/a_bits/w_bits``).

    Same arithmetic as :func:`layer_work` — a plan's geometry walk and the
    spec walk of :func:`model_work` agree for the paper's models, so the
    two routes produce bit-identical works (pinned in ``tests/test_api``).
    Full-precision layers execute as 8-bit fixed point in-memory, matching
    ``model_work``'s ``quant_first_last_fp`` policy.
    """
    works = []
    for lp in layers:
        mb, nb = effective_bits(lp)
        macs = lp.out_h * lp.out_w * lp.kh * lp.kw * lp.cin * lp.cout
        bitp = macs * mb * nb
        works.append(LayerWork(macs=macs, bit_products=bitp,
                               row_ops=-(-bitp // SUBARRAY_COLS)))
    return works


def accel_cost(design: DeviceModel, works: Sequence[LayerWork]) -> dict:
    """Energy (uJ) and latency (us) for one image on one design.

    ``works`` must be non-empty: an empty list used to fall through to a
    0-cycle, 0-energy result whose downstream ratios divide zero by zero —
    now it is a loud error at the call site.
    """
    if not works:
        raise ValueError("accel_cost: empty works — map at least one layer "
                         "before costing a design")
    total_macs = sum(w.macs for w in works)
    total_rows = sum(w.row_ops for w in works)
    if design.e_mac_asic:  # CMOS ASIC path
        cycles = total_macs / max(design.c_macs_per_cycle, 1)
        energy_pj = total_macs * design.e_mac_asic + cycles * design.e_static_per_cycle
    else:
        per_row_cycles = design.c_and + design.c_write + design.c_cmp + design.c_accum
        par = max(design.n_parallel_subarrays, 1)
        cycles = total_rows * per_row_cycles / par
        energy_pj = total_rows * (
            design.e_and_row + design.e_write_row + design.e_cmp_row + design.e_accum
        ) + cycles * design.e_static_per_cycle
    latency_us = cycles / (CLOCK_GHZ * 1e3)
    return dict(
        energy_uj=energy_pj * 1e-6,
        latency_us=latency_us,
        fps=1e6 / latency_us if latency_us else float("inf"),
        macs=total_macs,
        row_ops=total_rows,
    )


def compare_designs(specs, img: int, m_bits: int, n_bits: int,
                    area_mm2: dict[str, float] | None = None) -> dict[str, dict]:
    """Run all four designs over one model; optionally area-normalize."""
    out = {}
    for name, d in DESIGNS.items():
        works = model_work(specs, img, m_bits, n_bits)
        r = accel_cost(d, works)
        if area_mm2 and name in area_mm2 and area_mm2[name]:
            r["fps_per_mm2"] = r["fps"] / area_mm2[name]
            r["eff_per_mm2"] = (r["macs"] * 2 / (r["energy_uj"] * 1e-6)) / area_mm2[name]
        r["gops_per_w"] = (r["macs"] * 2e-9) / (r["energy_uj"] * 1e-6)
        out[name] = r
    return out
