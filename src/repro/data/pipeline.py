"""Sharded, prefetching host data pipeline.

Deterministic addressing is the backbone of both fault tolerance and
straggler mitigation (train/elastic.py): every batch is a pure function of
(step, micro, host), so restarts replay identically and any host can
compute any other host's shard.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import jax


class Pipeline:
    def __init__(self, batch_fn: Callable[[int, int], Any], *,
                 accum_steps: int = 1, prefetch: int = 2,
                 host_index: Optional[int] = None, n_hosts: Optional[int] = None):
        """batch_fn(step, micro) -> GLOBAL batch dict of np arrays; the
        pipeline slices this host's shard and prefetches ahead."""
        self.batch_fn = batch_fn
        self.accum = accum_steps
        self.host = jax.process_index() if host_index is None else host_index
        self.n_hosts = jax.process_count() if n_hosts is None else n_hosts
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._cursor = 0

    def _shard(self, batch):
        def slc(x):
            per = x.shape[0] // self.n_hosts
            return x[self.host * per: (self.host + 1) * per]
        return {k: slc(v) for k, v in batch.items()}

    def _producer(self, start_step: int):
        step, micro = start_step, 0
        while not self._stop.is_set():
            item = self._shard(self.batch_fn(step, micro))
            self._q.put(((step, micro), item))
            micro += 1
            if micro == self.accum:
                micro, step = 0, step + 1

    def start(self, start_step: int = 0):
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._producer, args=(start_step,), daemon=True)
        self._thread.start()
        return self

    def __next__(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
