"""Deterministic synthetic datasets (no network access in this container).

* ``svhn_like`` — 10-class 40x40x3 digit-ish images: class-conditional
  structured templates (strokes on textured background) + noise.  Rich
  enough that quantization bit-width measurably moves accuracy — which is
  all Table I needs (the *ordering* of W:I configs, not SVHN absolutes).
* ``lm_stream`` — Markov-chain token stream with local structure so an LM
  can beat the unigram floor within a few hundred steps.
"""
from __future__ import annotations

import numpy as np


def _digit_template(cls: int, size: int = 40, seed: int = 1234) -> np.ndarray:
    """Procedural 7-segment-ish digit rendering + per-class texture."""
    rng = np.random.RandomState(seed + cls)
    img = np.zeros((size, size, 3), np.float32)
    # textured background unique to nothing (shared stats)
    img += 0.25
    segs = {  # 7-segment map
        0: [0, 1, 2, 4, 5, 6], 1: [2, 5], 2: [0, 2, 3, 4, 6],
        3: [0, 2, 3, 5, 6], 4: [1, 2, 3, 5], 5: [0, 1, 3, 5, 6],
        6: [0, 1, 3, 4, 5, 6], 7: [0, 2, 5], 8: list(range(7)),
        9: [0, 1, 2, 3, 5, 6],
    }[cls]
    m, w = size // 8, size // 10  # margins, stroke width
    h = size - 2 * m
    coords = {
        0: (slice(m, m + w), slice(m, size - m)),                       # top
        1: (slice(m, m + h // 2), slice(m, m + w)),                     # top-left
        2: (slice(m, m + h // 2), slice(size - m - w, size - m)),       # top-right
        3: (slice(m + h // 2 - w // 2, m + h // 2 + w - w // 2), slice(m, size - m)),
        4: (slice(m + h // 2, size - m), slice(m, m + w)),              # bot-left
        5: (slice(m + h // 2, size - m), slice(size - m - w, size - m)),
        6: (slice(size - m - w, size - m), slice(m, size - m)),         # bottom
    }
    color = 0.5 + 0.5 * rng.rand(3)
    for s in segs:
        img[coords[s]] = color
    return img


_TEMPLATES: dict[int, np.ndarray] = {}


def svhn_like(n: int, *, seed: int = 0, size: int = 40):
    """Returns (images (n,size,size,3) float32 in [0,1], labels (n,) int32)."""
    if size not in _TEMPLATES:
        _TEMPLATES[size] = np.stack([_digit_template(c, size) for c in range(10)])
    t = _TEMPLATES[size]
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, n).astype(np.int32)
    imgs = t[labels].copy()
    # global illumination + shifts + noise (SVHN-ish nuisances)
    gain = 0.6 + 0.8 * rng.rand(n, 1, 1, 1).astype(np.float32)
    imgs *= gain
    shift = rng.randint(-3, 4, (n, 2))
    for i in range(n):  # cheap jitter
        imgs[i] = np.roll(imgs[i], shift[i], axis=(0, 1))
    imgs += rng.randn(*imgs.shape).astype(np.float32) * 0.15
    return np.clip(imgs, 0.0, 1.0), labels


def lm_stream(n_tokens: int, vocab: int, *, seed: int = 0, order: int = 1):
    """Markov token stream: P(t|prev) concentrated on ~8 successors."""
    rng = np.random.RandomState(seed)
    succ = rng.randint(0, vocab, (vocab, 8))
    out = np.empty(n_tokens, np.int32)
    t = rng.randint(vocab)
    for i in range(n_tokens):
        out[i] = t
        t = succ[t, rng.randint(8)] if rng.rand() < 0.9 else rng.randint(vocab)
    return out


def lm_batch(step: int, micro: int, *, batch: int, seq: int, vocab: int,
             seed: int = 0):
    """Deterministically addressed LM batch: (tokens, labels)."""
    rng = np.random.RandomState((seed * 1_000_003 + step) * 97 + micro)
    succ_rng = np.random.RandomState(seed)
    succ = succ_rng.randint(0, vocab, (vocab, 8))
    toks = np.empty((batch, seq + 1), np.int32)
    t = rng.randint(0, vocab, batch)
    for i in range(seq + 1):
        toks[:, i] = t
        jump = rng.rand(batch) < 0.1
        t = np.where(jump, rng.randint(0, vocab, batch),
                     succ[t, rng.randint(0, 8, batch)])
    return dict(tokens=toks[:, :-1], labels=toks[:, 1:])
