"""Fleet-scale intermittency simulation + per-node plan co-design.

``traces``  seeded harvest-trace generators (solar / rf / thermal);
``sim``     the fluid fleet simulator and its live-engine validation arm;
``search``  per-node (quant, target, period) co-design under accuracy SLOs.

See DESIGN.md §14.  Import is jax-free: only the live-validation arm pulls
in the serve stack, lazily.
"""
from .search import (REFERENCE_ERROR_PCT, SLO_LEVELS, assign_slos,
                     candidate_space, codesign, frame_cost_table,
                     load_accuracy_table)
from .sim import (NodeConfig, epoch_schedule, fleet_report, live_validation,
                  measured_efficiency, outage_faultplan,
                  predict_engine_stats, rescale_outages, simulate_fleet,
                  simulate_node)
from .traces import (ARCHETYPES, DAY_S, DEFAULT_MIX, HarvestTrace, TraceSpec,
                     generate_fleet, make_trace)

__all__ = [
    "ARCHETYPES", "DAY_S", "DEFAULT_MIX", "HarvestTrace", "NodeConfig",
    "REFERENCE_ERROR_PCT", "SLO_LEVELS", "TraceSpec", "assign_slos",
    "candidate_space", "codesign", "epoch_schedule", "fleet_report",
    "frame_cost_table", "generate_fleet", "live_validation",
    "load_accuracy_table", "make_trace", "measured_efficiency",
    "outage_faultplan", "predict_engine_stats", "rescale_outages",
    "simulate_fleet", "simulate_node",
]
