"""Per-node plan co-design search over the fleet (DESIGN.md §14).

Each node's operating point is a candidate ``(quant, pim_target,
checkpoint_period)``.  The search couples BOTH measured frontiers the repo
already produces:

* **complexity/accuracy** — Table-I test error per quant config, read from
  ``results/bench_rows.json`` (``benchmarks/run.py`` output) when present,
  else the pinned reference numbers below; a node's accuracy SLO (max
  test-error %) filters which quants it may run.
* **energy/latency** — the plan's Table-II-pinned per-frame cost on each
  PIM target, priced by ``core/plan.plan_cost_on`` from ONE structure-only
  compiled plan per quant (no weights, no jax arrays — pure cost model).

Feasible candidates are then scored by actually simulating the node's
harvest trace (:mod:`repro.fleet.sim`), so the winner reflects the full
intermittency story — buffer size, duty cycling, checkpoint commit cost,
resume overhead — not just energy per frame.  The baseline every result is
reported against is the best ONE-CONFIG-FITS-ALL candidate: the single
operating point feasible under every node's SLO that maximizes fleet
inferences/day.  Co-design wins exactly where heterogeneity matters — a
loose-SLO node on a weak harvester picks a cheaper quant than the fleet-
wide accuracy floor forces on the uniform config.

Everything is a pure function of (traces, SLO seed, candidate space):
repro-lint RL001 holds here too.
"""
from __future__ import annotations

import json
import os

import numpy as np

from .sim import NodeConfig, simulate_node
from .traces import HarvestTrace, TraceSpec, make_trace

# Table-I synthetic-SVHN test error (%), benchmarks/paper_tables.py
# table1_accuracy(steps=120) — regenerate with `python benchmarks/run.py`
# and the loader below picks up the fresh numbers from bench_rows.json.
REFERENCE_ERROR_PCT = {
    "w32a32": 7.03, "w1a1": 9.96, "w1a4": 5.08, "w1a8": 8.4, "w2a2": 12.89,
}

# quantized operating points only: fp32 has no PIM mapping story
DEFAULT_QUANTS = ("w1a1", "w1a4", "w1a8", "w2a2")
DEFAULT_TARGETS = ("sot_mram", "imce", "reram", "cmos_asic")
DEFAULT_PERIODS = (1, 10, 50)

# per-node accuracy SLOs (max tolerated test-error %), spanning the
# Table-I frontier: 6.0 admits only w1a4, 13.0 admits every quant
SLO_LEVELS = (6.0, 9.0, 10.5, 13.0)


def load_accuracy_table(path: str | None = "results/bench_rows.json") -> dict:
    """Quant -> test-error %.  Prefers measured Table-I rows from a
    ``benchmarks/run.py`` artifact; falls back to the pinned reference."""
    table = dict(REFERENCE_ERROR_PCT)
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                rows = json.load(f).get("table1_accuracy") or []
            for row in rows:
                if "test_error_pct" in row:
                    table[row["config"]] = float(row["test_error_pct"])
        except (OSError, ValueError, KeyError, TypeError):
            pass   # unreadable artifact -> pinned reference
    return table


def frame_cost_table(quants=DEFAULT_QUANTS, targets=DEFAULT_TARGETS, *,
                     channels: int = 20, img_hw: int = 40) -> dict:
    """(quant, target) -> ``(frame_energy_uj, frame_time_us)`` for the
    paper's SVHN CNN: one structure-only compile per quant, re-priced on
    every PIM target through ``plan_cost_on`` (bit-identical Table-II
    arithmetic)."""
    from repro.core.plan import compile_model, plan_cost_on
    from repro.core.quant import PAPER_CONFIGS
    from repro.models.cnn import svhn_cnn_spec

    costs = {}
    for q in quants:
        plan = compile_model(None, svhn_cnn_spec(channels), PAPER_CONFIGS[q],
                             backend="cpu", img_hw=img_hw, model="svhn_cnn")
        for t in targets:
            r = plan_cost_on(plan, t)
            costs[(q, t)] = (float(r["energy_uj"]), float(r["latency_us"]))
    return costs


def candidate_space(costs: dict, *, quants=DEFAULT_QUANTS,
                    targets=DEFAULT_TARGETS,
                    periods=DEFAULT_PERIODS) -> list[tuple[str, str, int]]:
    """All (quant, target, period) triples, with per-quant Pareto pruning
    over targets: a target strictly worse in BOTH frame energy and frame
    latency than another can never win a node (the simulator is monotone
    in each at fixed harvest), so it is dropped before the O(nodes x
    candidates) simulation loop."""
    cands = []
    for q in quants:
        keep = []
        for t in targets:
            e, lat = costs[(q, t)]
            if any(costs[(q, o)][0] <= e and costs[(q, o)][1] <= lat
                   and costs[(q, o)] != costs[(q, t)]
                   for o in targets if o != t):
                continue
            keep.append(t)
        for t in keep:
            for p in periods:
                cands.append((q, t, int(p)))
    return cands


def assign_slos(n_nodes: int, seed: int = 0, levels=SLO_LEVELS) -> list[float]:
    """Deterministic per-node accuracy SLO draw (uniform over levels)."""
    rng = np.random.RandomState(seed)
    levels = tuple(float(x) for x in levels)
    return [levels[int(i)] for i in rng.randint(0, len(levels),
                                                size=n_nodes)]


def _node_config(node_id: str, cand, costs, node_kw) -> NodeConfig:
    q, t, p = cand
    e, lat = costs[(q, t)]
    return NodeConfig(node_id=node_id, quant=q, target=t, period=p,
                      frame_energy_uj=e, frame_time_us=lat, **node_kw)


def codesign(traces, slos, *, accuracy=None, costs=None, candidates=None,
             node_kw=None) -> dict:
    """Per-node co-design search + one-config-fits-all baseline + Pareto.

    For each node, every SLO-feasible candidate is simulated on the node's
    own trace and the inferences/day argmax wins (ties break to higher
    forward-progress efficiency, then candidate order — deterministic).
    The per-(node, candidate) results are reused to score every globally-
    feasible uniform config, so the baseline costs no extra simulation.

    ``traces``: HarvestTrace/TraceSpec list.  ``slos``: per-node max
    test-error %.  ``node_kw``: shared NodeConfig knobs (resume_us,
    cap_uj, ...).  Returns assignments, fleet aggregates, the baseline,
    and the (inferences/day, worst-case error) Pareto frontier over
    uniform configs plus the co-design point.
    """
    traces = [make_trace(tr) if isinstance(tr, TraceSpec) else tr
              for tr in traces]
    if len(traces) != len(slos):
        raise ValueError(f"got {len(traces)} traces but {len(slos)} SLOs")
    accuracy = accuracy if accuracy is not None else load_accuracy_table()
    costs = costs if costs is not None else frame_cost_table()
    candidates = (candidates if candidates is not None
                  else candidate_space(costs))
    node_kw = dict(node_kw or {})
    infeasible = [s for s in slos
                  if not any(accuracy[q] <= s for q, _, _ in candidates)]
    if infeasible:
        raise ValueError(f"no candidate quant meets SLO {min(infeasible)} "
                         f"(best error: "
                         f"{min(accuracy[q] for q, _, _ in candidates)})")

    assignments, chosen_results = [], []
    # candidate -> summed fleet inferences/day, only while feasible for
    # every node seen so far (the uniform-baseline bookkeeping)
    uniform_ipd = {c: 0.0 for c in candidates
                   if all(accuracy[c[0]] <= s for s in slos)}
    for trace, slo in zip(traces, slos):
        nid = trace.spec.node_id
        best, best_key = None, None
        for cand in candidates:
            if accuracy[cand[0]] > slo:
                continue
            r = simulate_node(trace, _node_config(nid, cand, costs, node_kw))
            if cand in uniform_ipd:
                uniform_ipd[cand] += r["inferences_per_day"]
            key = (r["inferences_per_day"], r["efficiency"])
            if best is None or key > best_key:
                best, best_key = (cand, r), key
        cand, r = best
        assignments.append(dict(node_id=nid, quant=cand[0], target=cand[1],
                                period=cand[2], slo_error_pct=slo,
                                error_pct=accuracy[cand[0]],
                                inferences_per_day=r["inferences_per_day"],
                                efficiency=r["efficiency"], dead=r["dead"]))
        chosen_results.append(r)

    codesign_ipd = float(sum(a["inferences_per_day"] for a in assignments))
    if not uniform_ipd:
        raise ValueError("no single candidate is feasible for every node's "
                         "SLO — one-config-fits-all baseline undefined")
    base_cand = max(uniform_ipd, key=lambda c: (uniform_ipd[c],
                                                -candidates.index(c)))
    baseline_ipd = float(uniform_ipd[base_cand])

    # Pareto over uniform configs: (fleet inferences/day, error %); the
    # co-design point's "error" is its worst assigned error (every node
    # individually meets its own SLO by construction)
    points = [dict(kind="uniform", quant=c[0], target=c[1], period=c[2],
                   inferences_per_day=float(v), error_pct=accuracy[c[0]])
              for c, v in sorted(uniform_ipd.items())]
    points.append(dict(kind="codesign", inferences_per_day=codesign_ipd,
                       error_pct=max(a["error_pct"] for a in assignments)))
    frontier = [p for p in points
                if not any(o["inferences_per_day"] > p["inferences_per_day"]
                           and o["error_pct"] <= p["error_pct"]
                           for o in points)]
    return dict(
        assignments=assignments,
        results=chosen_results,
        inferences_per_day=codesign_ipd,
        baseline=dict(quant=base_cand[0], target=base_cand[1],
                      period=base_cand[2],
                      inferences_per_day=baseline_ipd,
                      error_pct=accuracy[base_cand[0]]),
        win_vs_baseline=(codesign_ipd / baseline_ipd
                         if baseline_ipd > 0 else float("inf")),
        slo_violations=sum(1 for a in assignments
                           if a["error_pct"] > a["slo_error_pct"]),
        pareto=frontier,
        candidates=[list(c) for c in candidates],
    )
