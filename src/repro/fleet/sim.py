"""Fleet-scale intermittency simulator (ROADMAP item 4, DESIGN.md §14).

Steps thousands of battery-less nodes through seeded harvest traces
(:mod:`repro.fleet.traces`) and prices each node's forward progress with
its compiled plan's cost on its PIM target (``core/plan.plan_cost_on`` —
the Table-II-pinned ``(energy_uj, latency_us)`` per frame), charging NV
checkpoint commits at the node's period P and a resume overhead after
every outage, exactly the accounting of ``pim/intermittent``.

Two arms, one failure model:

* **fluid arm** (:func:`simulate_node`) — closed-form segment walking for
  fleet scale.  A node alternates ON (buffer drains at the plan's active
  power minus harvest) and OFF (recharge to the wake threshold); an
  outage fires when the buffer empties, losing the frames since the last
  NV commit.  Within a constant-power trace segment the charge/run cycle
  repeats identically, so k cycles collapse to one closed form — a node
  duty-cycling 30k times/day costs a handful of float ops per segment,
  never a per-frame loop.
* **discrete arm** (:func:`predict_engine_stats` + :func:`live_validation`)
  — the fluid arm's derived outage instants become a
  ``FaultPlan.timeline`` (power_loss at fixed work-clock times), which is
  polled by BOTH a step-exact mirror of ``ResilientServeEngine``'s hook
  sequence and the real engine.  Simulated outages and live-engine chaos
  share one failure model by construction, and the validation contract is
  stated in :func:`live_validation`: integer work counters match exactly,
  float accounting within ``tol``.

Determinism: everything here is a pure function of (trace specs, node
configs) — repro-lint RL001 enforces no wall-clock or ambient randomness
in this package, same as ``resilience/``.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.resilience.faults import (DEVICE_DROP, POWER_LOSS, SLOW_DISPATCH,
                                     STAGING_CORRUPTION, FaultPlan)
from .traces import DAY_S, HarvestTrace

# Mirror of the engine's non-decode hook charges (resilience/engine.py).
# Defined locally so the fluid simulator imports without jax; a unit test
# pins these against the engine's own constants.
STAGING_DT = 0.25
PREFILL_DT = 1.0

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Node configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeConfig:
    """One node's operating point + energy front-end.

    ``frame_energy_uj`` / ``frame_time_us`` price one inference of the
    node's compiled plan on its PIM target
    (``core/plan.plan_cost_on(plan, target)``); ``period`` is the paper's
    P (frames per NV commit, >= 1 — results are durable only at commits);
    ``resume_us`` is the reboot overhead after every outage (plan reload,
    cf. ``pim/intermittent.plan_resume_study``); ``cap_uj`` is the energy
    buffer and ``wake_frac`` the recharge fraction at which a dark node
    restarts.  The node draws constant active power
    ``frame_energy_uj / frame_time_us`` whenever ON (computing, committing,
    or resuming) and nothing while OFF.
    """

    node_id: str
    quant: str
    target: str
    period: int
    frame_energy_uj: float
    frame_time_us: float
    nv_write_us: float = 1.0
    resume_us: float = 0.0
    cap_uj: float = 200_000.0     # ~0.2 J: a small supercap
    wake_frac: float = 0.5

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1 (results are durable "
                             f"only at NV commits), got {self.period}")
        if self.frame_energy_uj <= 0 or self.frame_time_us <= 0:
            raise ValueError(f"frame_energy_uj and frame_time_us must be "
                             f"positive, got {self.frame_energy_uj}, "
                             f"{self.frame_time_us}")
        if self.nv_write_us < 0 or self.resume_us < 0:
            raise ValueError(f"nv_write_us and resume_us must be >= 0, got "
                             f"{self.nv_write_us}, {self.resume_us}")
        if self.cap_uj <= 0 or not 0 < self.wake_frac <= 1:
            raise ValueError(f"cap_uj must be positive and wake_frac in "
                             f"(0, 1], got {self.cap_uj}, {self.wake_frac}")

    # derived, all in SI-ish units: seconds, uJ, uJ/s
    @property
    def t_frame_s(self) -> float:
        return self.frame_time_us * 1e-6

    @property
    def t_commit_s(self) -> float:
        return self.nv_write_us * 1e-6

    @property
    def t_resume_s(self) -> float:
        return self.resume_us * 1e-6

    @property
    def block_s(self) -> float:
        """One commit block: P frames + the NV write."""
        return self.period * self.t_frame_s + self.t_commit_s

    @property
    def p_active_ujps(self) -> float:
        """Active draw in uJ/s (constant while ON)."""
        return self.frame_energy_uj / self.t_frame_s

    @property
    def wake_uj(self) -> float:
        return self.wake_frac * self.cap_uj


# ---------------------------------------------------------------------------
# Fluid arm: closed-form node simulation
# ---------------------------------------------------------------------------

class _NodeState:
    """Mutable walk state + accounting for one node."""

    __slots__ = ("cfg", "on", "b", "blk", "resume_left", "committed",
                 "wasted", "failures", "on_s", "off_s", "resume_s",
                 "harvested_uj", "outages", "collect")

    def __init__(self, cfg: NodeConfig, collect: int):
        self.cfg = cfg
        self.on = True                 # boot with a full buffer
        self.b = cfg.cap_uj
        self.blk = 0.0                 # seconds into the current commit block
        self.resume_left = cfg.t_resume_s   # cold boot pays one resume
        self.committed = 0.0           # durable frames
        self.wasted = 0.0              # frames lost to outages
        self.failures = 0
        self.on_s = 0.0
        self.off_s = 0.0
        self.resume_s = 0.0
        self.harvested_uj = 0.0
        self.outages: list[float] = []
        self.collect = collect

    def _in_flight(self) -> float:
        """Frames sitting volatile at block offset ``blk`` (frames complete
        during the first P*t_frame of a block; the commit tail adds none)."""
        return min(float(self.cfg.period), self.blk / self.cfg.t_frame_s)

    def _work_clock(self) -> float:
        """Total attempted frames so far (committed + wasted + in-flight) —
        the logical clock outage instants are recorded on, and the clock
        the engine replay's ``FaultPlan.timeline`` is polled against."""
        return self.committed + self.wasted + self._in_flight()

    def _advance_on(self, span_s: float) -> None:
        """``span_s`` of uninterrupted ON time: resume debt first, then
        productive blocks (commits at block boundaries, O(1) via divmod)."""
        self.on_s += span_s
        burn = min(self.resume_left, span_s)
        self.resume_left -= burn
        self.resume_s += burn
        productive = span_s - burn
        if productive <= 0:
            return
        self.blk += productive
        nblocks = int(self.blk / self.cfg.block_s)
        if nblocks:
            self.committed += nblocks * self.cfg.period
            self.blk -= nblocks * self.cfg.block_s

    def _outage(self) -> None:
        """Buffer hit empty while ON: lose the volatile in-flight frames."""
        lost = self._in_flight()
        self.blk = 0.0
        self.wasted += lost
        self.failures += 1
        if len(self.outages) < self.collect:
            self.outages.append(self._work_clock())
        self.on = False
        self.b = 0.0
        self.resume_left = 0.0   # an interrupted resume restarts from scratch

    def _wake(self) -> None:
        self.on = True
        self.b = self.cfg.wake_uj
        self.resume_left = self.cfg.t_resume_s
        self.blk = 0.0

    def _bulk_cycles(self, k: int, t_charge: float, t_run: float) -> None:
        """Apply ``k`` identical charge->resume->run->outage cycles in
        closed form (the node starts each one dark with an empty buffer)."""
        cfg = self.cfg
        burn = min(cfg.t_resume_s, t_run)
        productive = t_run - burn
        nblocks = int(productive / cfg.block_s)
        rem = productive - nblocks * cfg.block_s
        per_committed = nblocks * cfg.period
        per_lost = min(float(cfg.period), rem / cfg.t_frame_s)
        if self.collect and len(self.outages) < self.collect:
            base = self.committed + self.wasted
            for j in range(min(k, self.collect - len(self.outages))):
                self.outages.append(base + (j + 1) * (per_committed
                                                      + per_lost))
        self.off_s += k * t_charge
        self.on_s += k * t_run
        self.resume_s += k * burn
        self.committed += k * per_committed
        self.wasted += k * per_lost
        self.failures += k
        # cycle invariant: ends dark, empty, no block in flight
        self.on = False
        self.b = 0.0
        self.blk = 0.0
        self.resume_left = 0.0


def simulate_node(trace: HarvestTrace, cfg: NodeConfig,
                  collect_outages: int = 0) -> dict:
    """Walk one node through its trace; returns progress statistics.

    ``collect_outages > 0`` additionally records the work-clock instants
    (in frames) of the first that-many outages — the schedule handed to
    :func:`outage_faultplan` for the live-engine arm.
    """
    st = _NodeState(cfg, collect_outages)
    p_active = cfg.p_active_ujps
    dt = trace.dt_s
    for p_mw in trace.power_mw:
        h = float(p_mw) * 1e3          # mW -> uJ/s
        st.harvested_uj += h * dt
        remaining = dt
        while remaining > _EPS:
            if st.on:
                drain = p_active - h
                if drain <= 0:
                    st._advance_on(remaining)
                    st.b = min(cfg.cap_uj, st.b - drain * remaining)
                    remaining = 0.0
                    continue
                t_empty = st.b / drain
                if t_empty >= remaining:
                    st._advance_on(remaining)
                    st.b -= drain * remaining
                    remaining = 0.0
                else:
                    st._advance_on(t_empty)
                    remaining -= t_empty
                    st._outage()
                continue
            # OFF: recharge toward the wake threshold
            if h <= _EPS:
                st.off_s += remaining
                remaining = 0.0
                continue
            if st.b <= _EPS and h < p_active:
                # dark with an empty buffer at constant insufficient
                # harvest: the charge/run cycle repeats identically —
                # collapse every whole cycle left in this segment
                t_charge = cfg.wake_uj / h
                t_run = cfg.wake_uj / (p_active - h)
                k = int(remaining / (t_charge + t_run))
                if k >= 1:
                    st._bulk_cycles(k, t_charge, t_run)
                    remaining -= k * (t_charge + t_run)
                    continue
            t_charge = (cfg.wake_uj - st.b) / h
            if t_charge >= remaining:
                st.b += h * remaining
                st.off_s += remaining
                remaining = 0.0
            else:
                st.off_s += t_charge
                remaining -= t_charge
                st._wake()
    useful_s = st.committed * cfg.t_frame_s
    return dict(
        node_id=cfg.node_id,
        quant=cfg.quant, target=cfg.target, period=cfg.period,
        committed_frames=st.committed,
        wasted_frames=st.wasted,
        failures=st.failures,
        on_s=st.on_s, off_s=st.off_s, resume_s=st.resume_s,
        harvested_j=st.harvested_uj * 1e-6,
        consumed_j=st.on_s * p_active * 1e-6,
        # forward-progress efficiency: durable work over total powered time
        # (resume + commit + soon-to-be-wasted work all charge the node)
        efficiency=useful_s / st.on_s if st.on_s > 0 else 0.0,
        inferences_per_day=st.committed * (DAY_S / trace.duration_s),
        dead=st.committed < 1.0,
        outage_frames=st.outages,
    )


def simulate_fleet(traces, configs) -> list[dict]:
    """Simulate each (trace, config) pair; pure and order-stable."""
    if len(traces) != len(configs):
        raise ValueError(f"got {len(traces)} traces but {len(configs)} "
                         f"node configs")
    return [simulate_node(tr, cfg) for tr, cfg in zip(traces, configs)]


def fleet_report(results, specs=None) -> dict:
    """Aggregate per-node stats into the fleet-level report (the
    ``bench_fleet.json`` currency): total inferences/day, mean
    forward-progress efficiency, dead-node count, per-archetype
    breakdown when the trace specs are supplied."""
    n = len(results)
    total_ipd = float(sum(r["inferences_per_day"] for r in results))
    dead = sum(1 for r in results if r["dead"])
    agg = dict(
        nodes=n,
        inferences_per_day=total_ipd,
        mean_efficiency=float(np.mean([r["efficiency"] for r in results]))
        if n else 0.0,
        dead_nodes=dead,
        failures=int(sum(r["failures"] for r in results)),
        harvested_j=float(sum(r["harvested_j"] for r in results)),
        consumed_j=float(sum(r["consumed_j"] for r in results)),
    )
    if specs is not None:
        by_arch: dict[str, list] = {}
        for spec, r in zip(specs, results):
            by_arch.setdefault(spec.archetype, []).append(r)
        agg["archetypes"] = {
            k: dict(nodes=len(rs),
                    inferences_per_day=float(
                        sum(r["inferences_per_day"] for r in rs)),
                    mean_efficiency=float(
                        np.mean([r["efficiency"] for r in rs])),
                    dead_nodes=sum(1 for r in rs if r["dead"]))
            for k, rs in sorted(by_arch.items())}
    return agg


# ---------------------------------------------------------------------------
# Discrete arm: engine-accounting replay + live validation
# ---------------------------------------------------------------------------

def outage_faultplan(outage_frames) -> FaultPlan:
    """A node's derived outage schedule as a live fault plan: power_loss
    at fixed work-clock instants (frames ~ logical decode steps).  The
    same JSON spec drives :func:`predict_engine_stats` and a real
    :class:`~repro.resilience.engine.ResilientServeEngine` — one failure
    model for simulated and live arms."""
    return FaultPlan.timeline([(t, POWER_LOSS) for t in outage_frames])


def rescale_outages(outage_frames, node_work_frames: float,
                    engine_work: float) -> list[float]:
    """Compress a node's outage schedule (work clock in frames, spanning a
    whole trace) onto a small engine replay's work-clock range, preserving
    the relative outage structure.  Both validation arms consume the SAME
    compressed timeline, so the compression factor never enters the
    simulator-vs-engine comparison — it only makes a day of node work
    replayable in seconds."""
    if node_work_frames <= 0:
        return []
    k = engine_work / node_work_frames
    return [t * k for t in outage_frames]


def epoch_schedule(new_tokens: int, epoch_steps: int) -> tuple:
    """Mirror of ``EpochLMRunner.epoch_schedule``."""
    n, k = new_tokens - 1, epoch_steps
    return tuple([k] * (n // k) + ([n % k] if n % k else []))


def predict_engine_stats(fault_spec, *, n_requests: int, new_tokens: int,
                         epoch_steps: int, max_batch: int) -> dict:
    """The simulator's accounting of what ``ResilientServeEngine`` will do
    under ``fault_spec`` (a ``FaultPlan`` JSON spec or instance).

    A step-exact mirror of the engine's hook sequence with checkpointing
    on: per attempt — staging poll (dt 0.25); prefill poll (dt 1.0) only
    when no checkpoint exists yet, commit after prefill; one decode poll
    per epoch (dt = steps), commit after each; a kill-class event requeues
    the bucket FIFO keeping its committed epoch.  Polls the same
    ``FaultPlan`` implementation the engine does, so fault times and
    offsets agree bit-for-bit.  Assumes no dead-letters (the validation
    arm runs the engine with a huge ``max_retries``) and no degrade
    (energy scale 1)."""
    faults = (fault_spec if isinstance(fault_spec, FaultPlan)
              else FaultPlan.from_json(fault_spec))
    schedule = epoch_schedule(new_tokens, epoch_steps)
    sizes = [max_batch] * (n_requests // max_batch)
    if n_requests % max_batch:
        sizes.append(n_requests % max_batch)
    # bucket state: [n_requests, committed_epoch or None (no checkpoint)]
    queue = deque([size, None] for size in sizes)
    s = dict(faults=0, power_losses=0, device_drops=0, slow_dispatches=0,
             staging_retries=0, retries=0, prefills=0, resumes=0, epochs=0,
             commits=0, executed_steps=0, useful_steps=0, wasted_steps=0.0,
             dispatches=0, requests=0)

    def _kill(ev, bucket, charge_offset: bool) -> bool:
        if ev is None:
            return False
        if ev.kind == SLOW_DISPATCH:
            s["slow_dispatches"] += 1
            return False
        if ev.kind == STAGING_CORRUPTION:
            s["staging_retries"] += 1
            return False
        s["faults"] += 1
        s["power_losses" if ev.kind == POWER_LOSS else "device_drops"] += 1
        if charge_offset:
            # only _fault_gate (prefill/decode) charges the partial window;
            # a staging kill raises from _stage_checked without it
            s["wasted_steps"] += ev.offset
        s["retries"] += bucket[0]
        queue.append(bucket)
        return True

    while queue:
        bucket = queue.popleft()
        if _kill(faults.poll("staging", dt=STAGING_DT), bucket,
                 charge_offset=False):
            continue
        if bucket[1] is None:
            if _kill(faults.poll("prefill", dt=PREFILL_DT), bucket,
                     charge_offset=True):
                continue
            s["prefills"] += 1
            s["commits"] += 1          # the epoch-0 (post-prefill) commit
            bucket[1] = 0
        else:
            s["resumes"] += 1
        killed = False
        for e in range(bucket[1], len(schedule)):
            steps = schedule[e]
            if _kill(faults.poll("decode", dt=float(steps)), bucket,
                     charge_offset=True):
                killed = True
                break
            s["executed_steps"] += steps
            s["epochs"] += 1
            s["commits"] += 1
            bucket[1] = e + 1
        if killed:
            continue
        s["useful_steps"] += sum(schedule)
        s["dispatches"] += 1
        s["requests"] += bucket[0]
    return s


def measured_efficiency(stats, nv_write_steps: float = 0.0) -> float:
    """Useful steps over total charged work — the same formula
    ``benchmarks/bench_resilience`` applies to live engine stats, usable
    on :func:`predict_engine_stats` output interchangeably."""
    restarts = max(0.0, stats["prefills"] + stats["resumes"]
                   - stats["dispatches"])
    total = (stats["executed_steps"] + stats["wasted_steps"] + restarts
             + nv_write_steps * stats["commits"])
    return stats["useful_steps"] / total if total else 0.0


# keys whose exact/tolerance match constitutes the validation contract
_VALIDATE_INT_KEYS = ("faults", "power_losses", "prefills", "resumes",
                      "epochs", "commits", "executed_steps", "useful_steps",
                      "dispatches", "requests", "retries")
_VALIDATE_FLOAT_KEYS = ("wasted_steps",)


def live_validation(outage_frames, *, checkpoint_dir, n_requests: int = 8,
                    new_tokens: int = 7, epoch_steps: int = 2,
                    max_batch: int = 4, prompt_len: int = 8,
                    tol: float = 1e-6) -> dict:
    """Replay one node's outage schedule through a REAL
    ``ResilientServeEngine`` (tiny smoke LM) and compare its measured
    stats against :func:`predict_engine_stats` on the same fault spec.

    Validation contract (the "stated tolerance" of the acceptance
    criteria): every integer work counter in ``_VALIDATE_INT_KEYS``
    matches EXACTLY; float accounting (``wasted_steps`` and the derived
    ``measured_efficiency``) matches within ``tol`` (absolute).  Both
    arms poll the same ``FaultPlan.timeline`` JSON spec — one failure
    model, two executors.
    """
    import jax                                    # noqa: F401 (lazy; the
    from repro.configs import SINGLE, all_configs  # fluid arm needs no jax)
    from repro.core.quant import PAPER_CONFIGS
    from repro.models import transformer as T
    from repro.resilience import EpochLMRunner, ResilientServeEngine

    spec = outage_faultplan(outage_frames).to_json()
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    prompts = [np.random.RandomState(i).randint(0, 64, size=(prompt_len,))
               .astype(np.int32) for i in range(n_requests)]
    runner = EpochLMRunner(params, cfg, new_tokens=new_tokens,
                           epoch_steps=epoch_steps)
    eng = ResilientServeEngine(runner, fault_plan=FaultPlan.from_json(spec),
                               checkpoint_dir=checkpoint_dir,
                               max_batch=max_batch, max_retries=10**9)
    results = eng.serve(prompts)
    predicted = predict_engine_stats(spec, n_requests=n_requests,
                                     new_tokens=new_tokens,
                                     epoch_steps=epoch_steps,
                                     max_batch=max_batch)
    measured = {k: eng.stats[k] for k in (*_VALIDATE_INT_KEYS,
                                          *_VALIDATE_FLOAT_KEYS)}
    deltas = {}
    ok = len(results) == n_requests and not eng.dead_letters
    for k in _VALIDATE_INT_KEYS:
        deltas[k] = int(measured[k]) - int(predicted[k])
        ok = ok and deltas[k] == 0
    for k in _VALIDATE_FLOAT_KEYS:
        deltas[k] = float(measured[k]) - float(predicted[k])
        ok = ok and abs(deltas[k]) <= tol
    eff_pred = measured_efficiency(predicted)
    eff_meas = measured_efficiency(measured)
    deltas["measured_efficiency"] = eff_meas - eff_pred
    ok = ok and abs(deltas["measured_efficiency"]) <= tol
    return dict(ok=bool(ok), tol=tol, fault_spec=spec, predicted=predicted,
                measured=measured, deltas=deltas,
                efficiency_predicted=eff_pred, efficiency_measured=eff_meas,
                completed=len(results), dead_letters=len(eng.dead_letters))


# DEVICE_DROP is imported for _kill's kind split but never drawn by
# timeline plans; referenced here so the shared-model contract is explicit
_KILL_KINDS = (POWER_LOSS, DEVICE_DROP)
