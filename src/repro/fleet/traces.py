"""Seeded energy-harvest trace generators (ROADMAP item 4, DESIGN.md §14).

A fleet of battery-less nodes is heterogeneous in exactly one input: the
power its harvester offers over the day.  This module turns a compact
:class:`TraceSpec` (archetype + seed + a few physical knobs) into a
:class:`HarvestTrace` — a piecewise-constant power-availability timeline in
mW — deterministically: the trace is a pure function of the spec, so a
fleet study replays bit-for-bit from the JSON'd specs alone and the
serialized form stays kilobytes even for thousands of day-long traces.

Three harvester archetypes (the usual energy-harvesting IoT trio):

``solar``    diurnal half-sine between sunrise and sunset, modulated by a
             smoothed cloud-attenuation process; zero at night.
``rf``       a low ambient floor plus Poisson bursts (a nearby transmitter
             duty-cycling): exponential inter-burst gaps, jittered burst
             length and amplitude.
``thermal``  steady harvest from a temperature gradient with slow AR(1)
             drift, interrupted by exponential dropouts (the gradient
             collapses — machinery off, sun leaves the hot plate).

Traces serialize spec-first: ``HarvestTrace.to_json()`` stores the spec and
(optionally) the samples; ``from_json`` regenerates from the spec when the
samples were not embedded and verifies length when they were.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ARCHETYPES = ("solar", "rf", "thermal")

DAY_S = 86400.0


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate one node's harvest timeline."""

    node_id: str
    archetype: str
    seed: int
    dt_s: float = 60.0            # sample period (piecewise-constant power)
    duration_s: float = DAY_S
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.archetype not in ARCHETYPES:
            raise ValueError(f"unknown archetype {self.archetype!r}; "
                             f"valid: {ARCHETYPES}")
        if self.dt_s <= 0 or self.duration_s <= 0:
            raise ValueError(f"dt_s and duration_s must be positive, got "
                             f"dt_s={self.dt_s} duration_s={self.duration_s}")
        if self.duration_s < self.dt_s:
            raise ValueError(f"duration_s ({self.duration_s}) must cover at "
                             f"least one sample (dt_s={self.dt_s})")

    @property
    def n_samples(self) -> int:
        return int(round(self.duration_s / self.dt_s))

    def to_json(self) -> dict:
        return dict(node_id=self.node_id, archetype=self.archetype,
                    seed=self.seed, dt_s=self.dt_s,
                    duration_s=self.duration_s, params=dict(self.params))

    @classmethod
    def from_json(cls, d: dict) -> "TraceSpec":
        return cls(node_id=d["node_id"], archetype=d["archetype"],
                   seed=int(d["seed"]), dt_s=float(d["dt_s"]),
                   duration_s=float(d["duration_s"]),
                   params=dict(d.get("params") or {}))


@dataclasses.dataclass(frozen=True)
class HarvestTrace:
    """A spec plus its realized power timeline (mW per ``dt_s`` sample)."""

    spec: TraceSpec
    power_mw: np.ndarray

    @property
    def dt_s(self) -> float:
        return self.spec.dt_s

    @property
    def duration_s(self) -> float:
        return self.spec.duration_s

    def harvested_j(self) -> float:
        """Total energy the harvester offers over the trace, in joules."""
        return float(self.power_mw.sum()) * self.dt_s * 1e-3

    def to_json(self, embed_power: bool = False) -> dict:
        d = dict(version=1, spec=self.spec.to_json())
        if embed_power:
            d["power_mw"] = [float(p) for p in self.power_mw]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "HarvestTrace":
        spec = TraceSpec.from_json(d["spec"])
        if "power_mw" in d:
            power = np.asarray(d["power_mw"], float)
            if power.shape != (spec.n_samples,):
                raise ValueError(
                    f"embedded power length {power.shape} does not match "
                    f"spec ({spec.n_samples} samples)")
            return cls(spec, power)
        return make_trace(spec)


# ---------------------------------------------------------------------------
# Generators — each a pure function of (spec.seed, spec.params)
# ---------------------------------------------------------------------------

def _ar1(rng: np.random.RandomState, n: int, tau_samples: float) -> np.ndarray:
    """Smoothed noise in [0, 1]: an AR(1) walk with correlation time
    ``tau_samples``, squashed through a logistic.  Gives clouds/drift their
    slow structure without any FFT machinery."""
    rho = float(np.exp(-1.0 / max(tau_samples, 1e-9)))
    innov = rng.normal(size=n) * np.sqrt(max(1.0 - rho * rho, 1e-12))
    x = np.empty(n)
    acc = rng.normal()
    for i in range(n):
        acc = rho * acc + innov[i]
        x[i] = acc
    return 1.0 / (1.0 + np.exp(-1.5 * x))


def _solar(spec: TraceSpec) -> np.ndarray:
    p = spec.params
    peak_mw = float(p.get("peak_mw", 120.0))
    sunrise_s = float(p.get("sunrise_s", 6 * 3600.0))
    sunset_s = float(p.get("sunset_s", 18 * 3600.0))
    cloud_depth = float(p.get("cloud_depth", 0.6))     # worst-case attenuation
    cloud_tau_s = float(p.get("cloud_tau_s", 1800.0))  # cloud correlation time
    if sunset_s <= sunrise_s:
        raise ValueError(f"sunset_s ({sunset_s}) must be after "
                         f"sunrise_s ({sunrise_s})")
    rng = np.random.RandomState(spec.seed)
    n = spec.n_samples
    t = (np.arange(n) + 0.5) * spec.dt_s
    tod = t % DAY_S                      # multi-day traces repeat the diurnal
    phase = (tod - sunrise_s) / (sunset_s - sunrise_s)
    day = np.where((phase > 0) & (phase < 1), np.sin(np.pi * phase), 0.0)
    clouds = 1.0 - cloud_depth * _ar1(rng, n, cloud_tau_s / spec.dt_s)
    return peak_mw * day * clouds


def _rf(spec: TraceSpec) -> np.ndarray:
    p = spec.params
    floor_mw = float(p.get("floor_mw", 1.0))
    burst_mw = float(p.get("burst_mw", 150.0))
    gap_s = float(p.get("mean_gap_s", 600.0))       # mean gap between bursts
    burst_s = float(p.get("mean_burst_s", 90.0))    # mean burst length
    rng = np.random.RandomState(spec.seed)
    n = spec.n_samples
    power = np.full(n, floor_mw)
    t = rng.exponential(gap_s)
    while t < spec.duration_s:
        width = rng.exponential(burst_s)
        amp = burst_mw * rng.uniform(0.5, 1.5)
        i0 = int(t / spec.dt_s)
        i1 = max(i0 + 1, int(np.ceil((t + width) / spec.dt_s)))
        power[i0:min(i1, n)] += amp
        t += width + rng.exponential(gap_s)
    return power


def _thermal(spec: TraceSpec) -> np.ndarray:
    p = spec.params
    level_mw = float(p.get("level_mw", 40.0))
    drift = float(p.get("drift", 0.3))              # relative AR(1) wander
    drift_tau_s = float(p.get("drift_tau_s", 7200.0))
    gap_s = float(p.get("mean_gap_s", 4 * 3600.0))  # mean time between drops
    drop_s = float(p.get("mean_drop_s", 1200.0))    # mean dropout length
    rng = np.random.RandomState(spec.seed)
    n = spec.n_samples
    wander = 1.0 - drift + 2 * drift * _ar1(rng, n, drift_tau_s / spec.dt_s)
    power = level_mw * wander
    t = rng.exponential(gap_s)
    while t < spec.duration_s:
        width = rng.exponential(drop_s)
        i0 = int(t / spec.dt_s)
        i1 = max(i0 + 1, int(np.ceil((t + width) / spec.dt_s)))
        power[i0:min(i1, n)] = 0.0
        t += width + rng.exponential(gap_s)
    return power


_GENERATORS = {"solar": _solar, "rf": _rf, "thermal": _thermal}


def make_trace(spec: TraceSpec) -> HarvestTrace:
    """Realize a spec.  Pure: same spec -> bit-identical timeline."""
    power = _GENERATORS[spec.archetype](spec)
    return HarvestTrace(spec, np.maximum(power, 0.0))


# ---------------------------------------------------------------------------
# Fleet generation
# ---------------------------------------------------------------------------

DEFAULT_MIX = (("solar", 0.5), ("rf", 0.3), ("thermal", 0.2))


def generate_fleet(n_nodes: int, seed: int = 0,
                   mix=DEFAULT_MIX, dt_s: float = 60.0,
                   duration_s: float = DAY_S) -> list[TraceSpec]:
    """Draw ``n_nodes`` heterogeneous trace specs from one master seed.

    Per-node heterogeneity: the archetype (drawn from ``mix``), the
    archetype's physical knobs (panel size, transmitter distance, gradient
    strength, ...) and the child seed all come from one ``RandomState``,
    so the whole fleet is a pure function of ``(n_nodes, seed, mix)`` and
    specs stay stable under fleet-size growth (node i's spec never depends
    on n_nodes).
    """
    if n_nodes < 1:
        raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
    kinds = [k for k, _ in mix]
    probs = np.asarray([w for _, w in mix], float)
    if (probs < 0).any() or probs.sum() <= 0:
        raise ValueError(f"mix weights must be non-negative and sum > 0, "
                         f"got {mix}")
    probs = probs / probs.sum()
    master = np.random.RandomState(seed)
    specs = []
    for i in range(n_nodes):
        kind = kinds[int(master.choice(len(kinds), p=probs))]
        child_seed = int(master.randint(0, 2**31 - 1))
        if kind == "solar":
            params = dict(
                peak_mw=float(master.uniform(40.0, 240.0)),
                sunrise_s=float(master.uniform(5.0, 7.0) * 3600),
                sunset_s=float(master.uniform(17.0, 19.0) * 3600),
                cloud_depth=float(master.uniform(0.2, 0.8)))
        elif kind == "rf":
            params = dict(
                floor_mw=float(master.uniform(0.2, 3.0)),
                burst_mw=float(master.uniform(60.0, 300.0)),
                mean_gap_s=float(master.uniform(180.0, 1200.0)),
                mean_burst_s=float(master.uniform(30.0, 240.0)))
        else:
            params = dict(
                level_mw=float(master.uniform(10.0, 80.0)),
                drift=float(master.uniform(0.1, 0.5)),
                mean_gap_s=float(master.uniform(2.0, 8.0) * 3600),
                mean_drop_s=float(master.uniform(300.0, 2400.0)))
        specs.append(TraceSpec(node_id=f"node{i:05d}", archetype=kind,
                               seed=child_seed, dt_s=dt_s,
                               duration_s=duration_s, params=params))
    return specs
