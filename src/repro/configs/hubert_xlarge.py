"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447].

Modality frontend is a stub: input_specs() provides precomputed frame
embeddings (B, T, frame_dim). Encoder-only => decode shapes skipped.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, causal=False, frame_input=True, frame_dim=512,
    pattern=("attn",), act="gelu", rope_theta=10_000.0,
    skip_shapes=("decode_32k", "long_500k"),
)
