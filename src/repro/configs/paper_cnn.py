"""The paper's own models: SVHN bitwise CNN + binary AlexNet.

Not an LM ArchConfig — exposed for the CNN benchmarks/examples; the
channel width default is chosen so the SVHN model costs ~80 MFLOPs per
40x40 image, matching the paper's \u00a7III-A claim.
"""
from repro.core.quant import PAPER_CONFIGS, W1A4
from repro.models.cnn import alexnet_spec, svhn_cnn_spec

SVHN_CHANNELS = 20           # ~80 MFLOPs / 40x40 image (see bench)
SVHN_SPEC = svhn_cnn_spec(SVHN_CHANNELS)
ALEXNET_SPEC = alexnet_spec()
DEFAULT_QUANT = W1A4
QUANTS = PAPER_CONFIGS
