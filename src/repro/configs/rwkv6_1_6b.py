"""rwkv6-1.6b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892]. O(1) decode state => long_500k runs.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=0,
    d_ff=7168, vocab=65536, rwkv_head_dim=64,
    pattern=("rwkv",),
    skip_shapes=(),
)
