"""yi-34b — llama-arch GQA dense [arXiv:2403.04652].

56 query heads are padded to 64 on TP=16 meshes (zero-masked, math-exact);
kv=8 heads replicate across the model axis (DESIGN.md \u00a75).
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128, rope_theta=5_000_000.0,
    pattern=("attn",), act="swiglu",
    skip_shapes=("long_500k",),
)
