"""internvl2-26b — InternViT + InternLM2 VLM [arXiv:2404.16821].

Vision frontend is a stub: input_specs() provides precomputed patch
embeddings (B, n_patches, vit_dim) projected into the LM sequence.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, head_dim=128,
    n_patches=256, vit_dim=1024,
    pattern=("attn",), act="swiglu",
    skip_shapes=("long_500k",),
)
