"""recurrentgemma-9b — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427]. Bounded window + O(1) LRU state => long_500k runs.
38 layers = 12 x (rec, rec, attn_local) + (rec, rec) remainder.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256, window=2048,
    lru_width=4096, conv_width=4,
    pattern=("rec", "rec", "attn_local"), act="gelu",
    skip_shapes=(),
)
