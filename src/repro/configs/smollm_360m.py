"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM].

15 query heads pad to 16 on TP=16; kv=5 replicates.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64, tie_embeddings=True,
    pattern=("attn",), act="swiglu",
    skip_shapes=("long_500k",),
)
