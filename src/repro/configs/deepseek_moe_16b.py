"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066]. 64 % 16 == 0 => experts shard on the model axis (EP).
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, expert_d_ff=1408,
    pattern=("moe",), act="swiglu",
    skip_shapes=("long_500k",),
)
