"""qwen3-32b — qk_norm + GQA dense [hf:Qwen/Qwen3-*]."""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1_000_000.0, pattern=("attn",), act="swiglu",
    skip_shapes=("long_500k",),
)
