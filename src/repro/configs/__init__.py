"""Config registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "phi3-mini-3.8b", "yi-34b", "smollm-360m", "qwen3-32b", "hubert-xlarge",
    "deepseek-moe-16b", "granite-moe-3b-a800m", "rwkv6-1.6b",
    "recurrentgemma-9b", "internvl2-26b",
]


def _mod_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_mod_name(arch_id)}")
    return mod.ARCH


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


from .base import SHAPES, ArchConfig, ShapeCell, ShardPlan, SINGLE, make_plan  # noqa: E402,F401
