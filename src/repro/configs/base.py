"""Architecture & sharding configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; reduced smoke
variants derive from the full config via :meth:`ArchConfig.smoke`.  The
paper's technique plugs in through ``quant`` (a
:class:`repro.core.quant.QuantConfig`), applied to projection GEMMs by the
model layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.core.quant import FP32, QuantConfig

VOCAB_PAD = 256  # pad vocab to a multiple of this (divisible by TP=16)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | rwkv | rglru | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    window: Optional[int] = None            # local-attention window (rglru)
    pattern: Tuple[str, ...] = ("attn",)    # block pattern, tiled over n_layers
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # activations / norms
    act: str = "swiglu"                     # swiglu | gelu
    tie_embeddings: bool = False
    # modality stubs
    n_patches: int = 0                      # vlm: vision tokens prepended
    vit_dim: int = 0                        # vlm: stub patch-embedding dim
    frame_input: bool = False               # audio: frame embeddings replace tokens
    frame_dim: int = 0                      # audio: stub frame-feature dim
    # recurrent families
    lru_width: Optional[int] = None
    conv_width: int = 4
    rwkv_head_dim: int = 64
    lora_rank: int = 32
    # paper technique
    quant: QuantConfig = FP32
    # dtypes
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # training
    remat: bool = True
    # analysis/runtime toggles (launch/dryrun.py sets these for roofline
    # accounting: XLA CPU cost_analysis counts loop bodies ONCE, so the
    # dry-run unrolls the layer loop and uses closed-form attention /
    # associative recurrences — see EXPERIMENTS.md §Roofline "method")
    scan_layers: bool = True
    full_attn_analysis: bool = False
    rglru_assoc: bool = False
    remat_prevent_cse: bool = False   # hillclimb: stop XLA CSE undoing remat
    bf16_logits: bool = False         # hillclimb: bf16 attention logits
    ce_where_mask: bool = False       # hillclimb: bool-mask CE (no f32 one-hot)
    act_scale: float = 0.0            # >0: static (calibrated) activation
                                      # scale for the prequant serve path
    banded_attn: bool = False         # hillclimb: banded local attention
                                      # (compute only the window band, not S^2)
    constrain_acts: bool = False      # hillclimb: pin activations batch-sharded
                                      # (forces FSDP weight all-gather instead
                                      # of XLA replicating activations)
    # which shape cells apply (documented skips in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ("long_500k",)

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def blocks_pattern(self) -> Tuple[str, ...]:
        """Full per-layer block-type sequence of length n_layers."""
        reps = -(-self.n_layers // len(self.pattern))
        return tuple((list(self.pattern) * reps)[: self.n_layers])

    def n_blocks_of(self, kind: str) -> int:
        return sum(1 for b in self.blocks_pattern if b == kind)

    def shapes(self):
        for name, cell in SHAPES.items():
            if name in self.skip_shapes:
                continue
            yield cell

    def smoke(self, **overrides) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=max(2, 2 * len(self.pattern)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            head_dim=32,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            expert_d_ff=64 if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            lru_width=128 if self.lru_width else None,
            n_patches=16 if self.n_patches else 0,
            vit_dim=64 if self.vit_dim else 0,
            frame_dim=64 if self.frame_dim else 0,
            lora_rank=8,
            window=min(self.window, 64) if self.window else None,
            compute_dtype=jnp.float32,
            remat=False,
        )
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Logical-axis -> mesh-axis mapping plus padding-relevant sizes.

    tp   = size of the "model" axis (TP/EP degree)
    fsdp = size of the "data" axis (FSDP/ZeRO param sharding degree)
    dp   = total batch-sharding degree (pod*data)
    """

    tp: int = 1
    fsdp: int = 1
    dp: int = 1
    batch_axes: Tuple[str, ...] = ()        # mesh axes for the batch dim
    rules: Tuple[Tuple[str, Optional[str]], ...] = ()

    def axis_for(self, logical: str):
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def padded_heads(self, n_heads: int) -> int:
        """Q heads padded to a TP multiple (zero-masked; math-exact)."""
        return -(-n_heads // self.tp) * self.tp

    def shard_kv(self, n_kv: int) -> bool:
        return self.tp > 1 and n_kv % self.tp == 0

    def shard_experts(self, n_experts: int) -> bool:
        return self.tp > 1 and n_experts > 0 and n_experts % self.tp == 0


SINGLE = ShardPlan(
    tp=1, fsdp=1, dp=1, batch_axes=(),
    rules=(("vocab", None), ("heads", None), ("kv_heads", None), ("mlp", None),
           ("expert", None), ("embed", None), ("layers", None)),
)


def make_plan(mesh_shape: dict[str, int], *, inference: bool = False) -> ShardPlan:
    """Build the production sharding plan from a mesh {axis: size} dict.

    inference=True drops the FSDP rule: with no optimizer state there is no
    per-chip memory pressure, and FSDP's per-layer parameter all-gathers
    would dominate the serve-path collective term (§Perf hillclimb #2/#3).
    """
    tp = mesh_shape.get("model", 1)
    fsdp = mesh_shape.get("data", 1)
    pod = mesh_shape.get("pod", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    return ShardPlan(
        tp=tp,
        fsdp=fsdp,
        dp=pod * fsdp,
        batch_axes=batch_axes,
        rules=(
            ("vocab", "model"),
            ("heads", "model"),
            ("kv_heads", "model"),      # applied only if divisible (shard_kv)
            ("mlp", "model"),
            ("expert", "model"),        # applied only if divisible (shard_experts)
            ("embed", None if inference else "data"),  # FSDP/ZeRO param axis
            ("layers", None),
        ),
    )
