"""granite-moe-3b-a800m — 40 experts top-8
[hf:ibm-granite/granite-3.0-*]. 40 % 16 != 0 => experts replicate; each
expert d_ff=512 TP-shards (512/16=32) instead (DESIGN.md \u00a75).
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64, tie_embeddings=True,
    n_experts=40, top_k=8, n_shared_experts=0, expert_d_ff=512,
    pattern=("moe",), act="swiglu",
    skip_shapes=("long_500k",),
)
