"""Quantizer unit + property tests (paper Table I closed forms, DoReFa)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.quant import (
    PAPER_CONFIGS, QuantConfig, activation_levels, activation_levels_signed,
    fake_quant_act_signed, quantize_activation, quantize_gradient,
    quantize_weight, weight_levels,
)


def test_table1_complexity_columns():
    """Paper Table I, computation-complexity columns, exactly."""
    expect = {  # (W,I): (inference, training) with 8-bit gradients
        (1, 1): (1, 9), (1, 4): (4, 12), (1, 8): (8, 16), (2, 2): (4, 20),
    }
    for (w, i), (inf, tr) in expect.items():
        cfg = QuantConfig(w_bits=w, a_bits=i, g_bits=8)
        assert cfg.inference_complexity == inf
        assert cfg.training_complexity == tr


def test_paper_configs_registry():
    assert set(PAPER_CONFIGS) == {"w32a32", "w1a1", "w1a4", "w1a8", "w2a2"}


@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_activation_levels_bounds(bits, seed):
    a = jax.random.uniform(jax.random.PRNGKey(seed), (17,), minval=-2, maxval=3)
    lv, s = activation_levels(a, bits)
    assert int(jnp.min(lv)) >= 0 and int(jnp.max(lv)) <= (1 << bits) - 1
    # dequantized value approximates clip(a, 0, 1) within half a level
    np.testing.assert_allclose(np.asarray(lv) * float(s),
                               np.clip(np.asarray(a), 0, 1),
                               atol=0.5 / ((1 << bits) - 1) + 1e-6)


@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_weight_levels_roundtrip(bits, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (33,))
    lv, s, z = weight_levels(w, bits)
    wq_int = (np.asarray(lv, np.float64) - float(z)) * float(s)
    wq_float = np.asarray(quantize_weight(w, bits))
    np.testing.assert_allclose(wq_int, wq_float, atol=1e-6)


def test_binary_weight_is_scaled_sign():
    w = jnp.asarray([0.5, -0.2, 0.1, -0.9])
    wq = np.asarray(quantize_weight(w, 1))
    alpha = float(jnp.mean(jnp.abs(w)))
    np.testing.assert_allclose(np.abs(wq), alpha, rtol=1e-6)
    assert (np.sign(wq) == np.sign(np.asarray(w))).all()


@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_signed_levels_affine(bits, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (25,)) * 4
    lv, s, z = activation_levels_signed(a, bits)
    assert int(jnp.min(lv)) >= 0 and int(jnp.max(lv)) <= (1 << bits) - 1
    deq = (np.asarray(lv, np.float64) - float(z)) * float(s)
    fq = np.asarray(fake_quant_act_signed(a, bits), np.float64)
    np.testing.assert_allclose(deq, fq, atol=1e-5)


def test_ste_gradients_pass_through():
    f = lambda x: jnp.sum(quantize_activation(x, 2))
    g = jax.grad(f)(jnp.asarray([0.3, 0.7, -0.5, 1.5]))
    # STE: identity grad inside [0,1], zero outside (clip region)
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_gradient_quantization_levels():
    key = jax.random.PRNGKey(0)

    def f(x):
        return jnp.sum(jnp.square(quantize_gradient(x, 4, key)))

    x = jax.random.normal(key, (64,))
    g = jax.grad(f)(x)
    # quantized gradient has at most 2^4 distinct levels (up to fp noise)
    lv = np.unique(np.round(np.asarray(g), 6))
    assert len(lv) <= 16 + 1
    assert np.isfinite(np.asarray(g)).all()
