"""Executable intermittency resilience (repro.resilience, DESIGN.md §11).

Headline contract (ISSUE acceptance): under a seeded FaultPlan, every
completed request's output is BIT-IDENTICAL to the fault-free run — across
kill points in prefill, mid-decode-epoch, staging, and single-shot CNN
dispatch — and recovery is idempotent (same rid, one result, no
duplicates).  Plus: deterministic fault schedules, crash-consistent resume
from the last committed epoch, bounded retries -> dead letters, deadlines,
and degraded-plan fallback.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import SINGLE, all_configs
from repro.core.quant import PAPER_CONFIGS, W1A4
from repro.core.prequant import prequantize_cnn_params
from repro.launch.engine import CNNRunner, ServeEngine
from repro.models import transformer as T
from repro.models.cnn import init_cnn, svhn_cnn_spec
from repro.resilience import (DegradePolicy, DeviceDrop, EpochLMRunner,
                              FaultPlan, PowerLoss, ResilientServeEngine)

VOCAB = 64
NEW_TOKENS = 7          # 6 decode steps; epoch_steps=2 -> schedule (2, 2, 2)


# ---------------------------------------------------------------------------
# FaultPlan: determinism, validation, site/kind discipline
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_logged():
    def events(seed):
        p = FaultPlan(3.0, seed=seed)
        for _ in range(40):
            p.poll("decode", dt=2.0)
        return [(e.kind, e.site, e.t, e.offset, e.seq) for e in p.log]

    a, b = events(5), events(5)
    assert a and a == b                      # same seed -> same schedule
    assert events(6) != a                    # different seed -> different
    # at most one event per poll, clock stops at the fault
    p = FaultPlan(0.5, seed=0)
    ev = p.poll("decode", dt=4.0)
    assert ev is not None and ev.offset <= 4.0 and p._t == ev.t


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(0.0)
    with pytest.raises(ValueError):
        FaultPlan(-1.0)
    with pytest.raises(ValueError):
        FaultPlan(1.0, weights={"meteor_strike": 1.0})
    with pytest.raises(ValueError):
        FaultPlan.scripted([("nowhere", 0, "power_loss")])
    with pytest.raises(ValueError):
        # device_drop is not physically meaningful during staging
        FaultPlan.scripted([("staging", 0, "device_drop")])
    assert FaultPlan(None).poll("decode") is None   # never fires


def test_fault_plan_scripted_fires_nth_poll_per_site():
    p = FaultPlan.scripted([("decode", 1, "power_loss"),
                            ("staging", 0, "staging_corruption")])
    assert p.poll("staging", dt=0.5).kind == "staging_corruption"
    assert p.poll("decode") is None
    assert p.poll("decode").kind == "power_loss"
    assert p.poll("decode") is None
    assert [e.kind for e in p.log] == ["staging_corruption", "power_loss"]


def test_fault_plan_site_restricted_kinds():
    p = FaultPlan(0.1, seed=1)       # fires on nearly every poll
    for _ in range(50):
        p.poll("staging", dt=1.0)
    assert p.log
    assert all(e.kind in ("power_loss", "staging_corruption")
               for e in p.log)


# ---------------------------------------------------------------------------
# LM chaos: kill points at every site, resume, bit-identity
# ---------------------------------------------------------------------------

def _lm_setup():
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=VOCAB, head_dim=32),
        quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    return cfg, params


@pytest.fixture(scope="module")
def lm():
    cfg, params = _lm_setup()
    prompts = [np.random.RandomState(i).randint(0, VOCAB, size=(8,))
               .astype(np.int32) for i in range(4)]

    def mk(fault_plan=None, ckdir=None, **kw):
        runner = EpochLMRunner(params, cfg, new_tokens=NEW_TOKENS,
                               epoch_steps=2)
        return ResilientServeEngine(runner, fault_plan=fault_plan,
                                    checkpoint_dir=ckdir, max_batch=4, **kw)

    ref = [r.value for r in mk().serve(prompts)]
    return dict(cfg=cfg, params=params, prompts=prompts, mk=mk, ref=ref)


def _assert_identical(results, ref):
    assert len(results) == len(ref)
    for r, v in zip(results, ref):
        np.testing.assert_array_equal(r.value, v)


def test_fault_plan_json_roundtrip_all_modes(tmp_path):
    """to_json/from_json round-trips the CONSTRUCTION spec: a reloaded
    plan replays the identical event schedule in every mode (the one
    on-disk format shared by chaos tests, bench_resilience, and fleet
    outage timelines)."""
    def replay(p, n=30):
        return [(e.kind, e.site, e.t, e.offset)
                for _ in range(n) for e in [p.poll("decode", dt=2.0)]
                if e is not None]

    random_p = FaultPlan(3.0, seed=11, weights={"power_loss": 1.0})
    scripted = FaultPlan.scripted([("decode", 2, "power_loss"),
                                   ("decode", 5, "device_drop")])
    timeline = FaultPlan.timeline([(1.5, "power_loss"), (9.0, "power_loss")])
    for plan in (random_p, scripted, timeline, FaultPlan(None)):
        spec = json.loads(json.dumps(plan.to_json()))
        assert replay(FaultPlan.from_json(spec)) == replay(
            FaultPlan.from_json(plan.to_json()))
    # polling state is NOT serialized: a mid-run plan still round-trips
    # to a fresh equivalent plan
    half = FaultPlan(3.0, seed=11, weights={"power_loss": 1.0})
    replay(half, n=7)
    assert replay(FaultPlan.from_json(half.to_json())) == replay(
        FaultPlan(3.0, seed=11, weights={"power_loss": 1.0}))
    # file round-trip + version guard
    path = tmp_path / "plan.json"
    scripted.save(path)
    assert replay(FaultPlan.load(path)) == replay(
        FaultPlan.scripted([("decode", 2, "power_loss"),
                            ("decode", 5, "device_drop")]))
    with pytest.raises(ValueError, match="version"):
        FaultPlan.from_json({"version": 99})


def test_lm_epoch_schedule():
    cfg, params = _lm_setup()
    r = EpochLMRunner(params, cfg, new_tokens=8, epoch_steps=3)
    assert r.epoch_schedule() == (3, 3, 1)          # non-divisible tail
    r = EpochLMRunner(params, cfg, new_tokens=7, epoch_steps=2)
    assert r.epoch_schedule() == (2, 2, 2)
    with pytest.raises(ValueError):
        EpochLMRunner(params, cfg, new_tokens=8, epoch_steps=0)


def test_lm_kill_in_prefill_bit_identical(lm, tmp_path):
    eng = lm["mk"](FaultPlan.scripted([("prefill", 0, "power_loss")]),
                   ckdir=str(tmp_path))
    res = eng.serve(lm["prompts"])
    assert eng.stats["power_losses"] == 1 and eng.stats["retries"] == 4
    _assert_identical(res, lm["ref"])


def test_lm_kill_mid_decode_resumes_from_epoch(lm, tmp_path):
    """A kill in decode epoch 1 must NOT rerun prefill: the retry restores
    the committed (epoch-1) state — the software NV-FA partial-state
    retention — and still produces bit-identical tokens."""
    eng = lm["mk"](FaultPlan.scripted([("decode", 1, "power_loss")]),
                   ckdir=str(tmp_path))
    res = eng.serve(lm["prompts"])
    s = eng.stats
    assert s["prefills"] == 1           # prefill ran exactly once
    assert s["resumes"] == 1            # the retry resumed, not restarted
    # the kill fired at epoch 1's gate (before it ran), so resume replays
    # nothing: epoch 0 + epochs 1..2 = 3 total, all useful
    assert s["epochs"] == 3
    assert s["executed_steps"] == s["useful_steps"] == 6
    _assert_identical(res, lm["ref"])


def test_lm_kill_without_checkpoints_restarts_clean(lm):
    """No checkpoint dir = the volatile P=0 baseline: the kill restarts
    the bucket from prefill, and the output is still bit-identical."""
    eng = lm["mk"](FaultPlan.scripted([("decode", 1, "power_loss")]))
    res = eng.serve(lm["prompts"])
    assert eng.stats["prefills"] == 2 and eng.stats["resumes"] == 0
    _assert_identical(res, lm["ref"])


def test_lm_kill_in_staging_bit_identical(lm, tmp_path):
    eng = lm["mk"](FaultPlan.scripted([("staging", 0, "power_loss")]),
                   ckdir=str(tmp_path))
    res = eng.serve(lm["prompts"])
    assert eng.stats["power_losses"] == 1
    _assert_identical(res, lm["ref"])


def test_lm_staging_corruption_detected_and_restaged(lm):
    eng = lm["mk"](FaultPlan.scripted([("staging", 0,
                                        "staging_corruption")]))
    res = eng.serve(lm["prompts"])
    assert eng.stats["staging_retries"] == 1        # checksum caught it
    assert eng.stats["faults"] == 0                 # not a kill
    _assert_identical(res, lm["ref"])


def test_lm_device_drop_and_slow_dispatch(lm, tmp_path):
    eng = lm["mk"](FaultPlan.scripted([("decode", 0, "device_drop"),
                                       ("decode", 2, "slow_dispatch")]),
                   ckdir=str(tmp_path))
    res = eng.serve(lm["prompts"])
    assert eng.stats["device_drops"] == 1
    assert eng.stats["slow_dispatches"] == 1
    _assert_identical(res, lm["ref"])


def test_lm_random_chaos_bit_identical(lm, tmp_path):
    """Seeded exponential schedule (not scripted): everything completes and
    matches the fault-free run bit for bit."""
    eng = lm["mk"](FaultPlan(6.0, seed=3), ckdir=str(tmp_path),
                   max_retries=50)
    res = eng.serve(lm["prompts"])
    assert eng.stats["faults"] >= 1                 # chaos actually happened
    assert not eng.dead_letters
    _assert_identical(res, lm["ref"])


def test_lm_idempotent_requeue_no_duplicate_results(lm, tmp_path):
    """Killed-bucket requests keep their rid; one Result per rid, and rids
    are exactly the submitted ones."""
    eng = lm["mk"](FaultPlan.scripted([("prefill", 0, "power_loss"),
                                       ("decode", 1, "power_loss")]),
                   ckdir=str(tmp_path))
    rids = [eng.submit(p) for p in lm["prompts"]]
    res = eng.drain()
    assert [r.rid for r in res] == sorted(rids)
    assert len({r.rid for r in res}) == len(rids)
    _assert_identical(res, lm["ref"])


# ---------------------------------------------------------------------------
# CNN path: single-shot dispatch kills, vs the PLAIN engine's output
# ---------------------------------------------------------------------------

SPEC = svhn_cnn_spec(8)
_params, _ = init_cnn(jax.random.PRNGKey(0), SPEC)
CNN_PARAMS = prequantize_cnn_params(_params, SPEC, W1A4)
IMGS = [np.random.RandomState(i).uniform(size=(16, 16, 3)).astype(np.float32)
        for i in range(4)]


def test_cnn_dispatch_kill_bit_identical_to_plain_engine():
    ref = ServeEngine(CNNRunner(CNN_PARAMS, SPEC, W1A4),
                      max_batch=4).serve(IMGS)
    eng = ResilientServeEngine(
        CNNRunner(CNN_PARAMS, SPEC, W1A4),
        fault_plan=FaultPlan.scripted([("dispatch", 0, "power_loss"),
                                       ("staging", 1,
                                        "staging_corruption")]),
        max_batch=4)
    res = eng.serve(IMGS)
    assert eng.stats["power_losses"] == 1
    assert eng.stats["staging_retries"] == 1
    for a, b in zip(ref, res):
        np.testing.assert_array_equal(a.value, b.value)


def test_mesh_rejected():
    class FakeMesh:
        pass

    with pytest.raises(ValueError):
        ResilientServeEngine(CNNRunner(CNN_PARAMS, SPEC, W1A4),
                             mesh=FakeMesh())


# ---------------------------------------------------------------------------
# Recovery policy: retries bounded, deadlines, dead letters
# ---------------------------------------------------------------------------

def test_retry_exhaustion_dead_letters():
    eng = ResilientServeEngine(
        CNNRunner(CNN_PARAMS, SPEC, W1A4),
        fault_plan=FaultPlan.scripted(
            [("dispatch", i, "power_loss") for i in range(3)]),
        max_batch=4, max_retries=2)
    res = eng.serve(IMGS)
    assert res == []
    assert set(eng.dead_letters) == set(range(4))
    assert all("retries exhausted" in v for v in eng.dead_letters.values())
    assert eng.stats["dead_lettered"] == 4
    # the engine stays serviceable: the next submit round succeeds (poll 3
    # has no scripted fault) and gets fresh rids
    res2 = eng.serve(IMGS)
    assert len(res2) == 4 and set(eng.dead_letters) == set(range(4))


def test_deadline_dead_letters_with_fake_clock():
    t = [0.0]
    eng = ResilientServeEngine(
        CNNRunner(CNN_PARAMS, SPEC, W1A4),
        fault_plan=FaultPlan.scripted([("dispatch", 0, "power_loss")]),
        max_batch=4, deadline_s=5.0, clock=lambda: t[0],
        backoff_base_s=0.0, backoff_max_s=0.0)
    for img in IMGS:
        eng.submit(img)     # 4th submit fills the bucket
    t[0] = 1.0
    eng.pump()              # dispatch -> scripted kill -> requeued, in time
    t[0] = 10.0             # past every deadline before the retry lands
    res = eng.drain()
    assert res == []
    assert all(v == "deadline" for v in eng.dead_letters.values())
    assert len(eng.dead_letters) == 4


def test_backoff_schedule_is_bounded_and_jittered():
    eng = ResilientServeEngine(
        CNNRunner(CNN_PARAMS, SPEC, W1A4),
        fault_plan=FaultPlan.scripted(
            [("dispatch", i, "power_loss") for i in range(4)]),
        max_batch=1, max_retries=4, backoff_base_s=0.01, backoff_max_s=0.03,
        clock=lambda: 0.0)
    eng.submit(IMGS[0])
    delays = []
    for _ in range(4):
        eng._flush_all()                      # dispatch -> kill -> requeue
        (eligible_at, _), = eng._retry
        delays.append(eligible_at)
        eng._admit_retries(force=True)
    # exponential growth up to the cap, jitter in [0.5, 1.5) of nominal
    for d, nominal in zip(delays, (0.01, 0.02, 0.03, 0.03)):
        assert 0.5 * nominal <= d < 1.5 * nominal


# ---------------------------------------------------------------------------
# Graceful degradation: plan fallback under fault pressure / energy budget
# ---------------------------------------------------------------------------

def test_degrade_policy_triggers():
    p = DegradePolicy(fault_window=4, fault_threshold=2)
    p.record_fault()
    assert not p.should_degrade()
    p.record_fault()
    assert p.should_degrade()
    p.reset()
    assert not p.should_degrade()
    # old faults age out of the window
    p2 = DegradePolicy(fault_window=2, fault_threshold=2)
    p2.record_fault()
    p2.record_dispatch()
    p2.record_fault()
    assert not p2.should_degrade()
    # energy budget trigger
    p3 = DegradePolicy(energy_budget_pj=100.0)
    p3.record_dispatch(60.0)
    assert not p3.should_degrade()
    p3.record_dispatch(60.0)
    assert p3.should_degrade()
    with pytest.raises(ValueError):
        DegradePolicy(fault_window=0)
    with pytest.raises(ValueError):
        DegradePolicy(energy_budget_pj=-1.0)


@pytest.fixture(scope="module")
def compiled_pair():
    from repro import api

    cfg, params = _lm_setup()
    cfg4 = dataclasses.replace(cfg, quant=PAPER_CONFIGS["w1a4"])
    primary = api.build(cfg, params=params).compile(batch_hints=(1, 4),
                                                    prompt_len=8)
    fallback = api.build(cfg4, params=params).compile(batch_hints=(1, 4),
                                                      prompt_len=8)
    prompts = [np.random.RandomState(i).randint(0, VOCAB, size=(8,))
               .astype(np.int32) for i in range(4)]
    return primary, fallback, prompts


def test_degrade_swaps_to_fallback_plan(compiled_pair, tmp_path):
    """Two prefill kills trip the policy; the engine swaps to the w1a4
    fallback plan, retries with a FRESH budget, and completes with no dead
    letters — outputs bit-identical to the fallback plan served fault-free
    (the accuracy-for-progress trade, executed)."""
    from repro.resilience import ResilienceConfig

    primary, fallback, prompts = compiled_pair
    ref_dep = fallback.serve(resilience=ResilienceConfig(),
                             new_tokens=NEW_TOKENS, max_batch=4)
    ref = [r.value for r in ref_dep.engine.serve(prompts)]

    dep = primary.serve(resilience=ResilienceConfig(
        fault_plan=FaultPlan.scripted([("prefill", 0, "power_loss"),
                                       ("prefill", 1, "power_loss")]),
        checkpoint_dir=str(tmp_path), epoch_steps=2,
        degrade=DegradePolicy(fault_window=4, fault_threshold=2)),
        fallback=fallback, new_tokens=NEW_TOKENS, max_batch=4)
    eng = dep.engine
    res = eng.serve(prompts)
    assert eng.stats["degrades"] == 1
    assert not eng.dead_letters
    assert all(v == 1 for v in eng.result_runner.values())
    for r, v in zip(res, ref):
        np.testing.assert_array_equal(r.value, v)


def test_energy_budget_degrades_between_batches(compiled_pair, tmp_path):
    """No faults at all: a tiny modeled energy budget alone forces the
    fallback for the SECOND batch (result_runner records who served what),
    exercising plan_energy_pj as the budget currency."""
    from repro.core.plan import plan_energy_pj
    from repro.resilience import ResilienceConfig

    primary, fallback, prompts = compiled_pair
    e = plan_energy_pj(primary.plan)
    assert e > 0 and plan_energy_pj(fallback.plan) < e
    dep = primary.serve(resilience=ResilienceConfig(
        checkpoint_dir=str(tmp_path), epoch_steps=2,
        degrade=DegradePolicy(energy_budget_pj=e)),  # first dispatch spends
        fallback=fallback, new_tokens=NEW_TOKENS, max_batch=4)
    eng = dep.engine
    first = eng.serve(prompts)
    assert eng.stats["degrades"] == 1
    second = eng.serve(prompts)
    by_runner = {r.rid: eng.result_runner[r.rid] for r in first + second}
    assert set(by_runner.values()) == {0, 1}
    assert all(eng.result_runner[r.rid] == 1 for r in second)


def test_degrade_policy_edge_cases():
    """Window/threshold/recover_after degenerate values + streak algebra."""
    # zero-width pressure window is rejected at construction, not silently
    # never-triggering (deque(maxlen=0) would drop every observation)
    with pytest.raises(ValueError):
        DegradePolicy(fault_window=0, fault_threshold=1)
    with pytest.raises(ValueError):
        DegradePolicy(recover_after=0)
    # streak: builds on clean dispatches, zeroes on any fault, survives
    # exactly the recover_after boundary
    p = DegradePolicy(recover_after=2)
    p.record_dispatch()
    assert p.clean_streak() == 1 and not p.should_recover()
    p.record_fault()
    assert p.clean_streak() == 0
    p.record_dispatch()
    p.record_dispatch()
    assert p.should_recover()
    p.reset()
    assert p.clean_streak() == 0 and not p.should_recover()
    # recover_after=None: degrades are one-way no matter the streak
    q = DegradePolicy()
    for _ in range(100):
        q.record_dispatch()
    assert not q.should_recover()


def test_equal_energy_fallback_keeps_unit_scale(compiled_pair, tmp_path):
    """A fallback whose modeled energy EQUALS the primary's gives no
    effective MTBF gain: the engine still swaps (forward progress may come
    from the fresh retry budget) but the energy-weighted fault clock must
    keep scale 1.0 — degrading to an equally hungry plan must not dilate
    fault exposure."""
    from repro import api
    from repro.core.plan import plan_energy_pj
    from repro.resilience import ResilienceConfig

    primary, _, prompts = compiled_pair
    cfg, params = _lm_setup()          # same quant as the primary
    clone = api.build(cfg, params=params).compile(batch_hints=(1, 4),
                                                  prompt_len=8)
    assert plan_energy_pj(clone.plan) == plan_energy_pj(primary.plan) > 0
    dep = primary.serve(resilience=ResilienceConfig(
        fault_plan=FaultPlan.scripted([("prefill", 0, "power_loss"),
                                       ("prefill", 1, "power_loss")]),
        checkpoint_dir=str(tmp_path), epoch_steps=2,
        degrade=DegradePolicy(fault_window=4, fault_threshold=2)),
        fallback=clone, new_tokens=NEW_TOKENS, max_batch=4)
    eng = dep.engine
    res = eng.serve(prompts)
    assert eng.stats["degrades"] == 1
    assert eng._energy_scale == 1.0
    assert len(res) == len(prompts) and not eng.dead_letters


def test_recovery_rearms_primary_plan(compiled_pair, tmp_path):
    """After a fault-pressure degrade, ``recover_after`` consecutive clean
    dispatches re-arm the primary: the next batch is served by runner 0
    with outputs bit-identical to the primary's fault-free run, the energy
    scale is restored to 1.0, and stats['recoveries'] records it."""
    from repro.resilience import ResilienceConfig

    primary, fallback, prompts = compiled_pair
    ref_dep = primary.serve(resilience=ResilienceConfig(),
                            new_tokens=NEW_TOKENS, max_batch=4)
    ref = [r.value for r in ref_dep.engine.serve(prompts)]

    dep = primary.serve(resilience=ResilienceConfig(
        fault_plan=FaultPlan.scripted([("prefill", 0, "power_loss"),
                                       ("prefill", 1, "power_loss")]),
        checkpoint_dir=str(tmp_path), epoch_steps=2,
        degrade=DegradePolicy(fault_window=4, fault_threshold=2,
                              recover_after=1)),
        fallback=fallback, new_tokens=NEW_TOKENS, max_batch=4)
    eng = dep.engine
    first = eng.serve(prompts)           # kills -> degrade -> clean dispatch
    assert eng.stats["degrades"] == 1
    assert eng.stats["recoveries"] == 1  # the completing dispatch re-arms
    assert eng._active == 0 and eng._energy_scale == 1.0
    assert all(eng.result_runner[r.rid] == 1 for r in first)
    second = eng.serve(prompts)          # back on the primary plan
    assert all(eng.result_runner[r.rid] == 0 for r in second)
    for r, v in zip(second, ref):
        np.testing.assert_array_equal(r.value, v)


# ---------------------------------------------------------------------------
# Facade: api serve(resilience=...) wiring
# ---------------------------------------------------------------------------

def test_api_serve_resilience_roundtrip(compiled_pair, tmp_path):
    from repro.resilience import ResilienceConfig

    primary, _, prompts = compiled_pair
    ref = [r.value
           for r in primary.serve(resilience=ResilienceConfig(),
                                  new_tokens=NEW_TOKENS,
                                  max_batch=4).engine.serve(prompts)]
    dep = primary.serve(resilience=ResilienceConfig(
        fault_plan=FaultPlan.scripted([("decode", 2, "power_loss")]),
        checkpoint_dir=str(tmp_path), epoch_steps=2),
        new_tokens=NEW_TOKENS, max_batch=4)
    assert isinstance(dep.engine, ResilientServeEngine)
    res = dep.engine.serve(prompts)
    assert dep.engine.stats["resumes"] == 1
    for r, v in zip(res, ref):
        np.testing.assert_array_equal(r.value, v)


def test_exception_types():
    ev_args = ("power_loss", "decode", 1.0, 0.5, 0)
    from repro.resilience import FaultEvent

    with pytest.raises(PowerLoss):
        FaultPlan.raise_for(FaultEvent(*ev_args))
    with pytest.raises(DeviceDrop):
        FaultPlan.raise_for(FaultEvent("device_drop", "decode", 1.0, 0.5, 0))
    # latency/corruption kinds are handled in place, never raised
    FaultPlan.raise_for(FaultEvent("slow_dispatch", "decode", 1.0, 0.5, 0))
