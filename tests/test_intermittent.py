"""Power-intermittency resilience (paper §II-B3 adapted): training with
injected power failures must produce *bit-identical* results to an
uninterrupted run, resuming mid-accumulation from NV-FA-style snapshots."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.models import transformer as T
from repro.configs import SINGLE, all_configs
from repro.train.checkpoint import Checkpointer
from repro.train.intermittent import (
    IntermittentConfig, IntermittentTrainer, PowerFailure, run_with_failures)
from repro.train.optimizer import OptConfig

VOCAB = 64


def _mk_cfg():
    return all_configs()["smollm-360m"].smoke(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab=VOCAB, head_dim=32)


def _loss_fn(cfg):
    def loss(params, batch):
        return T.lm_loss(params, batch, cfg, SINGLE)
    return loss


def _batch_fn(step, micro):
    b = lm_batch(step, micro, batch=4, seq=16, vocab=VOCAB, seed=7)
    return {k: jnp.asarray(v) for k, v in b.items()}


def _make_trainer(tmpdir, fail_at=None):
    cfg = _mk_cfg()
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    icfg = IntermittentConfig(accum_steps=4, snapshot_every=2, full_every=2)
    ckpt = Checkpointer(tmpdir, keep=3, async_save=False)
    return IntermittentTrainer(_loss_fn(cfg), params, OptConfig(lr=1e-3),
                               _batch_fn, ckpt, icfg, fail_at=fail_at)


def test_uninterrupted_baseline(tmp_path):
    tr = _make_trainer(str(tmp_path / "a"))
    out = tr.train(3)
    assert np.isfinite(out["loss"])


def test_failure_mid_accumulation_bit_identical(tmp_path):
    # golden: no failures
    golden = _make_trainer(str(tmp_path / "g"))
    golden.train(4)
    gold_params = jax.tree.leaves(golden.params)

    # chaotic: fail mid-step at (1, micro 3) and (3, micro 1).  The SAME
    # set is passed to every incarnation (failures are the environment's;
    # the trainer discards each one as it fires).
    fails = {(1, 3), (3, 1)}

    def make():
        return _make_trainer(str(tmp_path / "c"), fail_at=fails)

    trainer, out, restarts = run_with_failures(make, 4)
    assert restarts == 2
    got = jax.tree.leaves(trainer.params)
    for a, b in zip(gold_params, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resumes_from_snapshot_not_step_start(tmp_path):
    """After failing at micro 3 (snapshot_every=2), the restart must resume
    from micro 2 — the NV-FA property: partial sums survive power loss."""
    tr = _make_trainer(str(tmp_path / "s"), fail_at={(0, 3)})
    with pytest.raises(PowerFailure):
        tr.train(1)
    tr2 = _make_trainer(str(tmp_path / "s"))
    assert tr2.restore()
    assert tr2._pending is not None
    assert tr2._pending[1] == 2  # resumes at micro 2, not 0


def test_checkpointer_atomic_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = dict(w=jnp.arange(6.0).reshape(2, 3), step=jnp.asarray(3))
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    names = sorted(os.listdir(tmp_path))
    assert len([n for n in names if n.startswith("ckpt_")]) == 2  # GC keeps 2
    step, restored = ck.restore(state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    # no stale tmp dirs left behind
    assert not [n for n in names if n.startswith(".tmp_")]


def test_checkpointer_init_sweeps_stale_tmp_dirs(tmp_path):
    """A process killed mid-write leaves an unpublished .tmp_* dir; it holds
    no durable state (rename never ran) but escapes keep-k GC.  Construction
    sweeps them — and leaves published checkpoints alone."""
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    ck.save(1, dict(w=jnp.ones((2,))))
    stale = tmp_path / ".tmp_killed_mid_write"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"partial")
    ck2 = Checkpointer(str(tmp_path), keep=2, async_save=False)
    names = sorted(os.listdir(tmp_path))
    assert not [n for n in names if n.startswith(".tmp_")]
    assert ck2.latest_step() == 1  # the published checkpoint survived


def test_checkpointer_purge_is_prefix_matching(tmp_path):
    """purge("dec") drops the whole dec<hash> tag family (the resilience
    layer's composition tags) without touching other tags."""
    ck = Checkpointer(str(tmp_path), keep=5, async_save=False)
    state = dict(w=jnp.ones((2,)))
    ck.save(1, state, tag="decaaaa")
    ck.save(2, state, tag="decbbbb")
    ck.save(3, state, tag="ckpt")
    assert ck.purge("dec") == 2
    assert ck.latest_step("decaaaa") is None
    assert ck.latest_step("decbbbb") is None
    assert ck.latest_step("ckpt") == 3
    assert ck.purge("dec") == 0  # idempotent


def test_checkpoint_async_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    state = dict(a=jnp.ones((4, 4)), b=[jnp.zeros(3), jnp.full((2,), 7.0)])
    ck.save(10, state)
    ck.wait()
    step, restored = ck.restore(state)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["b"][1]), [7.0, 7.0])


def test_checkpoint_async_write_failure_raises(tmp_path, monkeypatch):
    """A failed async NV-write must surface at wait(), not be swallowed by
    the daemon thread — silent checkpoint loss is the exact failure the
    paper's retention scheme exists to prevent."""
    import repro.train.checkpoint as C

    ck = C.Checkpointer(str(tmp_path), async_save=True)
    state = dict(w=jnp.ones((2, 2)))

    def boom(*a, **kw):
        raise OSError("NV write failed (injected)")

    monkeypatch.setattr(C.np, "savez", boom)
    ck.save(1, state)
    with pytest.raises(C.CheckpointWriteError) as ei:
        ck.wait()
    assert isinstance(ei.value.__cause__, OSError)
    # the failed write must not have published a checkpoint
    assert ck.latest_step() is None
    # error is consumed once; the checkpointer stays usable afterwards
    monkeypatch.undo()
    ck.save(2, state)
    ck.wait()
    assert ck.latest_step() == 2


def test_checkpoint_async_write_failure_raises_at_next_save(tmp_path,
                                                            monkeypatch):
    """save() waits on the in-flight write first, so a prior failure also
    surfaces there (callers that never call wait() still find out)."""
    import repro.train.checkpoint as C

    ck = C.Checkpointer(str(tmp_path), async_save=True)
    state = dict(w=jnp.zeros((3,)))
    monkeypatch.setattr(C.np, "savez",
                        lambda *a, **kw: (_ for _ in ()).throw(IOError("x")))
    ck.save(1, state)
    if ck._thread is not None:  # let the failure land before re-saving
        ck._thread.join()
    monkeypatch.undo()
    with pytest.raises(C.CheckpointWriteError):
        ck.save(2, state)


def test_forward_progress_budget_stop_counts_only_committed():
    """When the budget_us hard-stop fires, volatile in_flight frames are NOT
    completed work: the no-retention baseline (P=0) that never committed
    anything must report zero, not its still-powered tail."""
    from repro.pim.intermittent import forward_progress

    # mtbf of 40 frames, sequence of 1000: P=0 restarts forever and the
    # budget stops it mid-tail — durable progress is exactly zero.
    for seed in range(3):
        r0 = forward_progress(1000, 1.0, 40.0, 0, seed=seed)
        assert r0["completed_frames"] == 0
        assert r0["efficiency"] == 0.0


def test_forward_progress_p0_vs_p20_ordering():
    """Fig.-7 ordering under harsh intermittency: NV retention (P=20) must
    beat the volatile baseline (P=0) once MTBF << sequence length."""
    from repro.pim.intermittent import forward_progress

    for seed in range(3):
        r0 = forward_progress(1000, 1.0, 40.0, 0, seed=seed)
        r20 = forward_progress(1000, 1.0, 40.0, 20, seed=seed)
        assert r20["completed_frames"] == 1000
        assert r20["efficiency"] > r0["efficiency"]
        # an uninterrupted-completion case still counts its volatile tail
        rful = forward_progress(50, 1.0, 1e9, 0, seed=seed)
        assert rful["completed_frames"] == 50
        assert rful["efficiency"] > 0.9


def test_forward_progress_rejects_bad_inputs():
    """mtbf_us <= 0 would make every exponential draw zero (an infinite
    failure loop inside the budget); the rest silently produce nonsense —
    all must raise up front, in the sweep helper too."""
    from repro.pim.intermittent import forward_progress, sweep_checkpoint_period

    good = dict(n_frames=10, frame_time_us=1.0, mtbf_us=40.0,
                checkpoint_period_frames=2)
    forward_progress(**good)  # sanity: the base point is valid
    for bad in (dict(mtbf_us=0.0), dict(mtbf_us=-1.0), dict(n_frames=0),
                dict(n_frames=-5), dict(frame_time_us=0.0),
                dict(checkpoint_period_frames=-1), dict(nv_write_us=-0.1),
                dict(resume_us=-1.0)):
        with pytest.raises(ValueError):
            forward_progress(**{**good, **bad})
    with pytest.raises(ValueError):
        sweep_checkpoint_period(n_frames=10, frame_time_us=1.0, mtbf_us=0.0)


def test_vulnerable_window_model():
    """Paper: power loss during the final adds costs ~(m+n)*58 ps."""
    from repro.core.compressor import NVFATiming
    t = NVFATiming()
    assert t.vulnerable_window_ps(1, 8) == pytest.approx(9 * 58.0)
    assert t.vulnerable_window_ps(2, 2) == pytest.approx(4 * 58.0)


def test_sweep_checkpoint_period_rng_discipline():
    """The sweep is a pure function of its explicit seed/RNG: same seed ->
    identical aggregates; a caller-supplied RandomState reproduces the
    seed path; anything else is rejected; every statistic carries a 95%
    CI half-width and the repeat count."""
    from repro.pim.intermittent import sweep_checkpoint_period

    kw = dict(periods=(0, 5, 20), mtbf_us=300.0, n_frames=100,
              frame_time_us=1.0, repeats=4)
    a = sweep_checkpoint_period(seed=7, **kw)
    assert a == sweep_checkpoint_period(seed=7, **kw)
    assert a != sweep_checkpoint_period(seed=8, **kw)
    assert a == sweep_checkpoint_period(rng=np.random.RandomState(7), **kw)
    with pytest.raises(TypeError, match="RandomState"):
        sweep_checkpoint_period(rng=42, **kw)
    with pytest.raises(ValueError, match="repeats"):
        sweep_checkpoint_period(repeats=0)
    for r in a.values():
        assert r["repeats"] == 4
        for key in ("efficiency", "completed_frames", "failures"):
            assert r[key + "_ci95"] >= 0.0
    # seeds are drawn per period up front: extending the period list never
    # perturbs the aggregates of the periods before it
    b = sweep_checkpoint_period(seed=7, periods=(0, 5, 20, 50),
                                mtbf_us=300.0, n_frames=100,
                                frame_time_us=1.0, repeats=4)
    assert {p: b[p] for p in (0, 5, 20)} == a


def test_plan_resume_study_paired_and_reproducible():
    """Both arms run on the SAME per-repeat failure seeds (paired draws),
    so cheaper resume can only help: reload efficiency >= recompile on
    the arm means, and the whole study replays bit-for-bit."""
    from repro.pim.intermittent import plan_resume_study

    kw = dict(compile_us=4000.0, plan_load_us=26.0, mtbf_us=300.0,
              n_frames=100, frame_time_us=1.0, repeats=6)
    a = plan_resume_study(seed=3, **kw)
    assert a == plan_resume_study(seed=3, **kw)
    assert a == plan_resume_study(rng=np.random.RandomState(3), **kw)
    assert a["recompile"]["repeats"] == a["plan_reload"]["repeats"] == 6
    assert a["plan_reload"]["efficiency"] >= a["recompile"]["efficiency"]
    assert a["efficiency_gain"] >= 1.0
    assert a["plan_reload"]["efficiency_ci95"] >= 0.0
    with pytest.raises(ValueError, match="repeats"):
        plan_resume_study(4000.0, 26.0, repeats=0)
