"""Fleet simulator + co-design search (repro.fleet, DESIGN.md §14).

Covers: trace determinism and serialization, the fluid node walk's
physics invariants against closed forms, fleet aggregation, the
simulator-vs-live-engine validation contract (the engine-accounting
mirror AND one real ``ResilientServeEngine`` replay), and the co-design
search (SLO enforcement, baseline win, Pareto bookkeeping).
"""
import json

import numpy as np
import pytest

from repro.fleet import (DAY_S, HarvestTrace, NodeConfig, TraceSpec,
                         assign_slos, candidate_space, codesign,
                         epoch_schedule, fleet_report, generate_fleet,
                         make_trace, measured_efficiency, outage_faultplan,
                         predict_engine_stats, rescale_outages,
                         simulate_fleet, simulate_node)
from repro.fleet import sim as fleet_sim
from repro.resilience.faults import POWER_LOSS, FaultPlan


def _const_trace(power_mw: float, duration_s: float = 3600.0,
                 dt_s: float = 60.0) -> HarvestTrace:
    spec = TraceSpec(node_id="n0", archetype="thermal", seed=0, dt_s=dt_s,
                     duration_s=duration_s)
    n = spec.n_samples
    return HarvestTrace(spec, np.full(n, float(power_mw)))


def _cfg(**kw) -> NodeConfig:
    base = dict(node_id="n0", quant="w1a4", target="sot_mram", period=5,
                frame_energy_uj=50.0, frame_time_us=100.0, nv_write_us=1.0,
                resume_us=0.0, cap_uj=10_000.0, wake_frac=0.5)
    base.update(kw)
    return NodeConfig(**base)


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------

def test_trace_determinism_and_prefix_stability():
    """Same spec -> bit-identical trace; node i's spec never depends on
    the fleet size (growth appends, never reshuffles)."""
    spec = TraceSpec(node_id="a", archetype="solar", seed=7)
    np.testing.assert_array_equal(make_trace(spec).power_mw,
                                  make_trace(spec).power_mw)
    big, small = generate_fleet(12, seed=3), generate_fleet(5, seed=3)
    assert big[:5] == small
    assert generate_fleet(12, seed=3) == big
    assert generate_fleet(12, seed=4) != big


def test_trace_archetype_shapes():
    """Solar is zero at night, rf never drops below its floor, thermal
    dropouts reach exactly zero; power is never negative."""
    solar = make_trace(TraceSpec("s", "solar", 1))
    night = int(3 * 3600 / solar.dt_s)      # 03:00, well before sunrise
    assert solar.power_mw[night] == 0.0 and solar.power_mw.max() > 0
    rf = make_trace(TraceSpec("r", "rf", 1, params=dict(floor_mw=2.0)))
    assert rf.power_mw.min() >= 2.0
    thermal = make_trace(TraceSpec("t", "thermal", 1,
                                   params=dict(mean_gap_s=1800.0)))
    assert thermal.power_mw.min() == 0.0    # at least one dropout landed
    for tr in (solar, rf, thermal):
        assert (tr.power_mw >= 0).all() and tr.harvested_j() > 0


def test_trace_serialization_roundtrip():
    spec = TraceSpec("n1", "rf", 42, params=dict(burst_mw=80.0))
    assert TraceSpec.from_json(json.loads(json.dumps(spec.to_json()))) == spec
    tr = make_trace(spec)
    # spec-first form regenerates; embedded form restores verbatim
    lean = HarvestTrace.from_json(json.loads(json.dumps(tr.to_json())))
    np.testing.assert_array_equal(lean.power_mw, tr.power_mw)
    fat = HarvestTrace.from_json(
        json.loads(json.dumps(tr.to_json(embed_power=True))))
    np.testing.assert_array_equal(fat.power_mw, tr.power_mw)
    bad = tr.to_json(embed_power=True)
    bad["power_mw"] = bad["power_mw"][:-1]
    with pytest.raises(ValueError, match="length"):
        HarvestTrace.from_json(bad)


def test_trace_spec_validation():
    with pytest.raises(ValueError, match="archetype"):
        TraceSpec("x", "nuclear", 0)
    with pytest.raises(ValueError, match="positive"):
        TraceSpec("x", "solar", 0, dt_s=0.0)
    with pytest.raises(ValueError, match="cover"):
        TraceSpec("x", "solar", 0, dt_s=60.0, duration_s=30.0)
    with pytest.raises(ValueError, match="weights"):
        generate_fleet(2, mix=(("solar", 0.0), ("rf", 0.0)))


# ---------------------------------------------------------------------------
# Fluid node simulation
# ---------------------------------------------------------------------------

def test_node_config_validation():
    for bad in (dict(period=0), dict(frame_energy_uj=0.0),
                dict(frame_time_us=-1.0), dict(nv_write_us=-0.1),
                dict(cap_uj=0.0), dict(wake_frac=0.0), dict(wake_frac=1.5)):
        with pytest.raises(ValueError):
            _cfg(**bad)


def test_node_ample_harvest_matches_closed_form():
    """Harvest above active power: the node never fails and commits
    exactly int(duration / block_s) * P frames (one closed form vs the
    segment-walking loop)."""
    cfg = _cfg(resume_us=0.0)
    assert cfg.p_active_ujps == pytest.approx(500_000.0)   # 0.5 W
    trace = _const_trace(600.0)                            # 0.6 W harvest
    r = simulate_node(trace, cfg)
    assert r["failures"] == 0 and not r["dead"]
    expected = int(trace.duration_s / cfg.block_s) * cfg.period
    assert r["committed_frames"] == expected
    assert r["on_s"] == pytest.approx(trace.duration_s)
    assert r["off_s"] == 0.0
    assert 0.0 < r["efficiency"] <= 1.0
    # resume debt is paid before any productive block
    cfg2 = _cfg(resume_us=5e5)                             # 0.5 s reboot
    r2 = simulate_node(trace, cfg2)
    assert r2["resume_s"] == pytest.approx(0.5)
    assert r2["committed_frames"] == int(
        (trace.duration_s - 0.5) / cfg2.block_s) * cfg2.period


def test_node_duty_cycle_physics():
    """Insufficient harvest: the node duty-cycles; energy and time are
    conserved and every outage loses at most P in-flight frames."""
    cfg = _cfg()
    trace = _const_trace(100.0, duration_s=7200.0)   # 0.1 W vs 0.5 W draw
    r = simulate_node(trace, cfg)
    assert r["failures"] > 10                        # real duty cycling
    assert r["on_s"] + r["off_s"] == pytest.approx(trace.duration_s)
    # consumed energy can exceed harvested only by the boot buffer charge
    assert r["consumed_j"] <= r["harvested_j"] + cfg.cap_uj * 1e-6 + 1e-9
    assert r["wasted_frames"] <= r["failures"] * cfg.period
    assert r["committed_frames"] % cfg.period == 0
    # the walk is deterministic: identical reruns, bit for bit
    assert simulate_node(trace, cfg) == r


def test_node_bulk_cycle_path_consistent_with_segment_walk():
    """The closed-form k-cycle fast path must agree with walking the same
    constant-power span chopped into many segments (which interrupts
    cycles at boundaries and takes the incremental path)."""
    cfg = _cfg(frame_time_us=2**10, period=3, cap_uj=500.0)
    coarse = simulate_node(_const_trace(20.0, duration_s=7200.0,
                                        dt_s=7200.0), cfg)
    fine = simulate_node(_const_trace(20.0, duration_s=7200.0, dt_s=30.0),
                         cfg)
    assert coarse["failures"] == pytest.approx(fine["failures"], abs=1)
    assert coarse["committed_frames"] == pytest.approx(
        fine["committed_frames"], rel=1e-3)
    assert coarse["on_s"] == pytest.approx(fine["on_s"], rel=1e-6)
    assert coarse["harvested_j"] == pytest.approx(fine["harvested_j"])


def test_node_dead_and_outage_collection():
    """No harvest at all: the boot buffer runs out once, then darkness —
    outage instants are on the work clock (frames) and capped at
    ``collect_outages``."""
    cfg = _cfg(cap_uj=30.0)      # buffer worth ~0.6 frames: dead node
    r = simulate_node(_const_trace(0.0), cfg, collect_outages=4)
    assert r["dead"] and r["failures"] == 1
    assert r["committed_frames"] == 0.0
    assert len(r["outage_frames"]) == 1
    cfg2 = _cfg(cap_uj=10_000.0)
    r2 = simulate_node(_const_trace(100.0, duration_s=7200.0), cfg2,
                       collect_outages=4)
    assert len(r2["outage_frames"]) == 4
    assert all(b > a for a, b in zip(r2["outage_frames"],
                                     r2["outage_frames"][1:]))


def test_fleet_report_aggregates_and_archetypes():
    specs = generate_fleet(6, seed=1, duration_s=3600.0)
    traces = [make_trace(s) for s in specs]
    cfgs = [_cfg(node_id=s.node_id) for s in specs]
    results = simulate_fleet(traces, cfgs)
    rep = fleet_report(results, specs)
    assert rep["nodes"] == 6
    assert rep["inferences_per_day"] == pytest.approx(
        sum(r["inferences_per_day"] for r in results))
    arch = rep["archetypes"]
    assert sum(a["nodes"] for a in arch.values()) == 6
    assert sum(a["inferences_per_day"] for a in arch.values()) == (
        pytest.approx(rep["inferences_per_day"]))
    with pytest.raises(ValueError, match="configs"):
        simulate_fleet(traces, cfgs[:-1])


# ---------------------------------------------------------------------------
# Discrete arm: engine mirror + live validation
# ---------------------------------------------------------------------------

def test_sim_constants_mirror_engine():
    """sim.py keeps jax out of the fluid path by mirroring the engine's
    poll charges as local constants — pin them to the real ones."""
    from repro.resilience import engine as real

    assert fleet_sim.STAGING_DT == real.STAGING_DT
    assert fleet_sim.PREFILL_DT == real.PREFILL_DT


def test_epoch_schedule_mirror():
    from repro.resilience import EpochLMRunner

    for nt, es in ((7, 2), (8, 3), (5, 5), (2, 4)):
        r = object.__new__(EpochLMRunner)   # schedule reads only these two
        r.new_tokens, r.epoch_steps = nt, es
        assert epoch_schedule(nt, es) == r.epoch_schedule()


def test_predict_engine_stats_fault_free():
    s = predict_engine_stats(FaultPlan(None), n_requests=8, new_tokens=7,
                             epoch_steps=2, max_batch=4)
    sched = epoch_schedule(7, 2)
    assert s["prefills"] == s["dispatches"] == 2
    assert s["requests"] == 8 and s["faults"] == 0 and s["resumes"] == 0
    assert s["useful_steps"] == s["executed_steps"] == 2 * sum(sched)
    assert s["commits"] == 2 * (1 + len(sched))
    assert measured_efficiency(s) == pytest.approx(1.0)


def test_predict_engine_stats_timeline_kills():
    """A mid-decode power loss wastes the partial window, requeues the
    bucket, and the resumed attempt skips prefill (checkpoint restore)."""
    plan = outage_faultplan([2.0])       # dies inside the first decode epoch
    s = predict_engine_stats(plan, n_requests=4, new_tokens=7,
                             epoch_steps=2, max_batch=4)
    assert s["power_losses"] == 1 and s["retries"] == 4
    assert s["resumes"] == 1             # second attempt restores, no prefill
    assert s["prefills"] == 1
    assert s["useful_steps"] == sum(epoch_schedule(7, 2))
    assert 0 < s["wasted_steps"] <= 2.0
    assert measured_efficiency(s) < 1.0


def test_outage_faultplan_json_shared_format():
    """The fleet's outage schedule and the chaos FaultPlan share one JSON
    format: timeline events survive the round trip and replay identically."""
    plan = outage_faultplan([1.5, 4.0, 4.0])
    clone = FaultPlan.from_json(json.loads(json.dumps(plan.to_json())))
    kw = dict(n_requests=8, new_tokens=7, epoch_steps=2, max_batch=4)
    assert predict_engine_stats(plan, **kw) == predict_engine_stats(
        clone, **kw)
    with pytest.raises(ValueError, match="every site"):
        FaultPlan.timeline([(1.0, "staging_corruption")])
    with pytest.raises(ValueError, match="non-decreasing"):
        FaultPlan.timeline([(2.0, POWER_LOSS), (1.0, POWER_LOSS)])


def test_rescale_outages():
    assert rescale_outages([10.0, 20.0], 40.0, 8.0) == [2.0, 4.0]
    assert rescale_outages([], 0.0, 8.0) == []


@pytest.mark.slow
def test_live_validation_matches_engine(tmp_path):
    """THE acceptance-criteria contract: the simulator's engine-accounting
    mirror matches a real ResilientServeEngine replay of an outage
    timeline — integer counters exactly, floats within tol."""
    from repro.fleet import live_validation

    v = live_validation([3.0, 9.5], checkpoint_dir=str(tmp_path),
                        n_requests=8, new_tokens=7, epoch_steps=2,
                        max_batch=4, tol=1e-6)
    assert v["ok"], v["deltas"]
    assert v["measured"]["power_losses"] == 2
    assert v["completed"] == 8 and v["dead_letters"] == 0
    assert all(d == 0 for k, d in v["deltas"].items()
               if k in fleet_sim._VALIDATE_INT_KEYS)


# ---------------------------------------------------------------------------
# Co-design search
# ---------------------------------------------------------------------------

# synthetic frontier: cheap-but-inaccurate vs costly-but-accurate, plus a
# dominated target that Pareto pruning must drop
_ACC = {"wA": 5.0, "wB": 10.0}
_COSTS = {("wA", "fast"): (100.0, 200.0), ("wA", "slow"): (150.0, 400.0),
          ("wB", "fast"): (40.0, 120.0), ("wB", "slow"): (60.0, 300.0)}


def test_candidate_space_prunes_dominated_targets():
    cands = candidate_space(_COSTS, quants=("wA", "wB"),
                            targets=("fast", "slow"), periods=(1, 10))
    assert ("wA", "slow", 1) not in cands      # dominated in energy AND time
    assert {("wA", "fast", 1), ("wA", "fast", 10),
            ("wB", "fast", 1), ("wB", "fast", 10)} == set(cands)


def test_assign_slos_deterministic():
    a = assign_slos(50, seed=9, levels=(6.0, 13.0))
    assert a == assign_slos(50, seed=9, levels=(6.0, 13.0))
    assert set(a) == {6.0, 13.0}


def test_codesign_beats_baseline_and_enforces_slo():
    """Heterogeneous SLOs: strict nodes need the accurate quant, loose
    nodes run the cheap one — per-node choice must beat the best uniform
    config, with zero SLO violations and the codesign point on the
    Pareto frontier."""
    specs = generate_fleet(8, seed=2, duration_s=6 * 3600.0)
    traces = [make_trace(s) for s in specs]
    slos = [5.5 if i % 2 else 12.0 for i in range(8)]
    out = codesign(traces, slos, accuracy=_ACC, costs=_COSTS,
                   candidates=candidate_space(_COSTS, quants=("wA", "wB"),
                                              targets=("fast", "slow"),
                                              periods=(1, 10)),
                   node_kw=dict(cap_uj=10_000.0))
    assert out["slo_violations"] == 0
    assert all(a["error_pct"] <= a["slo_error_pct"]
               for a in out["assignments"])
    # strict nodes are forced onto wA; loose nodes pick the cheaper wB
    assert all(a["quant"] == "wA" for a in out["assignments"][1::2])
    assert out["baseline"]["quant"] == "wA"    # only wA fits every SLO
    assert out["win_vs_baseline"] > 1.0
    assert out["inferences_per_day"] >= out["baseline"]["inferences_per_day"]
    kinds = {p["kind"] for p in out["pareto"]}
    assert "codesign" in kinds
    # determinism: the whole search replays bit-for-bit
    out2 = codesign(traces, slos, accuracy=_ACC, costs=_COSTS,
                    candidates=candidate_space(_COSTS, quants=("wA", "wB"),
                                               targets=("fast", "slow"),
                                               periods=(1, 10)),
                    node_kw=dict(cap_uj=10_000.0))
    assert json.dumps(out, sort_keys=True, default=str) == json.dumps(
        out2, sort_keys=True, default=str)


def test_codesign_infeasible_slo_raises():
    specs = generate_fleet(2, seed=0, duration_s=3600.0)
    traces = [make_trace(s) for s in specs]
    with pytest.raises(ValueError, match="SLO"):
        codesign(traces, [4.0, 12.0], accuracy=_ACC, costs=_COSTS,
                 candidates=candidate_space(
                     _COSTS, quants=("wA", "wB"), targets=("fast", "slow"),
                     periods=(1,)))


@pytest.mark.slow
def test_frame_cost_table_real_plans():
    """Structure-only compiles priced via plan_cost_on: Table-II currency
    with sane orderings (more activation bits cost more energy on the
    same PIM target; fp-free)."""
    from repro.fleet import frame_cost_table

    costs = frame_cost_table(quants=("w1a4", "w1a8"),
                             targets=("sot_mram", "reram"))
    for (q, t), (e, lat) in costs.items():
        assert e > 0 and lat > 0
    assert costs[("w1a8", "sot_mram")][0] > costs[("w1a4", "sot_mram")][0]
    assert costs[("w1a8", "reram")][0] > costs[("w1a8", "sot_mram")][0]
