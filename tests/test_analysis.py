"""Static verification subsystem: plan prover (PV101-PV108) + repro-lint
(RL001-RL005).

Pins the DESIGN.md §12 contracts: golden plans prove clean, adversarial
hand-edited plans are rejected with their specific violation IDs, the
prover subsumes the runtime mantissa guards (same boundary, checked at
compile time instead of first dispatch), and the lint rules fire/suppress
exactly as documented.
"""
from __future__ import annotations

import dataclasses
import json
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.lint import lint_source
from repro.analysis.prover import (PlanVerificationError, Violation,
                                   assert_plan_verified, verify_plan,
                                   verify_plan_file)
from repro.configs import SINGLE, all_configs
from repro.configs.paper_cnn import ALEXNET_SPEC, SVHN_SPEC
from repro.core.and_accum import bitgemm_f32dot, f32dot_exact
from repro.core.plan import (LayerPlan, PlanError, compile_lm, compile_model,
                             save_plan)
from repro.core.quant import W1A4, W1A8
from repro.kernels.attn_flash import attn_flash_xla, flash_levels_exact
from repro.models import transformer as T


@pytest.fixture(scope="module")
def svhn_plan():
    return compile_model(None, SVHN_SPEC, W1A4, backend="cpu",
                         batch_hints=(1, 8), img_hw=40, model="svhn")


@pytest.fixture(scope="module")
def alexnet_plan():
    return compile_model(None, ALEXNET_SPEC, W1A8, backend="cpu",
                         batch_hints=(1, 8), img_hw=112, model="alexnet")


@pytest.fixture(scope="module")
def lm_plan():
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(W1A8, engine="auto"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    return compile_lm(params, cfg, backend="cpu", batch_hints=(2,),
                      prompt_len=8)


def _conv_row(k, engine, a_bits=8, w_bits=8):
    """A synthetic quantized conv row with consistent GEMM geometry."""
    return LayerPlan(
        index=0, name="adv", op="conv", role="mid", fp=False, kh=1, kw=1,
        stride=1, padding="SAME", cin=k, cout=16, in_h=8, in_w=8, out_h=8,
        out_w=8, k=k, a_bits=a_bits, w_bits=w_bits, engine=engine,
        engine_source="override", engines=((1, engine), (8, engine)),
        cost=(1.0, 1.0, 1.0))


def _attn_row(head_dim, engine="flash"):
    return LayerPlan(
        index=0, name="adv_attn", op="attn", role="mid", fp=False, kh=0,
        kw=0, stride=1, padding="", cin=0, cout=0, in_h=0, in_w=0, out_h=0,
        out_w=0, k=head_dim, a_bits=8, w_bits=8, engine=engine,
        engine_source="override", engines=((1, engine), (8, engine)),
        cost=(1.0, 1.0, 1.0), attn_engine=engine)


def _rules(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# Golden plans prove clean
# ---------------------------------------------------------------------------

def test_golden_svhn_verifies_clean(svhn_plan):
    assert verify_plan(svhn_plan) == []


def test_golden_alexnet_verifies_clean(alexnet_plan):
    assert verify_plan(alexnet_plan) == []


def test_golden_lm_verifies_clean(lm_plan):
    assert verify_plan(lm_plan) == []


def test_verify_plan_file_clean_on_saved_artifact(svhn_plan, tmp_path):
    base = save_plan(svhn_plan, str(tmp_path / "svhn"))
    assert verify_plan_file(base) == []


def test_verify_plan_file_clean_on_saved_lm(lm_plan, tmp_path):
    base = save_plan(lm_plan, str(tmp_path / "lm"))
    assert verify_plan_file(base) == []


# ---------------------------------------------------------------------------
# Adversarial plans MUST fail with their specific IDs
# ---------------------------------------------------------------------------

def test_mantissa_overflow_bits_rejected_pv101(svhn_plan):
    """16x16-bit f32dot at K=180 blows the fp32 mantissa: PV101 (and the
    engine_feasible re-check PV103 on the same row)."""
    bad = dataclasses.replace(
        svhn_plan, layers=(_conv_row(180, "f32dot", a_bits=16, w_bits=16),))
    rules = _rules(verify_plan(bad))
    assert "PV101" in rules and "PV103" in rules


def test_int32_accumulator_overflow_rejected_pv102(svhn_plan):
    bad = dataclasses.replace(
        svhn_plan, layers=(_conv_row(64, "int8", a_bits=20, w_bits=20),))
    assert "PV102" in _rules(verify_plan(bad))


def test_infeasible_engine_row_rejected_pv103(svhn_plan):
    """A hand-edited row pinning the Pallas 'fused' engine on a cpu plan is
    infeasible (off-TPU Pallas only interprets)."""
    violations = verify_plan(
        dataclasses.replace(svhn_plan, layers=(_conv_row(64, "fused"),)))
    assert any(v.rule == "PV103" and "fused" in v.message
               for v in violations)


def test_missing_attn_table_row_rejected_pv104(lm_plan):
    bad = dataclasses.replace(lm_plan, attn_table={})
    violations = verify_plan(bad)
    assert any(v.rule == "PV104" and "attn_table" in v.where
               for v in violations)


def test_orphan_dense_table_entry_rejected_pv104(lm_plan):
    table = dict(lm_plan.dense_table)
    table[("dense", 999, 999, 8, 1, "cpu")] = "planes"
    violations = verify_plan(dataclasses.replace(lm_plan,
                                                 dense_table=table))
    assert any(v.rule == "PV104" and "orphan" in v.message
               for v in violations)


def test_paged_lm_plan_verifies_clean_pv108(lm_plan):
    """A paged geometry declared at compile time (page_size, kv_pages)
    adds a 10-tuple attn_table verdict that proves PV108 clean."""
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(W1A8, engine="auto"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    plan = compile_lm(params, cfg, backend="cpu", batch_hints=(1, 4),
                      prompt_len=8, page_size=4, kv_pages=8)
    assert verify_plan(plan) == []
    paged_keys = [k for k in plan.attn_table if len(k) == 10]
    assert paged_keys and all(k[8] == 4 and k[9] == 32 for k in paged_keys)


def test_paged_nontiling_page_size_rejected_pv108(lm_plan):
    """page_size that does not tile the table extent: the paged program's
    whole-page table cannot represent the geometry."""
    table = dict(lm_plan.attn_table)
    table[("attn", 1, 2, 32, True, 0, True, "cpu", 3, 32)] = "paged"
    violations = verify_plan(dataclasses.replace(lm_plan,
                                                 attn_table=table))
    assert any(v.rule == "PV108" and "tile" in v.message
               for v in violations)


def test_paged_int32_index_overflow_rejected_pv108(lm_plan):
    """A pool whose flat KV index exceeds int32 at the largest batch hint
    would corrupt the gather at serve time — rejected at compile."""
    table = dict(lm_plan.attn_table)
    big = 1 << 25                          # 2 * big * 2 * 32 = 2^32 > int32
    table[("attn", 1, 2, 32, True, 0, True, "cpu", 4, big)] = "paged"
    violations = verify_plan(dataclasses.replace(lm_plan,
                                                 attn_table=table))
    assert any(v.rule == "PV108" and "int32" in v.message
               for v in violations)


def test_corrupted_cost_annotation_rejected_pv105(svhn_plan):
    row = dataclasses.replace(svhn_plan.layers[0],
                              cost=(-1.0, 10.0, 10.0))
    bad = dataclasses.replace(svhn_plan,
                              layers=(row,) + svhn_plan.layers[1:])
    assert any(v.rule == "PV105" and "energy_pj=-1.0" in v.message
               for v in verify_plan(bad))


def test_version_drift_rejected_pv107(svhn_plan):
    bad = dataclasses.replace(svhn_plan, version=99)
    assert "PV107" in _rules(verify_plan(bad))


def test_duplicate_batch_hints_rejected_pv107(svhn_plan):
    bad = dataclasses.replace(svhn_plan, batch_hints=(1, 1))
    assert "PV107" in _rules(verify_plan(bad))


def test_hand_edited_artifact_rejected_on_disk_pv106(svhn_plan, tmp_path):
    """A hand-edited .json artifact no longer matches the reloaded plan's
    re-serialization — verify_plan_file reports PV106 even when the edit is
    semantically invisible to load_plan."""
    path = save_plan(svhn_plan, str(tmp_path / "edited"))
    with open(path) as f:
        meta = json.load(f)
    meta["zzz_hand_edit"] = True
    with open(path, "w") as f:
        json.dump(meta, f)
    assert "PV106" in _rules(verify_plan_file(path))


def test_assert_plan_verified_raises_plan_error(svhn_plan):
    bad = dataclasses.replace(
        svhn_plan, layers=(_conv_row(180, "f32dot", a_bits=16, w_bits=16),))
    with pytest.raises(PlanVerificationError) as ei:
        assert_plan_verified(bad)
    assert isinstance(ei.value, PlanError)  # existing handlers catch it
    assert "verify=False" in str(ei.value)
    assert all(isinstance(v, Violation) for v in ei.value.violations)


# ---------------------------------------------------------------------------
# The prover subsumes the runtime mantissa guards (same boundary, earlier)
# ---------------------------------------------------------------------------

def test_prover_subsumes_f32dot_guard(svhn_plan):
    """At 8x8 bits the f32dot bound flips between K=258 and K=259; the
    prover rejects exactly where the bitgemm_f32dot runtime guard raises."""
    assert f32dot_exact(258, 8, 8) and not f32dot_exact(259, 8, 8)
    for k in (258, 259):
        plan = dataclasses.replace(svhn_plan,
                                   layers=(_conv_row(k, "f32dot"),))
        has_pv101 = "PV101" in _rules(verify_plan(plan))
        assert has_pv101 == (not f32dot_exact(k, 8, 8))
    # runtime guard agrees at the same boundary — but only fires at dispatch
    a = jnp.ones((1, 259), jnp.float32)
    w = jnp.ones((259, 4), jnp.float32)
    with pytest.raises(ValueError, match="f32dot engine inexact"):
        bitgemm_f32dot(a, w, 8, 8)
    assert bitgemm_f32dot(a[:, :258], w[:258], 8, 8).shape == (1, 4)


def test_prover_subsumes_flash_guard(svhn_plan):
    """flash_levels_exact flips at head_dim 1024 (8/8 bits); the prover
    flags PV101 exactly there, before attn_flash_xla's ValueError could."""
    assert flash_levels_exact(1023, 8, 8) and not flash_levels_exact(
        1024, 8, 8)
    for hd in (1023, 1024):
        plan = dataclasses.replace(svhn_plan, layers=(_attn_row(hd),))
        has_pv101 = "PV101" in _rules(verify_plan(plan))
        assert has_pv101 == (not flash_levels_exact(hd, 8, 8))
    q = jnp.zeros((1, 4, 1, 1024), jnp.float32)
    with pytest.raises(ValueError, match="head_dim"):
        attn_flash_xla(q, q, q)


def test_prover_subsumes_implicit_group_bound(svhn_plan):
    """Off-TPU implicit groups at 4-bit nibbles: 15*15*K < 2^24 fails past
    K=74565 — the same bound engine_feasible states as a reason string."""
    plan = dataclasses.replace(svhn_plan,
                               layers=(_conv_row(80000, "implicit"),))
    assert "PV101" in _rules(verify_plan(plan))


# ---------------------------------------------------------------------------
# Escape hatch + compile wiring
# ---------------------------------------------------------------------------

def test_compile_model_verify_escape_hatch(monkeypatch):
    """verify=True (default) routes through assert_plan_verified and
    surfaces prover rejections as PlanVerificationError; verify=False
    bypasses the prover entirely."""
    from repro.analysis import prover

    boom = [Violation("PV999", "test", "injected failure")]
    monkeypatch.setattr(prover, "verify_plan", lambda plan, target=None: boom)
    with pytest.raises(PlanVerificationError, match="PV999"):
        compile_model(None, SVHN_SPEC, W1A4, backend="cpu",
                      batch_hints=(1,), img_hw=40, model="svhn")
    plan = compile_model(None, SVHN_SPEC, W1A4, backend="cpu",
                         batch_hints=(1,), img_hw=40, model="svhn",
                         verify=False)
    assert plan.layers  # compiled fine with the prover bypassed


# ---------------------------------------------------------------------------
# repro-lint rules (fixture sources through lint_source)
# ---------------------------------------------------------------------------

def _lint(src, rel):
    return lint_source(textwrap.dedent(src), rel)


def _lint_rules(src, rel):
    return {v.rule for v in _lint(src, rel)}


def test_rl001_wall_clock_in_resilience_only():
    src = """\
    import time
    def now():
        return time.time()
    """
    assert _lint_rules(src, "src/repro/resilience/chaos.py") == {"RL001"}
    assert _lint_rules(src, "src/repro/launch/serve.py") == set()


def test_rl001_unseeded_numpy_rng():
    bad = "import numpy as np\nx = np.random.rand(3)\n"
    assert _lint_rules(bad, "src/repro/resilience/faults.py") == {"RL001"}
    unseeded_ctor = "import numpy as np\nr = np.random.RandomState()\n"
    assert _lint_rules(unseeded_ctor,
                       "src/repro/resilience/faults.py") == {"RL001"}
    seeded = "import numpy as np\nr = np.random.RandomState(1234)\n"
    assert _lint_rules(seeded, "src/repro/resilience/faults.py") == set()


def test_rl002_host_sync_scoped_to_src_repro():
    src = """\
    import jax.numpy as jnp
    def f(x):
        return float(jnp.max(x))
    """
    assert _lint_rules(src, "src/repro/kernels/k.py") == {"RL002"}
    assert _lint_rules(src, "tests/test_k.py") == set()  # out of scope


def test_rl002_inline_suppression():
    src = """\
    import jax.numpy as jnp
    def f(x):
        return float(jnp.max(x))  # repro-lint: disable=RL002 — pre-jit
    """
    assert _lint_rules(src, "src/repro/kernels/k.py") == set()


def test_rl003_broad_except_swallow():
    bad = """\
    try:
        work()
    except Exception:
        pass
    """
    assert _lint_rules(bad, "benchmarks/run2.py") == {"RL003"}
    reraised = """\
    try:
        work()
    except Exception:
        cleanup()
        raise
    """
    assert _lint_rules(reraised, "benchmarks/run2.py") == set()
    narrow = """\
    try:
        work()
    except ValueError:
        pass
    """
    assert _lint_rules(narrow, "benchmarks/run2.py") == set()


def test_rl003_pragma_rides_with_noqa():
    src = """\
    try:
        work()
    except BaseException as e:  # noqa: BLE001  repro-lint: disable=RL003 — recorded
        record(e)
    """
    assert _lint_rules(src, "src/repro/train/x.py") == set()


def test_rl003_file_level_suppression():
    src = """\
    # repro-lint: disable-file=RL003 — scratch script
    try:
        work()
    except Exception:
        pass
    """
    assert _lint_rules(src, "benchmarks/scratch.py") == set()


def test_rl004_blockspec_arity_mismatch():
    bad = """\
    import jax.experimental.pallas as pl
    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(4, 4),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        )(x)
    """
    violations = _lint(bad, "src/repro/kernels/k.py")
    assert [v.rule for v in violations] == ["RL004"]
    assert "takes 1 argument(s)" in violations[0].message
    good = bad.replace("lambda i: (i, 0)", "lambda i, j: (i, 0)")
    assert _lint(good, "src/repro/kernels/k.py") == []


def test_rl004_block_shape_rank_mismatch():
    bad = """\
    import jax.experimental.pallas as pl
    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i,))],
        )(x)
    """
    violations = _lint(bad, "src/repro/kernels/k.py")
    assert [v.rule for v in violations] == ["RL004"]
    assert "rank-2 block shape" in violations[0].message


def test_rl005_foreign_private_mutation():
    src = """\
    def drain(engine):
        engine._pending = []
        engine._queue.append(1)
    """
    assert [v.rule for v in _lint(src, "src/repro/launch/engine.py")] \
        == ["RL005", "RL005"]
    assert _lint(src, "src/repro/launch/other.py") == []  # out of scope
    owner = """\
    class Engine:
        def drain(self):
            self._pending = []
    """
    assert _lint(owner, "src/repro/launch/engine.py") == []


def test_lint_syntax_error_reports_rl000():
    violations = lint_source("def broken(:\n", "src/repro/x.py")
    assert [v.rule for v in violations] == ["RL000"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_check_plan_ok_and_reject(svhn_plan, tmp_path, capsys):
    from repro.analysis.__main__ import main

    path = save_plan(svhn_plan, str(tmp_path / "cli"))
    assert main(["check-plan", path]) == 0
    with open(path) as f:
        meta = json.load(f)
    meta["zzz_hand_edit"] = True
    with open(path, "w") as f:
        json.dump(meta, f)
    assert main(["check-plan", path]) == 1
    assert "PV106" in capsys.readouterr().out
    assert main(["check-plan"]) == 2  # no plans given


def test_cli_lint_list_rules(capsys):
    from repro.analysis.__main__ import main

    assert main(["lint", "--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("RL001", "RL002", "RL003", "RL004", "RL005"):
        assert rule in out
