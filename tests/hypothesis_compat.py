"""Import-or-skip shim for ``hypothesis``.

The property tests in this suite use hypothesis, which is a dev-only
dependency (see requirements-dev.txt).  When it is not installed the
property tests are collected as skips while every example-based test in
the same module keeps running — `pytest.importorskip` at module level
would throw those away too.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401 — re-exported to the test modules

    HAVE_HYPOTHESIS = True
except ImportError:  # stub decorators: collectable, skipped at run time
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """st.* factory stub — arguments to the stubbed @given are unused."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            # zero-arg stub (not functools.wraps) so pytest does not try to
            # resolve the property-test arguments as fixtures
            def stub():
                pytest.skip("hypothesis not installed")

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco
