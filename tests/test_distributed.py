"""Sharding-rule unit tests + data pipeline + compression + elastic logic.

Pure-logic tests run on the 1-device CPU mesh; PP runs in a subprocess
with 8 forced host devices.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import all_configs, make_plan
from repro.distributed.sharding import pspec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _plan(multi=False):
    return make_plan({"pod": 2, "data": 16, "model": 16} if multi
                     else {"data": 16, "model": 16})


def test_tp_rules_divisible():
    plan = _plan()
    cfg = all_configs()["phi3-mini-3.8b"]
    # wq (d, Hp*hd): heads -> model
    spec = pspec_for((3072, 32 * 96), ("embed", "heads"), plan, MESH, cfg)
    assert spec == P("data", "model")
    # kv 32 % 16 == 0 -> sharded
    spec = pspec_for((3072, 32 * 96), ("embed", "kv_heads"), plan, MESH, cfg)
    assert spec == P("data", "model")


def test_kv_replication_when_indivisible():
    plan = _plan()
    cfg = all_configs()["yi-34b"]  # kv=8, tp=16
    spec = pspec_for((7168, 8 * 128), ("embed", "kv_heads"), plan, MESH, cfg)
    assert spec == P("data", None)


def test_expert_sharding_rules():
    plan = _plan()
    ds = all_configs()["deepseek-moe-16b"]   # 64 % 16 == 0 -> EP
    gr = all_configs()["granite-moe-3b-a800m"]  # 40 % 16 != 0 -> replicate E, TP d_ff
    assert pspec_for((64, 2048, 1408), ("expert", "embed", "mlp"), plan, MESH, ds) \
        == P("model", "data", None)  # mlp falls back: model consumed by expert
    assert pspec_for((40, 1536, 512), ("expert", "embed", "mlp"), plan, MESH, gr) \
        == P(None, "data", "model")


def test_duplicate_mesh_axis_guard():
    plan = _plan()
    cfg = all_configs()["phi3-mini-3.8b"]
    # cache (layers, batch, seq, kv, hd): kv sharded => cache_seq must yield
    spec = pspec_for((32, 256, 32768, 32, 96),
                     ("layers", "batch", "cache_seq", "kv_heads", None),
                     plan, MESH, cfg)
    assert spec == P(None, ("data",), "model", None, None)


def test_indivisible_batch_replicates():
    plan = _plan(multi=True)
    spec = pspec_for((1, 128), ("batch", None), plan, MESH_MP, None)
    assert spec == P(None, None)  # batch 1 % 32 != 0 -> replicated


def test_vocab_padding_multiple_of_tp():
    for arch, cfg in all_configs().items():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab


def test_plan_padded_heads():
    plan = _plan()
    assert plan.padded_heads(56) == 64   # yi
    assert plan.padded_heads(15) == 16   # smollm
    assert plan.padded_heads(32) == 32   # phi3


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import Pipeline
    from repro.data.synthetic import lm_batch

    fn = lambda s, m: lm_batch(s, m, batch=8, seq=8, vocab=32, seed=1)
    p0 = Pipeline(fn, accum_steps=2, host_index=0, n_hosts=2).start(0)
    p1 = Pipeline(fn, accum_steps=2, host_index=1, n_hosts=2).start(0)
    (sm0, b0) = next(p0)
    (sm1, b1) = next(p1)
    assert sm0 == sm1 == (0, 0)
    assert b0["tokens"].shape == (4, 8)
    # shards are disjoint slices of the same global batch
    g = fn(0, 0)
    np.testing.assert_array_equal(b0["tokens"], g["tokens"][:4])
    np.testing.assert_array_equal(b1["tokens"], g["tokens"][4:])
    p0.stop(); p1.stop()
    # determinism across restarts
    p2 = Pipeline(fn, accum_steps=2, host_index=0, n_hosts=2).start(0)
    (_, b0b) = next(p2)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    p2.stop()


def test_gradient_compression_error_feedback():
    from repro.train.compression import (
        compress, compressed_allreduce, decompress, init_error_feedback)
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64) * 0.01)}
    ef = init_error_feedback(g)
    # single-shot error is bounded by one quantization level
    lv, sc = compress(g["w"], 8)
    err = np.abs(np.asarray(decompress(lv, sc)) - np.asarray(g["w"])).max()
    assert err <= float(sc) * 0.5 + 1e-9
    # error feedback telescopes: mean of N compressed steps -> true mean
    total, total_q = np.zeros((64, 64)), np.zeros((64, 64))
    for i in range(50):
        gi = {"w": jnp.asarray(np.random.RandomState(i).randn(64, 64) * 0.01)}
        cq, ef = compressed_allreduce(gi, ef)
        total += np.asarray(gi["w"])
        total_q += np.asarray(cq["w"])
    rel = np.abs(total_q - total).max() / np.abs(total).max()
    assert rel < 0.05, f"error feedback failed to telescope: {rel}"


def test_elastic_assignment_properties():
    from repro.train.elastic import shard_assignment, straggler_backup
    n = 8
    a = shard_assignment(n, step=3, micro=1, global_batch=64)
    hosts = [h for h, _ in a]
    offs = [o for _, o in a]
    assert sorted(hosts) == list(range(n))     # every host assigned
    assert sorted(offs) == [i * 8 for i in range(n)]  # full coverage
    b = straggler_backup(3, n, step=0, micro=0)
    assert b != 3 and 0 <= b < n


PP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_pipeline_mesh, pipeline_apply
from repro.distributed.sharding import mesh_context

S, M, mb, d = 4, 8, 2, 16
mesh = make_pipeline_mesh(S, data=2)
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (S, d, d)) * 0.2

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(key, (M, mb, d))
with mesh_context(mesh):
    y = pipeline_apply(stage_fn, Ws, x, mesh=mesh, n_microbatches=M)
# oracle: sequential application of all stages
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("PIPELINE OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_8dev_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", PP_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE OK" in p.stdout, p.stdout + p.stderr
