"""Implicit-GEMM conv: bit-identity vs the im2col+qGEMM path, engine
dispatch, and the ``im2col_sliced`` edge cases the implicit kernel must
reproduce (stride-2 SAME on odd dims, VALID, rectangular kernels).

The contract under test: patch extraction in-register (Pallas kernel) or
as a direct convolution (XLA realization) is *bit-identical* — not merely
close — to materializing ``im2col_sliced`` patches and running the fused
qGEMM, across every paper bit-width, both strides, and both paddings.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_lowering import im2col, im2col_sliced, quant_conv2d_pre
from repro.core.prequant import level_dtype, prequantize_conv_weight
from repro.core.quant import W1A4, activation_levels
from repro.kernels.conv_implicit import conv_implicit_pallas, conv_implicit_xla
from repro.kernels.ops import ConvShape, quant_conv_serve, select_engine

BITS = [(1, 1), (2, 1), (4, 1), (8, 1), (4, 4)]


def _conv_problem(ab, wb, H=9, W=9, kh=3, kw=3, cin=5, cout=7, B=2):
    k1, k2 = jax.random.split(jax.random.PRNGKey(ab * 31 + wb + kh))
    x = jax.random.uniform(k1, (B, H, W, cin), minval=-0.2, maxval=1.2)
    w = jax.random.normal(k2, (kh, kw, cin, cout))
    w_lv, s_w, z_w = prequantize_conv_weight(w, wb)
    x_lv = activation_levels(x, ab)[0].astype(level_dtype(ab))
    return x, x_lv, w_lv, s_w, z_w


# ---------------------------------------------------------------------------
# bit-identity: implicit (both realizations) vs the patch-GEMM path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ab,wb", BITS)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_implicit_bit_identical_to_patch_gemm(ab, wb, stride, padding):
    x, x_lv, w_lv, s_w, z_w = _conv_problem(ab, wb)
    kw_args = dict(kh=3, kw=3, stride=stride, padding=padding,
                   a_bits=ab, w_bits=wb)
    ref = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w, engine="int8",
                                      **kw_args))
    pallas = np.asarray(conv_implicit_pallas(x_lv, w_lv, s_w, z_w,
                                             interpret=True, **kw_args))
    xla = np.asarray(conv_implicit_xla(x_lv, w_lv, s_w, z_w, **kw_args))
    assert (pallas == ref).all()
    assert (xla == ref).all()


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_implicit_bit_identical_to_fused_qgemm(stride, padding):
    """Against the PR-1 fused Pallas chain specifically (same epilogue)."""
    ab, wb = 4, 1
    x, x_lv, w_lv, s_w, z_w = _conv_problem(ab, wb, H=8, W=8, cin=4, cout=6)
    kw_args = dict(kh=3, kw=3, stride=stride, padding=padding,
                   a_bits=ab, w_bits=wb)
    fused = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w, engine="fused",
                                        **kw_args))
    pallas = np.asarray(conv_implicit_pallas(x_lv, w_lv, s_w, z_w,
                                             interpret=True, **kw_args))
    assert (pallas == fused).all()


def test_implicit_rectangular_kernel_and_odd_dims():
    """kh != kw on odd spatial dims — the halo arithmetic must still match."""
    for stride in (1, 2):
        for padding in ("SAME", "VALID"):
            x, x_lv, w_lv, s_w, z_w = _conv_problem(
                4, 1, H=7, W=11, kh=5, kw=3, cin=3, cout=4)
            kw_args = dict(kh=5, kw=3, stride=stride, padding=padding,
                           a_bits=4, w_bits=1)
            ref = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w,
                                              engine="int8", **kw_args))
            pallas = np.asarray(conv_implicit_pallas(
                x_lv, w_lv, s_w, z_w, interpret=True, **kw_args))
            xla = np.asarray(conv_implicit_xla(x_lv, w_lv, s_w, z_w,
                                               **kw_args))
            assert (pallas == ref).all(), (stride, padding)
            assert (xla == ref).all(), (stride, padding)


def test_quant_conv2d_pre_auto_engine_bit_identical():
    """The dispatcher's pick (implicit on this shape, any backend) matches
    an explicit GEMM engine bit-for-bit through the public conv entry."""
    x, x_lv, w_lv, s_w, z_w = _conv_problem(4, 1, H=20, W=20, cin=64,
                                            cout=32, B=2)
    kw_args = dict(kh=3, kw=3, stride=1, padding="SAME", a_bits=4, w_bits=1)
    auto = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w, **kw_args))
    ref = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w, engine="f32dot",
                                      **kw_args))
    assert (auto == ref).all()


def test_quant_conv_serve_explicit_implicit_engine():
    x, x_lv, w_lv, s_w, z_w = _conv_problem(2, 1)
    kw_args = dict(kh=3, kw=3, stride=1, padding="SAME", a_bits=2, w_bits=1)
    out = np.asarray(quant_conv_serve(x_lv, w_lv, s_w, z_w,
                                      engine="implicit", **kw_args))
    ref = np.asarray(quant_conv_serve(x_lv, w_lv, s_w, z_w, engine="int8",
                                      **kw_args))
    assert (out == ref).all()


def test_implicit_xla_huge_k_accumulator_exact():
    """K in [65793, 74565) at a_bits=8: each nibble-pair conv fits the f32
    mantissa but their SUM does not — the accumulation must run in int32
    (regression: f32 accumulation silently rounded, max diff ~2e-3).

    The reference is the jitted ``quant_conv2d_pre`` path: bit-identity is
    a compiled-vs-compiled property (eager execution of the same epilogue
    can differ by FMA-contraction ulps on CPU)."""
    cin = 7400  # K = 3*3*7400 = 66600
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.uniform(k1, (1, 5, 5, cin))
    w = jax.random.normal(k2, (3, 3, cin, 2))
    w_lv, s_w, z_w = prequantize_conv_weight(w, 1)
    x_lv = activation_levels(x, 8)[0].astype(level_dtype(8))
    kw_args = dict(kh=3, kw=3, stride=1, padding="SAME", a_bits=8, w_bits=1)
    got = np.asarray(conv_implicit_xla(x_lv, w_lv, s_w, z_w, **kw_args))
    ref = np.asarray(quant_conv2d_pre(x, w_lv, s_w, z_w, engine="int8",
                                      **kw_args))
    assert (got == ref).all()


# ---------------------------------------------------------------------------
# engine dispatch
# ---------------------------------------------------------------------------

def test_select_engine_implicit_dispatch():
    deep = ConvShape(20, 20, 3, 3, 1, "SAME")      # kdim 3*3*64 = 576
    assert select_engine(800, 576, 128, 4, 1, backend="tpu",
                         conv=deep) == "implicit"
    # on CPU this 64->128 channel-expanding conv sits below the measured
    # cin=96 crossover (svhn L2 ran implicit at 0.63x gemm) -> f32dot
    assert select_engine(800, 576, 128, 4, 1, backend="cpu",
                         conv=deep) == "f32dot"
    # the non-expanding sibling (cin = cout = 64) stays implicit on CPU
    same = ConvShape(20, 20, 3, 3, 1, "SAME")
    assert select_engine(800, 576, 64, 4, 1, backend="cpu",
                         conv=same) == "implicit"
    # 1x1 conv: no patch blowup -> never implicit
    one = ConvShape(20, 20, 1, 1, 1, "VALID")
    assert select_engine(800, 64, 128, 4, 1, backend="tpu",
                         conv=one) == "fused"
    # shallow K stays fused on TPU
    shallow = ConvShape(40, 40, 3, 3, 1, "SAME")   # kdim 3*3*3 = 27
    assert select_engine(3200, 27, 64, 4, 1, backend="tpu",
                         conv=shallow) == "fused"
    # stride outside the kernel's support -> GEMM engines
    s4 = ConvShape(112, 112, 11, 11, 4, "SAME")
    assert select_engine(784, 363, 96, 4, 1, backend="tpu",
                         conv=s4) == "fused"
    # full-window FC-as-conv (alexnet FC6): oh=ow=1, zero im2col blowup,
    # the dense fused GEMM is strictly better
    fc = ConvShape(6, 6, 6, 6, 1, "VALID")
    assert select_engine(1, 9216, 4096, 8, 1, backend="tpu",
                         conv=fc) == "fused"
    assert select_engine(1, 9216, 4096, 8, 1, backend="cpu",
                         conv=fc) == "f32dot"
    # tiny-spatial off-TPU: patch GEMM keeps winning (measured)
    tiny = ConvShape(13, 13, 3, 3, 1, "SAME")
    assert select_engine(169, 2304, 384, 8, 1, backend="cpu",
                         conv=tiny) in ("f32dot", "int8")
    # no conv geometry: dense dispatch unchanged
    assert select_engine(800, 576, 128, 4, 1, backend="tpu") == "fused"
    # VMEM feasibility is in BYTES of the level dtype: a 224x224x96 image
    # fits as int8 levels (a_bits<=7) but not as int32 levels (a_bits=8,
    # ~19.6 MB resident > the 8 MiB budget) -> falls back to fused
    big = ConvShape(224, 224, 3, 3, 1, "SAME")
    assert select_engine(224 * 224, 864, 128, 4, 1, backend="tpu",
                         conv=big) == "implicit"
    assert select_engine(224 * 224, 864, 128, 8, 1, backend="tpu",
                         conv=big) == "fused"
    # off-TPU feasibility: K beyond the xla realization's exactness bound
    # must fall back to the GEMM engines, not trace-crash in the kernel
    huge = ConvShape(16, 16, 3, 3, 1, "SAME")  # K = 9*8300 = 74700
    assert select_engine(512, 74700, 64, 4, 4, backend="cpu",
                         conv=huge) == "int8"


def test_implicit_xla_exactness_guard():
    """5-7 bit operands stay whole under _nibble_split, so the feasibility
    bound must use the actual group widths (regression: assuming 4-bit
    groups silently rounded W6A6 at K=45000)."""
    from repro.kernels.conv_implicit import implicit_xla_exact

    assert implicit_xla_exact(2304, 8, 1)          # alexnet regime
    assert implicit_xla_exact(66600, 8, 1)         # nibble-split, exact
    assert not implicit_xla_exact(45000, 6, 6)     # whole 6-bit groups
    assert not implicit_xla_exact(74700, 4, 4)     # past the nibble bound
    cin = 5000  # K = 45000
    x_lv = jnp.ones((1, 4, 4, cin), jnp.int8)
    w_lv = jnp.ones((9 * cin, 2), jnp.int8)
    with pytest.raises(ValueError, match="inexact"):
        conv_implicit_xla(x_lv, w_lv, jnp.float32(1.0), jnp.float32(0.0),
                          kh=3, kw=3, stride=1, padding="SAME",
                          a_bits=6, w_bits=6)


def test_cnn_serve_forward_engines_agree():
    """Full serve forward: auto dispatch == forced GEMM engine, float
    checkpoint == prequantized params (on-the-fly prequant path)."""
    from repro.core.prequant import prequantize_cnn_params
    from repro.models.cnn import ConvSpec, cnn_forward, init_cnn

    # tiny 3-layer net exercising implicit dispatch + the 1x1 fallback
    spec = [ConvSpec(3, 16, 3, role="first"), ConvSpec(16, 64, 3),
            ConvSpec(64, 10, 1, role="last")]
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    sp = prequantize_cnn_params(params, spec, W1A4)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3))
    auto = np.asarray(cnn_forward(sp, x, spec, W1A4, "serve"))
    forced = np.asarray(cnn_forward(
        sp, x, spec, dataclasses.replace(W1A4, engine="int8"), "serve"))
    from_float = np.asarray(cnn_forward(params, x, spec, W1A4, "serve"))
    assert (auto == forced).all()
    assert (auto == from_float).all()


# ---------------------------------------------------------------------------
# im2col_sliced edge cases (cross-checked vs conv_general_dilated_patches)
# ---------------------------------------------------------------------------

def _patches_oracle(x, kh, kw, stride, padding):
    """(kh, kw, C)-major view of ``im2col`` (which wraps
    ``jax.lax.conv_general_dilated_patches``, (C, kh, kw)-major)."""
    p = im2col(x, kh, kw, stride, padding)
    b, oh, ow, _ = p.shape
    c = x.shape[-1]
    return (p.reshape(b, oh, ow, c, kh * kw)
            .transpose(0, 1, 2, 4, 3).reshape(b, oh, ow, kh * kw * c))


@pytest.mark.parametrize("hw,kh,kw,stride,padding", [
    ((7, 7), 3, 3, 2, "SAME"),     # stride 2, SAME, odd dims
    ((9, 7), 3, 3, 2, "SAME"),     # odd + rectangular image
    ((8, 8), 3, 3, 1, "VALID"),
    ((9, 9), 3, 3, 2, "VALID"),
    ((8, 10), 2, 5, 1, "SAME"),    # kh != kw
    ((10, 8), 5, 2, 2, "VALID"),   # kh != kw, strided, VALID
    ((5, 5), 5, 5, 1, "VALID"),    # window == image
])
def test_im2col_sliced_matches_dilated_patches(hw, kh, kw, stride, padding):
    h, w = hw
    x = jax.random.uniform(jax.random.PRNGKey(h * w + kh), (2, h, w, 3))
    got = np.asarray(im2col_sliced(x, kh, kw, stride, padding))
    want = np.asarray(_patches_oracle(x, kh, kw, stride, padding))
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)


def test_im2col_sliced_preserves_integer_dtype():
    """The serve path's whole point: integer patches stay integer."""
    x = jnp.arange(2 * 6 * 6 * 4, dtype=jnp.int8).reshape(2, 6, 6, 4) % 16
    p = im2col_sliced(x, 3, 3, 2, "SAME")
    assert p.dtype == jnp.int8
    assert p.shape == (2, 3, 3, 36)
