"""Paged KV cache: allocator (core/kv_pages.py) and the continuous
batching scheduler built on it (launch/engine.ContinuousLMEngine,
DESIGN.md §13).

Allocator contract: fixed-size block pool with all-or-nothing alloc,
FIFO reuse (deterministic page placement for replay), and a snapshot/
restore pair that preserves free-list ORDER so a resumed engine
allocates the same pages an uninterrupted one would.

Scheduler contract: step-granular admission/retirement is invisible to
numerics — every request's tokens are bit-identical to running it alone
through the same engine — while the jit cache stays at exactly three
programs regardless of the request mix.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SINGLE, all_configs
from repro.core.kv_pages import PagePool, PoolExhausted, pages_needed
from repro.core.quant import PAPER_CONFIGS
from repro.launch.engine import ContinuousLMEngine, QueueFull
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# pages_needed: ceil-div with a ragged final page
# ---------------------------------------------------------------------------

def test_pages_needed_ragged():
    assert pages_needed(0, 16) == 0
    assert pages_needed(-3, 16) == 0
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2      # one token spills to a new page
    assert pages_needed(33, 16) == 3


# ---------------------------------------------------------------------------
# PagePool: all-or-nothing alloc, ownership-checked free, FIFO reuse
# ---------------------------------------------------------------------------

def test_pool_exhaustion_allocates_nothing():
    p = PagePool(4, 16)
    got = p.alloc(3)
    with pytest.raises(PoolExhausted):
        p.alloc(2)                         # only 1 free: all-or-nothing
    assert p.free_pages == 1               # the failed alloc took nothing
    assert p.stats()["allocs"] == 3
    p.free(got)
    assert p.free_pages == 4 and p.used_pages == 0


def test_pool_free_rejects_foreign_and_double():
    p = PagePool(4, 16)
    got = p.alloc(2)
    with pytest.raises(ValueError):
        p.free([got[0], 99])               # foreign page: nothing freed
    assert p.used_pages == 2
    p.free(got)
    with pytest.raises(ValueError):
        p.free([got[0]])                   # double free
    with pytest.raises(ValueError):
        p.free([p.null_page])              # the null page is never owned


def test_pool_fifo_reuse_order():
    """Freed pages recycle in free order — page placement is a pure
    function of the alloc/free history, which resume replay depends on."""
    p = PagePool(6, 8)
    a = p.alloc(3)
    b = p.alloc(3)
    p.free(b)
    p.free(a)
    assert p.alloc(6) == b + a             # FIFO: b's pages come back first


def test_pool_stats_and_capacity():
    p = PagePool(8, 4)
    assert p.capacity_tokens() == 32 and p.null_page == 8
    assert p.can_fit(32) and not p.can_fit(33)
    got = p.alloc(5)
    st = p.stats()
    assert st["used_pages"] == 5 and st["high_water"] == 5
    p.free(got[:2])
    p.alloc(1)
    assert p.stats()["high_water"] == 5    # high-water never decays


def test_pool_snapshot_restore_roundtrip_preserves_order():
    p = PagePool(6, 8)
    a = p.alloc(2)
    b = p.alloc(2)
    p.free(a)                              # free list now: [4, 5, a0, a1]
    snap = p.snapshot()
    q = PagePool(6, 8)
    q.alloc(6)                             # scramble the fresh pool
    q.restore(snap)
    assert q.used_pages == p.used_pages == 2
    assert q.alloc(4) == p.alloc(4)        # identical reuse order
    with pytest.raises(ValueError):
        PagePool(6, 4).restore(snap)       # page_size mismatch
    with pytest.raises(ValueError):
        PagePool(8, 8).restore(snap)       # num_pages mismatch


# ---------------------------------------------------------------------------
# ContinuousLMEngine scheduler (smoke LM, w1a8 serve quantization)
# ---------------------------------------------------------------------------

def _lm_setup():
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    return cfg, params


CFG, PARAMS = _lm_setup()


def _engine(**kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("max_seq", 16)
    return ContinuousLMEngine(PARAMS, CFG, **kw)


def _payloads(n, seed=0, lens=(3, 5, 8), gens=(2, 4, 6)):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, CFG.vocab, rng.choice(lens)).astype(np.int32),
             int(rng.choice(gens))) for _ in range(n)]


def test_submit_rejects_impossible_requests():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.submit((np.arange(15, dtype=np.int32), 4))   # beyond max_seq
    with pytest.raises(ValueError):
        eng.submit((np.asarray([1], np.int32), 0))       # no horizon
    with pytest.raises(ValueError):
        eng.submit((np.zeros(0, np.int32), 4))           # empty prompt


def test_queue_full_at_max_pending():
    eng = _engine(max_pending=2)
    eng.submit((np.asarray([1, 2], np.int32), 2))
    eng.submit((np.asarray([3], np.int32), 2))
    with pytest.raises(QueueFull):
        eng.submit((np.asarray([4], np.int32), 2))
    assert len(eng.drain()) == 2           # nothing was lost


def test_pool_exhaustion_defers_admission_then_completes():
    """A pool too small for two in-flight requests serializes them:
    admission waits for pages (no failure, no deadlock), every request
    still completes, and every page returns to the pool."""
    eng = _engine(num_slots=2, num_pages=4, max_seq=16)   # 16-token pool
    res = eng.serve([(np.arange(1, 9, dtype=np.int32), 8),   # 4 pages: all
                     (np.arange(1, 9, dtype=np.int32), 8)])  # of them
    assert len(res) == 2 and all(len(r.value) == 8 for r in res)
    assert eng.pool.used_pages == 0
    st = eng.pool.stats()
    assert st["allocs"] == st["frees"] == 8
    assert st["high_water"] == 4           # never co-resident


def test_pages_released_on_retirement():
    eng = _engine()
    eng.serve(_payloads(6))
    assert eng.pool.used_pages == 0
    assert eng.pool.stats()["allocs"] == eng.pool.stats()["frees"] > 0
    assert (eng._table == eng.pool.null_page).all()


def test_pages_released_on_dead_letter():
    """A deadline overrun frees its pages and lands in dead_letters —
    the slot is reusable, the tokens are not silently dropped."""
    t = [0.0]
    eng = _engine(deadline_s=1.0, clock=lambda: t[0])
    eng.submit((np.asarray([1, 2, 3], np.int32), 12), t_submit=0.0)
    eng.pump()                             # admit + prefill + first step
    assert eng._slots[0] is not None
    t[0] = 2.0                             # blow the deadline
    eng.pump()
    assert eng._slots[0] is None and eng.pool.used_pages == 0
    assert len(eng.dead_letters) == 1
    dl = eng.dead_letters[0]
    assert dl["reason"] == "deadline" and len(dl["emitted"]) >= 1
    assert eng.stats["dead_lettered"] == 1


def test_continuous_bit_identical_to_sequential():
    """Step-granular join/leave is numerically invisible: a request's
    tokens match running it alone through the same engine class."""
    payloads = _payloads(8, seed=3)
    batched = _engine(num_slots=3, num_pages=16).serve(payloads)
    seq_eng = _engine(num_slots=3, num_pages=16)
    for p, r in zip(payloads, batched):
        [ref] = seq_eng.serve([p])
        np.testing.assert_array_equal(r.value, ref.value)


def test_program_count_bounded_under_mixed_replay():
    """64 mixed-length requests compile exactly three programs: the
    (1, chunk) prefill insert, the (num_slots, 1) decode step, and the
    page reset — the jit cache is bounded by geometry, not request mix."""
    eng = _engine(num_slots=2, num_pages=16)
    res = eng.serve(_payloads(64, seed=7))
    assert len(res) == 64
    assert eng.program_shapes == {
        ("reset",), ("run", 1, eng.chunk), ("run", eng.num_slots, 1)}


def test_fault_resume_bit_identical(tmp_path):
    """Two scripted power losses mid-decode: the engine reboots from its
    epoch checkpoints and the final token streams are bit-identical to a
    fault-free run."""
    from repro.resilience.faults import FaultPlan

    payloads = _payloads(6, seed=5)
    ref = _engine().serve(payloads)
    faults = FaultPlan.scripted([("decode", 3, "power_loss"),
                                 ("decode", 9, "power_loss")])
    eng = _engine(checkpoint_dir=str(tmp_path), epoch_steps=2,
                  faults=faults)
    res = eng.serve(payloads)
    assert eng.stats["power_losses"] == 2 and eng.stats["commits"] >= 2
    assert [r.rid for r in res] == [r.rid for r in ref]
    for a, b in zip(res, ref):
        np.testing.assert_array_equal(a.value, b.value)


def test_cross_process_resume_from_checkpoint(tmp_path):
    """A second engine constructed on the same checkpoint_dir adopts the
    first engine's in-flight state (pools, page table, allocator free
    list, queue) and drains to bit-identical results."""
    payloads = _payloads(5, seed=11, gens=(6, 8))
    ref = _engine().serve(payloads)

    first = _engine(checkpoint_dir=str(tmp_path), epoch_steps=1)
    for p in payloads:
        first.submit(p)
    for _ in range(3):
        first.pump()                       # die mid-flight (after a commit)
    assert any(s is not None for s in first._slots) or first._waiting

    second = _engine(checkpoint_dir=str(tmp_path), epoch_steps=1)
    res = second.drain()
    got = {r.rid: r.value for r in res}
    for r in ref:
        np.testing.assert_array_equal(got[r.rid], r.value)
