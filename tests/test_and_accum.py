"""AND-Accumulation engine equivalence (paper Eq. 1) — property tests.

All four engines must agree *bit-exactly* on integer levels, and the
dequantized GEMM must match the quantize->float-matmul oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import and_accum, bitplane
from repro.core.quant import activation_levels_signed, weight_levels

ENGINES = ["planes", "packed", "int8", "int8_planewise"]


@given(
    st.integers(1, 24), st.integers(1, 80), st.integers(1, 24),
    st.integers(1, 8), st.integers(1, 8), st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_engines_bit_exact(M, K, N, a_bits, w_bits, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a_lv = jax.random.randint(k1, (M, K), 0, 1 << a_bits).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (K, N), 0, 1 << w_bits).astype(jnp.int32)
    gold = np.asarray(a_lv) @ np.asarray(w_lv)  # plain integer GEMM identity
    for eng in ENGINES:
        out = np.asarray(and_accum._ENGINES[eng](a_lv, w_lv, a_bits, w_bits))
        assert (out == gold).all(), eng


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_quant_dense_matches_reference(a_bits, w_bits, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.uniform(k1, (7, 50))
    w = jax.random.normal(k2, (50, 11))
    ref = and_accum.reference_float(a, w, a_bits, w_bits)
    for eng in ENGINES:
        out = and_accum.quant_dense_forward(a, w, a_bits, w_bits, engine=eng)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_signed_affine_correction_exact():
    a = jax.random.normal(jax.random.PRNGKey(0), (9, 64)) * 3
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 13))
    for (ab, wb) in [(8, 1), (4, 2), (8, 8)]:
        al, sa, za = activation_levels_signed(a, ab)
        wl, sw, zw = weight_levels(w, wb)
        ref = ((np.asarray(al) - float(za)) * float(sa)) @ (
            (np.asarray(wl) - float(zw)) * float(sw))
        out = and_accum.quant_dense_forward_signed(a, w, ab, wb)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(K, seed):
    x = jax.random.randint(jax.random.PRNGKey(seed), (3, K), 0, 2)
    p = bitplane.pack_bits(bitplane.pad_to_lane(x))
    assert (np.asarray(bitplane.unpack_bits(p, k=K)) == np.asarray(x)).all()


@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_decompose_compose_roundtrip(bits, seed):
    lv = jax.random.randint(jax.random.PRNGKey(seed), (4, 9), 0, 1 << bits)
    planes = bitplane.decompose(lv, bits)
    assert (np.asarray(bitplane.compose(planes)) == np.asarray(lv)).all()
    # plane values are {0,1}
    assert set(np.unique(np.asarray(planes))) <= {0, 1}


def test_conv_lowering_matches_float_conv():
    from repro.core import conv_lowering as cl
    from repro.core.quant import activation_levels as alv
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 3, 4)) * 0.2
    a_l, s_a = alv(x, 4)
    w_l, s_w, z_w = weight_levels(w, 2)
    xq = a_l.astype(jnp.float32) * s_a
    wq = (w_l.astype(jnp.float32) - z_w) * s_w
    for stride, pad in [(1, "SAME"), (2, "VALID")]:
        ref = cl.conv2d_float(xq, wq, stride=stride, padding=pad)
        out = cl.quant_conv2d(x, w, stride=stride, padding=pad,
                              a_bits=4, w_bits=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_compressor_truth_table():
    from repro.core.compressor import compressor_outputs
    for bits in range(32):
        x = [(bits >> i) & 1 for i in range(5)]
        s, c, co = compressor_outputs(*x)
        assert sum(x) == s + 2 * (c + co), x
