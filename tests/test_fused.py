"""Fused quantize->bit-GEMM serve pipeline: kernel, prequant, dispatcher.

The fused Pallas kernel must be bit-exact against ``bitgemm_int8`` on the
integer accumulator (verified by pinning the epilogue scales to (1, 0) so
the kernel output IS the accumulator) and within fp32 tolerance of the
``reference_float`` oracle; the pre-quantized CNN serve path must be
numerically identical to the seed re-quantizing path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import and_accum
from repro.core.prequant import level_dtype, prequantize_conv_weight, serve_weight_bytes
from repro.core.quant import W1A4, W1A8, activation_levels, weight_levels
from repro.kernels import ops
from repro.kernels.fused_qgemm import fused_qgemm_pallas

BITS = [(1, 1), (2, 1), (4, 1), (8, 1), (4, 4)]
SHAPES = [(5, 70, 9), (33, 130, 17), (130, 600, 140)]


def _rand_problem(M, K, N, ab, wb):
    k1, k2 = jax.random.split(jax.random.PRNGKey(M + 13 * ab + wb))
    a = jax.random.uniform(k1, (M, K), minval=-0.3, maxval=1.3)
    w = jax.random.normal(k2, (K, N))
    w_lv, s_w, z_w = weight_levels(w, wb)
    return a, w, w_lv, s_w, z_w


@pytest.mark.parametrize("ab,wb", BITS)
@pytest.mark.parametrize("M,K,N", SHAPES)
def test_fused_qgemm_accumulator_bit_exact(M, K, N, ab, wb):
    """Scales pinned to (s=1, t=0): kernel output == int32 accumulator, which
    must equal bitgemm_int8 exactly (int32 < 2^24 here, so f32 is lossless)."""
    a, _, w_lv, _, _ = _rand_problem(M, K, N, ab, wb)
    one = jnp.asarray(float((1 << ab) - 1), jnp.float32)  # s_a * s_w == 1
    zero = jnp.zeros((), jnp.float32)
    out = np.asarray(fused_qgemm_pallas(
        a, w_lv.astype(level_dtype(wb)), one, zero,
        a_bits=ab, w_bits=wb, interpret=True))
    a_lv, _ = activation_levels(a, ab)
    gold = np.asarray(and_accum.bitgemm_int8(a_lv, w_lv, ab, wb))
    assert (out == gold.astype(np.float32)).all()


@pytest.mark.parametrize("ab,wb", BITS)
@pytest.mark.parametrize("M,K,N", SHAPES[:2])
def test_fused_qgemm_vs_reference_float(M, K, N, ab, wb):
    a, w, w_lv, s_w, z_w = _rand_problem(M, K, N, ab, wb)
    out = np.asarray(fused_qgemm_pallas(
        a, w_lv.astype(level_dtype(wb)), s_w, z_w,
        a_bits=ab, w_bits=wb, interpret=True))
    ref = np.asarray(and_accum.reference_float(a, w, ab, wb))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    # full-epilogue agreement with the unfused pre-levels path (same f32
    # expression; only FMA contraction may differ -> ulp tolerance)
    a_lv, _ = activation_levels(a, ab)
    exp = np.asarray(and_accum.quant_dense_pre_levels(
        a_lv, w_lv, s_w, z_w, ab, wb, engine="int8"))
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_fused_qgemm_level_input_mode():
    """a_is_levels=True skips in-kernel quantization; same result."""
    a, _, w_lv, s_w, z_w = _rand_problem(17, 90, 11, 4, 1)
    a_lv, _ = activation_levels(a, 4)
    via_float = np.asarray(fused_qgemm_pallas(
        a, w_lv.astype(jnp.int8), s_w, z_w, a_bits=4, w_bits=1,
        interpret=True))
    via_levels = np.asarray(fused_qgemm_pallas(
        a_lv.astype(jnp.int8), w_lv.astype(jnp.int8), s_w, z_w,
        a_bits=4, w_bits=1, a_is_levels=True, interpret=True))
    assert (via_float == via_levels).all()


def test_engines_include_f32dot_exact():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a_lv = jax.random.randint(k1, (9, 200), 0, 256).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (200, 7), 0, 16).astype(jnp.int32)
    gold = np.asarray(a_lv) @ np.asarray(w_lv)
    out = np.asarray(and_accum.bitgemm_f32dot(a_lv, w_lv, 8, 4))
    assert (out == gold).all() and out.dtype == np.int32


def test_f32dot_raises_beyond_mantissa_bound():
    """Explicit engine='f32dot' must be loud, not silently inexact."""
    a_lv = jnp.ones((2, 300), jnp.int32) * 255
    w_lv = jnp.ones((300, 2), jnp.int32) * 255
    with pytest.raises(ValueError, match="f32dot"):
        and_accum.bitgemm_f32dot(a_lv, w_lv, 8, 8)


def test_select_engine_dispatch():
    # off-TPU: exact float GEMM while the fp32-mantissa bound holds
    assert ops.select_engine(64, 576, 64, 4, 1, backend="cpu") == "f32dot"
    assert ops.select_engine(64, 576, 64, 4, 1, backend="gpu") == "f32dot"
    # bound exceeded (8x8 bits, huge K): exact int8 path
    assert ops.select_engine(64, 1 << 12, 64, 8, 8, backend="cpu") == "int8"
    # TPU default: the fused Pallas pipeline
    assert ops.select_engine(4096, 2304, 256, 4, 1, backend="tpu") == "fused"
    assert ops.select_engine(4096, 2304, 256, 8, 1, backend="tpu") == "fused"
    # binary / huge-K / skinny output: faithful packed-VPU Pallas kernel
    assert ops.select_engine(64, 1 << 16, 64, 1, 1, backend="tpu") == "faithful"


def test_quant_dense_serve_engines_agree():
    a, _, w_lv, s_w, z_w = _rand_problem(21, 128, 10, 4, 2)
    a_lv, _ = activation_levels(a, 4)
    w8 = w_lv.astype(jnp.int8)
    outs = {
        eng: np.asarray(ops.quant_dense_serve(
            a_lv.astype(jnp.int8) if eng == "fused" else a_lv, w8, s_w, z_w,
            a_bits=4, w_bits=2, engine=eng))
        for eng in ("fused", "int8", "f32dot", "packed", "faithful")
    }
    base = outs.pop("int8")
    for eng, out in outs.items():
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5,
                                   err_msg=eng)


def test_quant_conv2d_pre_matches_requant_conv():
    from repro.core import conv_lowering as cl

    x = jax.random.uniform(jax.random.PRNGKey(5), (2, 9, 9, 3))
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 3, 5)) * 0.3
    w_lv, s_w, z_w = prequantize_conv_weight(w, 2)
    for stride, pad in [(1, "SAME"), (2, "SAME"), (2, "VALID")]:
        ref = np.asarray(cl.quant_conv2d(x, w, stride=stride, padding=pad,
                                         a_bits=4, w_bits=2))
        # the dispatcher's TPU picks must also work through the legacy
        # (re-quantizing) conv entry point
        for eng in ("fused", "faithful"):
            out = np.asarray(cl.quant_conv2d(x, w, stride=stride, padding=pad,
                                             a_bits=4, w_bits=2, engine=eng))
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5,
                                       err_msg=f"legacy/{eng}")
        for eng in (None, "fused", "faithful", "int8"):
            out = np.asarray(cl.quant_conv2d_pre(
                x, w_lv, s_w, z_w, kh=3, kw=3, stride=stride, padding=pad,
                a_bits=4, w_bits=2, engine=eng))
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5,
                                       err_msg=f"{stride}/{pad}/{eng}")


def test_im2col_sliced_matches_float_im2col_contraction():
    """Layouts differ ((kh,kw,C) vs (C,kh,kw)) but the conv results agree."""
    from repro.core import conv_lowering as cl

    x = jax.random.uniform(jax.random.PRNGKey(7), (2, 8, 8, 4))
    w = jax.random.normal(jax.random.PRNGKey(8), (3, 3, 4, 6))
    p = cl.im2col_sliced(x, 3, 3, 1, "SAME")
    out = p.reshape(-1, p.shape[-1]) @ w.reshape(-1, 6)
    ref = cl.conv2d_float(x, w)
    np.testing.assert_allclose(np.asarray(out).reshape(ref.shape),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_prequantize_cnn_params_forward_identical():
    """prequantize_cnn_params + serve forward == seed re-quantizing serve."""
    from repro.core.prequant import prequantize_cnn_params
    from repro.models.cnn import cnn_forward, init_cnn, svhn_cnn_spec

    spec = svhn_cnn_spec(8)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3))
    for q in (W1A4, W1A8):
        ref = np.asarray(cnn_forward(params, x, spec, q, "serve"))
        sp = prequantize_cnn_params(params, spec, q)
        out = np.asarray(cnn_forward(sp, x, spec, q, "serve"))
        np.testing.assert_array_equal(out, ref)
        # first/last stay fp; quantized layers store int8 levels, no float w
        assert "w" in sp[0] and "w_lv" not in sp[0]
        assert "w" not in sp[1] and sp[1]["w_lv"].dtype == jnp.int8
        assert serve_weight_bytes(sp) < serve_weight_bytes(params)
