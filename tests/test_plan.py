"""Compile-once execution plans (repro.core.plan, DESIGN.md §8).

Pins four contracts:

* **Golden dispatch table** — ``compile_model``'s engine choice for every
  paper CNN layer (svhn, alexnet) at batch 1 and 8 on CPU.  A heuristic /
  cost-model regression shows up here as a readable dict diff, not as a
  perf mystery three benchmarks later.
* **Plan-time validation** — explicit ``QuantConfig.engine`` overrides
  that are infeasible for the backend/shape raise :class:`PlanError`
  naming the layer, instead of failing inside a ``pallas_call``.
* **Bit-identity** — plan-compiled serve output equals the legacy
  ``engine="auto"`` dispatch (CNN and LM), and a serialized plan reloaded
  from disk reproduces it WITHOUT requantizing or re-autotuning.
* **Plan-keyed program caches** — the serving engine never shares a
  compiled program between two different plans.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.quant import QuantConfig, W1A4, W1A8
from repro.kernels import ops
from repro.models.cnn import ConvSpec, cnn_forward, init_cnn, svhn_cnn_spec


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    """Plan installs / autotune verdicts must never leak across tests."""
    ops.clear_plan_state()
    yield
    ops.clear_plan_state()


def _small_setup(channels=8, img=16, batch=2, quant=W1A4, seed=0):
    spec = svhn_cnn_spec(channels)
    params, _ = init_cnn(jax.random.PRNGKey(seed), spec)
    x = jax.random.uniform(jax.random.PRNGKey(seed + 1),
                           (batch, img, img, 3))
    return spec, params, x


# ---------------------------------------------------------------------------
# Golden dispatch table (paper CNNs, CPU, batch 1 and 8)
# ---------------------------------------------------------------------------

GOLDEN_CPU = {
    "svhn": {
        "conv0": {1: "fp", 8: "fp"},
        "conv1": {1: "implicit", 8: "implicit"},
        # channel-expanding with cin below the measured cin=96 CPU
        # crossover (svhn L2 ran implicit at 0.63x gemm, crossover
        # 32->64 at 0.77x in bench_conv): route the patch GEMM
        "conv2": {1: "f32dot", 8: "f32dot"},
        "conv3": {1: "implicit", 8: "implicit"},
        "conv4": {1: "f32dot", 8: "f32dot"},
        "conv5": {1: "f32dot", 8: "implicit"},
        "conv6": {1: "f32dot", 8: "f32dot"},
        "conv7": {1: "fp", 8: "fp"},
    },
    "alexnet": {
        "conv0": {1: "fp", 8: "fp"},
        "conv1": {1: "implicit", 8: "implicit"},
        "conv2": {1: "f32dot", 8: "implicit"},
        "conv3": {1: "f32dot", 8: "implicit"},
        "conv4": {1: "f32dot", 8: "implicit"},
        "fc5": {1: "f32dot", 8: "f32dot"},
        "fc6": {1: "f32dot", 8: "f32dot"},
        "fc7": {1: "fp", 8: "fp"},
    },
}


def test_golden_dispatch_table_cpu():
    from repro.configs.paper_cnn import ALEXNET_SPEC, SVHN_SPEC

    got = {}
    for name, spec, img, quant in (("svhn", SVHN_SPEC, 40, W1A4),
                                   ("alexnet", ALEXNET_SPEC, 112, W1A8)):
        plan = P.compile_model(None, spec, quant, backend="cpu",
                               batch_hints=(1, 8), img_hw=img, model=name)
        got[name] = {lp.name: dict(lp.engines) for lp in plan.layers}
    assert got == GOLDEN_CPU


def test_structure_only_plan_cannot_execute():
    spec, _, x = _small_setup()
    plan = P.compile_model(None, spec, W1A4, img_hw=16)
    with pytest.raises(P.PlanError, match="structure-only"):
        P.plan_forward(plan, x)


# ---------------------------------------------------------------------------
# Plan-time validation of explicit engine overrides
# ---------------------------------------------------------------------------

def test_plan_error_fused_on_cpu_names_layer():
    spec, params, _ = _small_setup()
    with pytest.raises(P.PlanError, match=r"layer 1 \(conv1.*Pallas"):
        P.compile_model(params, spec,
                        dataclasses.replace(W1A4, engine="fused"),
                        backend="cpu", img_hw=16)


def test_plan_error_f32dot_mantissa_bound():
    # W8A8 at K=3*3*64: the f32dot accumulator bound (2^24) is exceeded
    spec = [ConvSpec(3, 64, 3, role="first"), ConvSpec(64, 64, 3),
            ConvSpec(64, 10, 1, role="last")]
    quant = QuantConfig(w_bits=8, a_bits=8, engine="f32dot")
    with pytest.raises(P.PlanError, match=r"layer 1 .*mantissa"):
        P.compile_model(None, spec, quant, backend="cpu", img_hw=16)


def test_plan_error_implicit_on_1x1():
    spec = [ConvSpec(3, 8, 3, role="first"), ConvSpec(8, 8, 1),
            ConvSpec(8, 10, 1, role="last")]
    with pytest.raises(P.PlanError, match=r"layer 1 .*1x1"):
        P.compile_model(None, spec,
                        dataclasses.replace(W1A4, engine="implicit"),
                        backend="cpu", img_hw=16)


def test_feasible_override_passes_strict_validation():
    spec, params, x = _small_setup()
    quant = dataclasses.replace(W1A4, engine="f32dot")
    plan = P.compile_model(params, spec, quant, backend="cpu", img_hw=16)
    assert all(lp.engine == "f32dot" and lp.engine_source == "override"
               for lp in plan.layers if not lp.fp)
    # and the permissive compat path still matches it bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(P.plan_forward(plan, x)),
        np.asarray(cnn_forward(plan.params, x, spec, quant, "serve")))


# ---------------------------------------------------------------------------
# Bit-identity: plan execution vs legacy auto dispatch; float checkpoints
# ---------------------------------------------------------------------------

def test_plan_forward_bit_identical_to_auto_dispatch():
    spec, params, x = _small_setup()
    plan = P.compile_model(params, spec, W1A4, batch_hints=(1, 2), img_hw=16)
    ref = np.asarray(cnn_forward(plan.params, x, spec, W1A4, "serve"))
    out = np.asarray(P.plan_forward(plan, x))
    np.testing.assert_array_equal(out, ref)
    # float checkpoint through the same plan structure (trace-time prequant)
    from_float = np.asarray(cnn_forward(params, x, spec, W1A4, "serve"))
    np.testing.assert_array_equal(out, from_float)


def test_prepare_serve_params_shim_is_gone():
    """The PR-4 deprecation shim was removed on schedule; compile_model's
    params payload is the (only) prequantization path and matches the raw
    prequantize step it wraps."""
    spec, params, _ = _small_setup()
    import repro.models.cnn as cnn_mod
    from repro.core.prequant import prequantize_cnn_params

    assert not hasattr(cnn_mod, "prepare_serve_params")
    sp = prequantize_cnn_params(params, spec, W1A4)
    plan = P.compile_model(params, spec, W1A4, img_hw=16)
    for a, b in zip(jax.tree.leaves(sp), jax.tree.leaves(plan.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_at_hint_policy():
    lp = P.LayerPlan(
        index=0, name="conv0", op="conv", role="mid", fp=False, kh=3, kw=3,
        stride=1, padding="SAME", cin=8, cout=8, in_h=16, in_w=16, out_h=16,
        out_w=16, k=72, a_bits=4, w_bits=1, engine="f32dot",
        engine_source="heuristic",
        engines=((1, "f32dot"), (4, "implicit"), (16, "int8")))
    assert lp.engine_at(1) == "f32dot"       # exact hint
    assert lp.engine_at(4) == "implicit"     # exact hint
    assert lp.engine_at(8) == "implicit"     # largest hint below
    assert lp.engine_at(64) == "int8"        # largest hint below
    assert lp.engine_at(0) == "f32dot"       # below every hint -> smallest


# ---------------------------------------------------------------------------
# Serialization: reload skips requantization and autotuning
# ---------------------------------------------------------------------------

def test_roundtrip_reload_is_bit_identical_and_never_requantizes(
        tmp_path, monkeypatch):
    spec, params, x = _small_setup()
    plan = P.compile_model(params, spec, W1A4, batch_hints=(1, 2),
                           img_hw=16, model="svhn_rt")
    expected = np.asarray(P.plan_forward(plan, x))
    path = P.save_plan(plan, str(tmp_path / "plan_rt"))
    assert path.endswith(".json") and (tmp_path / "plan_rt.npz").exists()

    plan2 = P.load_plan(str(tmp_path / "plan_rt"))
    assert plan2.fingerprint() == plan.fingerprint()
    # a reloaded plan must never touch the quantizers again
    import repro.core.quant as quant_mod

    def _forbidden(*a, **kw):
        raise AssertionError("requantization after plan reload")

    monkeypatch.setattr(quant_mod, "weight_levels", _forbidden)
    out = np.asarray(P.plan_forward(plan2, x))
    np.testing.assert_array_equal(out, expected)
    # level dtypes survive the npz round trip (int8 stays int8)
    for p, p2 in zip(plan.params, plan2.params):
        if "w_lv" in p:
            assert p2["w_lv"].dtype == p["w_lv"].dtype


def test_plan_version_gate(tmp_path):
    spec, params, _ = _small_setup()
    plan = P.compile_model(params, spec, W1A4, img_hw=16)
    base = str(tmp_path / "plan_v")
    P.save_plan(plan, base)
    import json

    with open(base + ".json") as f:
        meta = json.load(f)
    meta["version"] = -1
    with open(base + ".json", "w") as f:
        json.dump(meta, f)
    with pytest.raises(P.PlanError, match="version"):
        P.load_plan(base)


# ---------------------------------------------------------------------------
# Measured autotune
# ---------------------------------------------------------------------------

def test_autotune_compiles_measured_plan_and_caches(tmp_path):
    spec, params, x = _small_setup()
    plan = P.compile_model(params, spec, W1A4, batch_hints=(2,), img_hw=16,
                           autotune=True, model="svhn_at")
    assert all(lp.engine_source == "autotuned"
               for lp in plan.layers if not lp.fp)
    for lp in plan.layers:
        if not lp.fp:
            assert lp.engine in ("implicit", "f32dot", "int8")
    assert plan.autotune  # measurements recorded into the plan
    # every measured verdict has >= 1 timing, best == recorded engine
    for key, (eng, times) in plan.autotune.items():
        if times:
            assert eng == min(times, key=times.get)
    # autotuned plan output is bit-identical to the heuristic plan's
    ref_plan = P.compile_model(params, spec, W1A4, batch_hints=(2,),
                               img_hw=16)
    np.testing.assert_array_equal(np.asarray(P.plan_forward(plan, x)),
                                  np.asarray(P.plan_forward(ref_plan, x)))
    # reload restores the measurement cache: recompiling with autotune in a
    # "fresh process" (cleared caches) performs ZERO new measurements
    P.save_plan(plan, str(tmp_path / "plan_at"))
    ops.clear_plan_state()
    P.load_plan(str(tmp_path / "plan_at"))
    n_cached = len(ops._AUTOTUNE_CACHE)
    assert n_cached == len(plan.autotune) > 0
    plan3 = P.compile_model(params, spec, W1A4, batch_hints=(2,), img_hw=16,
                            autotune=True)
    assert len(ops._AUTOTUNE_CACHE) == n_cached  # no re-measurement
    assert {lp.name: lp.engine for lp in plan3.layers} == \
           {lp.name: lp.engine for lp in plan.layers}


# ---------------------------------------------------------------------------
# LM plans: dense verdict table, activation scoping, round trip
# ---------------------------------------------------------------------------

def _lm_setup():
    from repro.configs import SINGLE, all_configs
    from repro.models import transformer as T

    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(W1A8, engine="auto"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    return cfg, params, T, SINGLE


def test_lm_plan_bit_identical_and_scoped(tmp_path):
    cfg, params, T, SINGLE = _lm_setup()
    from repro.models.layers import prequantize_params

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    ref, _ = T.prefill(prequantize_params(params, cfg), cfg, SINGLE,
                       tokens=toks, qmode="serve")
    plan = P.compile_lm(params, cfg, batch_hints=(2,), prompt_len=8)
    assert plan.dense_table and all(v in P.SIGNED_ENGINES
                                    for v in plan.dense_table.values())
    with plan.activate():
        assert ops._PLAN_TABLE  # verdicts live while active
        out, _ = T.prefill(plan.params, cfg, SINGLE, tokens=toks,
                           qmode="serve")
    assert not ops._PLAN_TABLE  # and are removed after
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    # round trip through disk
    P.load_plan(P.save_plan(plan, str(tmp_path / "lmplan")))
    plan2 = P.load_plan(str(tmp_path / "lmplan"))
    assert plan2.dense_table == plan.dense_table
    with plan2.activate():
        out2, _ = T.prefill(plan2.params, cfg, SINGLE, tokens=toks,
                            qmode="serve")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out2))


def test_lm_runner_with_model_plan_matches_legacy():
    cfg, params, T, SINGLE = _lm_setup()
    from repro.launch.engine import LMRunner, ServeEngine
    from repro.models.layers import prequantize_params

    prompts = [np.random.RandomState(i).randint(0, cfg.vocab, size=(8,))
               .astype(np.int32) for i in range(3)]
    plan = P.compile_lm(params, cfg, batch_hints=(4,), prompt_len=8)
    res = ServeEngine(LMRunner(None, cfg, new_tokens=5, model_plan=plan),
                      max_batch=4).serve(prompts)
    legacy = ServeEngine(LMRunner(prequantize_params(params, cfg), cfg,
                                  new_tokens=5), max_batch=4).serve(prompts)
    for a, b in zip(res, legacy):
        np.testing.assert_array_equal(a.value, b.value)


# ---------------------------------------------------------------------------
# Review-fix regressions: reload guards, table restore, heuristic purity,
# interruptible resume
# ---------------------------------------------------------------------------

def test_check_plan_matches_rejects_mismatched_config(tmp_path):
    """A plan reloaded under a different quant config must refuse to serve
    (wrong bit widths would decode the stored levels into garbage)."""
    spec, params, _ = _small_setup()
    plan = P.compile_model(params, spec, W1A4, img_hw=16, model="m")
    P.save_plan(plan, str(tmp_path / "p"))
    loaded = P.load_plan(str(tmp_path / "p"))
    assert P.check_plan_matches(loaded, quant=W1A4, model="m") is loaded
    with pytest.raises(P.PlanError, match="w1a8"):
        P.check_plan_matches(loaded, quant=W1A8)
    with pytest.raises(P.PlanError, match="model"):
        P.check_plan_matches(loaded, model="other")
    # plan_exists normalizes a trailing .json (the CLI accepts both forms)
    assert P.plan_exists(str(tmp_path / "p"))
    assert P.plan_exists(str(tmp_path / "p.json"))
    assert not P.plan_exists(str(tmp_path / "missing"))


def test_activate_restores_installed_table():
    """activate() on top of a process-wide install() must restore the
    installed verdicts on exit, not uninstall them."""
    cfg, params, T, SINGLE = _lm_setup()
    plan = P.compile_lm(params, cfg, batch_hints=(2,), prompt_len=8)
    plan.install()
    try:
        before = dict(ops._PLAN_TABLE)
        with plan.activate():
            pass
        assert ops._PLAN_TABLE == before  # install() survives activate()
        # a disjoint plan's activation is also fully reversible
        other = {("dense", 7, 7, 8, 1, "cpu"): "int8"}
        ops.install_plan_table(other)
        with plan.activate():
            assert ops._PLAN_TABLE[("dense", 7, 7, 8, 1, "cpu")] == "int8"
        assert ops._PLAN_TABLE[("dense", 7, 7, 8, 1, "cpu")] == "int8"
    finally:
        ops.clear_plan_state()


def test_heuristic_compile_is_pure_under_foreign_state():
    """compile_model without autotune must ignore installed plan tables and
    cached autotune verdicts — 'heuristic' plans are deterministic."""
    spec, params, _ = _small_setup()
    ref = P.compile_model(params, spec, W1A4, img_hw=16)
    # poison every dispatch-state source select_engine consults
    for lp in ref.layers:
        if lp.fp:
            continue
        ops.install_plan_table(
            {ops.dense_plan_key(lp.k, lp.cout, lp.a_bits, lp.w_bits,
                                "cpu"): "int8"})
        for b, _ in lp.engines:
            key = ops.autotune_key(
                b * lp.out_h * lp.out_w, lp.k, lp.cout, lp.a_bits,
                lp.w_bits, "cpu",
                ops.ConvShape(lp.in_h, lp.in_w, lp.kh, lp.kw, lp.stride,
                              lp.padding, batch=b))
            ops._AUTOTUNE_CACHE[key] = ("int8", {})
    poisoned = P.compile_model(params, spec, W1A4, img_hw=16)
    assert {lp.name: dict(lp.engines) for lp in poisoned.layers} == \
           {lp.name: dict(lp.engines) for lp in ref.layers}
    assert poisoned.fingerprint() == ref.fingerprint()


def test_forward_progress_resume_window_is_interruptible():
    """The replan/restart window runs on the same failure-prone supply: a
    resume longer than the MTBF must compound (more failures, less
    progress) and still terminate via the budget hard-stop."""
    from repro.pim.intermittent import forward_progress

    kw = dict(n_frames=50, frame_time_us=100.0, mtbf_us=300.0,
              checkpoint_period_frames=5, seed=3)
    free = forward_progress(resume_us=0.0, **kw)
    costly = forward_progress(resume_us=600.0, **kw)  # 2x MTBF per replan
    assert costly["failures"] > free["failures"]  # resume itself fails
    assert costly["efficiency"] < free["efficiency"]
    assert costly["total_time_us"] <= kw["n_frames"] * 100.0 * 50 + 600.0


# ---------------------------------------------------------------------------
# Serving engine: program caches keyed on the plan
# ---------------------------------------------------------------------------

def test_serve_engine_program_cache_keyed_on_plan():
    spec, params, _ = _small_setup()
    from repro.launch.engine import CNNRunner, ServeEngine

    imgs = [np.random.RandomState(i).uniform(size=(16, 16, 3))
            .astype(np.float32) for i in range(3)]
    plan_a = P.compile_model(params, spec, W1A4, img_hw=16, model="a")
    plan_f = P.compile_model(params, spec,
                             dataclasses.replace(W1A4, engine="f32dot"),
                             img_hw=16, model="f")
    assert plan_a.fingerprint() != plan_f.fingerprint()
    res_a = ServeEngine(CNNRunner(None, spec, None, plan=plan_a),
                        max_batch=4).serve(imgs)
    res_f = ServeEngine(CNNRunner(None, spec, None, plan=plan_f),
                        max_batch=4).serve(imgs)
    from repro.core.prequant import prequantize_cnn_params
    sp = prequantize_cnn_params(params, spec, W1A4)
    legacy = ServeEngine(CNNRunner(sp, spec, W1A4), max_batch=4).serve(imgs)
    for a, f, l in zip(res_a, res_f, legacy):
        np.testing.assert_array_equal(a.value, l.value)
        np.testing.assert_array_equal(f.value, l.value)  # engines all exact
    # cache keys carry the fingerprint
    eng = ServeEngine(CNNRunner(None, spec, None, plan=plan_a), max_batch=4)
    eng.serve(imgs[:1])
    assert all(k[2] == plan_a.fingerprint() for k in eng._fns)
