"""PIM co-simulation vs the paper's published numbers.

Calibration fits ONE energy scale per design on the Table II ImageNet
column; everything asserted here beyond that column is a *prediction* of
the structural model (see repro/api/reports.py docstring — this suite
runs against the HardwareTarget-backed implementation;
``repro.pim.accelsim`` is its deprecation shim).
"""
import pytest

from repro.api import reports as A
from repro.pim.energy import DESIGNS
from repro.pim.mapper import accel_cost, model_work
from repro.models.cnn import alexnet_spec


def test_table2_imagenet_column_exact():
    t2 = A.table2()
    for d in ("reram", "imce", "proposed"):
        got = t2[d]["imagenet"]["energy_uj"]
        want = A.TABLE2[d]["imagenet"][0]
        assert abs(got - want) / want < 0.01, (d, got, want)


def test_table2_mnist_predictions():
    t2 = A.table2()
    # proposed & IMCE MNIST predicted within 35% of the paper
    for d in ("proposed", "imce"):
        got = t2[d]["mnist"]["energy_uj"]
        want = A.TABLE2[d]["mnist"][0]
        assert abs(got - want) / want < 0.35, (d, got, want)


def test_headline_speed_ratios():
    """IMCE 3x and ReRAM 9x speedups are structural (cycle counts)."""
    works = model_work(alexnet_spec(), 224, 1, 1)
    fps = {k: accel_cost(d, works)["fps"] for k, d in DESIGNS.items()}
    assert fps["proposed"] / fps["imce"] == pytest.approx(3.0, rel=0.15)
    assert fps["proposed"] / fps["reram"] == pytest.approx(9.0, rel=0.15)


def test_headline_energy_ratios():
    r_ims = A.simulate("imce", "imagenet")["energy_uj"] / \
        A.simulate("proposed", "imagenet")["energy_uj"]
    r_rer = A.simulate("reram", "imagenet")["energy_uj"] / \
        A.simulate("proposed", "imagenet")["energy_uj"]
    # Table II raw ratios: 1.66x IMCE, 4.8x ReRAM (paper's 2.1/5.4 headlines
    # average Fig. 9's config sweep; see EXPERIMENTS.md discussion)
    assert r_ims == pytest.approx(785.25 / 471.8, rel=0.05)
    assert r_rer == pytest.approx(2275.34 / 471.8, rel=0.05)


def test_asic_claims_area_normalized():
    p = A.simulate("proposed", "imagenet")
    a = A.simulate("asic", "imagenet")
    e_ratio = (a["energy_uj"] * a["area_mm2"]) / (p["energy_uj"] * p["area_mm2"])
    s_ratio = p["fps_per_mm2"] / a["fps_per_mm2"]
    assert e_ratio == pytest.approx(9.7, rel=0.25)
    assert s_ratio == pytest.approx(13.5, rel=0.25)


def test_compressor_vs_serial_counter_is_the_win():
    """Ablation: give the proposed design IMCE's serial counter and its
    advantage must collapse — the paper's central §II-B1 claim."""
    import dataclasses
    works = model_work(alexnet_spec(), 224, 1, 1)
    prop = DESIGNS["proposed"]
    crippled = dataclasses.replace(prop, c_cmp=DESIGNS["imce"].c_cmp,
                                   e_cmp_row=DESIGNS["imce"].e_cmp_row)
    fast = accel_cost(prop, works)
    slow = accel_cost(crippled, works)
    assert fast["fps"] / slow["fps"] == pytest.approx(3.0, rel=0.1)
    assert slow["energy_uj"] / fast["energy_uj"] > 1.5


def test_bitwidth_scaling():
    """Work scales with m*n bit-plane pairs (Eq. 1): W1A4 costs ~4x W1A1
    in the quantized layers."""
    e11 = A.simulate("proposed", "imagenet", 1, 1)
    e41 = A.simulate("proposed", "imagenet", 4, 1)
    # AlexNet's fp (8x8-bit) first conv dominates row-ops at 1:1, damping
    # the 4x mid-layer scaling — structurally expected, also in the paper.
    ratio = e41["energy_uj"] / e11["energy_uj"]
    assert 1.25 < ratio < 4.0


def test_storage_model_fig8():
    from repro.core.quant import model_storage_bits
    from repro.models.cnn import count_acts, count_params, svhn_cnn_spec, alexnet_spec
    spec = svhn_cnn_spec(20)
    p, a = count_params(spec), count_acts(spec, 40)
    s32 = model_storage_bits(p, a, 32, 32)
    s14 = model_storage_bits(p, a, 1, 4)
    assert 6 < s32 / s14 < 16  # paper: ~11.7x reduction for 1:4
    # AlexNet 1:1 vs fp32 (paper Fig. 8b says ~6x for its 40MB deployment
    # figure, which keeps first/last layers fp and counts buffers; the pure
    # weight+activation-bit ratio ceiling is 32x — we check both forms)
    ap, aa = count_params(alexnet_spec()), count_acts(alexnet_spec(), 224)
    pure = model_storage_bits(ap, aa, 32, 32) / model_storage_bits(ap, aa, 1, 1)
    assert 16 < pure <= 32.5
    # deployment form: first+last layers fp32 (paper's quantization policy)
    spec = alexnet_spec()
    fl = sum(s.k * s.k * s.cin * s.cout for s in spec if s.role in ("first", "last"))
    deploy_bits = fl * 32 + (ap - fl) * 1 + aa * 8
    deploy_ratio = (ap + aa) * 32 / deploy_bits
    assert 4 < deploy_ratio < 16  # paper's ~6x regime


def test_intermittency_forward_progress():
    """Checkpointing partial sums must dominate restart-from-scratch under
    frequent power failures (the paper's battery-less IoT scenario)."""
    from repro.pim.intermittent import forward_progress
    # high failure rate: 1 failure per 0.2 frame-times
    with_nv = forward_progress(n_frames=200, frame_time_us=100.0,
                               mtbf_us=20.0, checkpoint_period_frames=1)
    without = forward_progress(n_frames=200, frame_time_us=100.0,
                               mtbf_us=20.0, checkpoint_period_frames=0)
    assert with_nv["completed_frames"] > without["completed_frames"]
    assert with_nv["efficiency"] > 2 * without["efficiency"]
