"""Quantized flash attention (kernels/attn_flash) + attention dispatch.

Pins five contracts:

* **Exactness vs the quantization** — both realizations (Pallas
  interpret-mode and the XLA engine) are *bit-faithful* to the reference
  "quantize q/k, full softmax attention on the dequantized logits"
  computation across bit widths, masking variants, and GQA: the only
  approximation the flash engine introduces is the documented affine
  quantization of q/k, never the tiling.
* **Closeness to unquantized attention** — within a bits-dependent
  empirical bound (the worst case is :func:`flash_error_bound`).
* **Chunked-skip bit-identity** — skipping fully-masked kv chunks leaves
  ``attn_chunked`` bit-identical to the compute-and-zero dataflow.
* **Chunk-plan padding** — awkward sequence lengths (S=1021) keep a
  bounded chunk count instead of degenerating to a 1021-step scan.
* **Plan carriage** — ``compile_lm`` resolves the attention engine once,
  serializes it, and a reloaded plan dispatches it by table lookup.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.attn_flash import (attn_flash_pallas, attn_flash_xla,
                                      attn_quant_scale, flash_error_bound,
                                      flash_levels_exact, _levels)
from repro.models.layers import (_chunk_plan, _mask, attn_banded,
                                 attn_chunked, attn_full, expand_kv)


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    ops.clear_plan_state()
    yield
    ops.clear_plan_state()


def _qkv(S, heads=3, hd=16, batch=2, kv_heads=None, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (batch, S, heads, hd), jnp.float32)
    k = jax.random.normal(ks[1], (batch, S, kv_heads or heads, hd),
                          jnp.float32)
    v = jax.random.normal(ks[2], (batch, S, kv_heads or heads, hd),
                          jnp.float32)
    return q, k, v


def _ref_quant_full(q, k, v, *, causal, window, q_bits, k_bits):
    """Quantize q/k exactly as the kernel does, then plain full attention
    on the dequantized logits — the kernel's ground truth."""
    hd = q.shape[-1]
    s_q, z_q = attn_quant_scale(q, q_bits)
    s_k, z_k = attn_quant_scale(k, k_bits)
    qd = (_levels(q, s_q, q_bits) - z_q) * s_q
    kd = (_levels(k, s_k, k_bits) - z_k) * s_k
    pos = jnp.arange(q.shape[1])
    return attn_full(qd, kd, v, causal=causal, window=window,
                     q_pos=pos, kv_pos=pos)


CASES = [(8, 8), (4, 4), (8, 4)]
MASKS = [(True, None), (False, None), (True, 24)]


@pytest.mark.parametrize("q_bits,k_bits", CASES)
@pytest.mark.parametrize("causal,window", MASKS)
def test_flash_faithful_to_quantized_reference(q_bits, k_bits, causal,
                                               window):
    """Tiling is exact: both realizations match the quantize-then-full
    reference to f32 summation-order noise, including non-multiple S
    (padding) and boundary blocks."""
    q, k, v = _qkv(100)
    ref = _ref_quant_full(q, k, v, causal=causal, window=window,
                          q_bits=q_bits, k_bits=k_bits)
    for fn in (attn_flash_xla, attn_flash_pallas):
        out = fn(q, k, v, causal=causal, window=window, q_bits=q_bits,
                 k_bits=k_bits, block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=0)


@pytest.mark.parametrize("q_bits,k_bits", CASES)
def test_flash_gqa_expanded_kv(q_bits, k_bits):
    """GQA serve shape: kv expanded onto TP-padded query heads before the
    kernel (6 padded q heads over 2 kv heads, 4 real)."""
    q, k, v = _qkv(64, heads=6, kv_heads=2, seed=3)
    ke, ve = expand_kv(k, v, 4, 6)
    ref = _ref_quant_full(q, ke, ve, causal=True, window=None,
                          q_bits=q_bits, k_bits=k_bits)
    out = attn_flash_xla(q, ke, ve, causal=True, window=None,
                         q_bits=q_bits, k_bits=k_bits, block_q=32,
                         block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=0)


@pytest.mark.parametrize("q_bits,k_bits,tol", [(8, 8, 0.12), (4, 4, 0.9),
                                               (8, 4, 0.6)])
def test_flash_close_to_unquantized(q_bits, k_bits, tol):
    """Documented exactness bound: the only error vs full-precision
    attention is the q/k quantization (worst case flash_error_bound on
    the logits; the output deviation is far smaller in practice)."""
    q, k, v = _qkv(128, seed=5)
    pos = jnp.arange(128)
    ref = attn_full(q, k, v, causal=True, window=None, q_pos=pos,
                    kv_pos=pos)
    out = attn_flash_xla(q, k, v, causal=True, window=None, q_bits=q_bits,
                         k_bits=k_bits, block_q=64, block_kv=64)
    assert flash_error_bound(q, k, q_bits, k_bits) > 0
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_flash_levels_exact_bound():
    assert flash_levels_exact(256, 8, 8)      # every supported head dim
    assert not flash_levels_exact(1024, 8, 8)
    with pytest.raises(ValueError, match="inexact"):
        q, k, v = _qkv(32, hd=1024, heads=1, batch=1)
        attn_flash_xla(q, k, v)


# ---------------------------------------------------------------------------
# attn_chunked: skip + chunk-plan satellites
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
def test_chunked_skip_bit_identity(causal, window):
    """Skipping a fully-masked kv chunk leaves the carry untouched, which
    is bit-identical to computing it (its mask zeroes every weight)."""
    q, k, v = _qkv(256, seed=7)
    pos = jnp.arange(256)
    kw = dict(causal=causal, window=window, q_pos=pos, kv_pos=pos,
              q_chunk=64, kv_chunk=64)
    skip = attn_chunked(q, k, v, skip_masked=True, **kw)
    dense = attn_chunked(q, k, v, skip_masked=False, **kw)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(dense))
    ref = attn_full(q, k, v, causal=causal, window=window, q_pos=pos,
                    kv_pos=pos)
    np.testing.assert_allclose(np.asarray(skip), np.asarray(ref),
                               atol=2e-5, rtol=0)


def test_chunk_plan_stays_bounded():
    """S=1021 used to degenerate to chunk=1 (a 1021-step scan); the padded
    plan keeps the chunk at the target."""
    assert _chunk_plan(1021, 256) == (256, 1024)
    assert _chunk_plan(1021, 1024) == (1021, 1021)
    assert _chunk_plan(32768 + 256, 1024) == (1024, 33792)
    q, k, v = _qkv(1021, seed=9)
    pos = jnp.arange(1021)
    out = attn_chunked(q, k, v, causal=True, window=None, q_pos=pos,
                       kv_pos=pos, q_chunk=256, kv_chunk=256)
    ref = attn_full(q, k, v, causal=True, window=None, q_pos=pos,
                    kv_pos=pos)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=0)


# ---------------------------------------------------------------------------
# Attention edge cases the new kernel must honor (satellite coverage)
# ---------------------------------------------------------------------------

def test_banded_ragged_and_oversized_window():
    q, k, v = _qkv(100, seed=11)
    pos = jnp.arange(100)
    # Sq not a multiple of W
    for W in (32, 256):  # 100 % 32 != 0; window 256 > S
        ref = attn_full(q, k, v, causal=True, window=W, q_pos=pos,
                        kv_pos=pos)
        out = attn_banded(q, k, v, window=W, q_pos=pos, kv_pos=pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=0)


def test_mask_negative_kv_positions():
    iq = jnp.asarray([0, 1, 5])
    jk = jnp.asarray([-1, 0, 3, -1])
    m = np.asarray(_mask(iq, jk, True, None))
    assert not m[:, 0].any() and not m[:, 3].any()  # invalid slots
    assert m[2, 2] and not m[1, 2]                  # causal on the rest
    mw = np.asarray(_mask(iq, jk, True, 2))
    assert mw[1, 1] and not mw[2, 1]                # window lower bound


def test_expand_kv_tp_padded_heads():
    q, k, v = _qkv(8, heads=2, kv_heads=2, seed=13)
    ke, ve = expand_kv(k, v, 4, 6)  # 4 real q heads padded to 6, 2 kv
    assert ke.shape[2] == 6
    # real heads map in groups of g=2; padded heads reuse the last kv head
    for j, src in enumerate([0, 0, 1, 1, 1, 1]):
        np.testing.assert_array_equal(np.asarray(ke[:, :, j]),
                                      np.asarray(k[:, :, src]))


# ---------------------------------------------------------------------------
# Plan carriage: compile_lm resolves, serializes, reload dispatches
# ---------------------------------------------------------------------------

def _lm_cfg():
    from repro.configs import all_configs
    from repro.core.quant import W1A8

    return dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(W1A8, engine="auto"))


def test_lm_plan_carries_attention_engine(tmp_path):
    from repro.configs import SINGLE
    from repro.core import plan as P
    from repro.models import transformer as T

    cfg = _lm_cfg()
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    plan = P.compile_lm(params, cfg, backend="cpu", batch_hints=(1,),
                        prompt_len=8192)
    rows = [lp for lp in plan.layers if lp.op == "attn"]
    assert rows and all(lp.attn_engine == lp.engine for lp in rows)
    # quantized W1A8 serve at S=8192 resolves the flash engine
    assert plan.attn_table and set(plan.attn_table.values()) == {"flash"}
    # round trip: the verdict survives serialization
    plan2 = P.load_plan(P.save_plan(plan, str(tmp_path / "attnplan")))
    assert plan2.attn_table == plan.attn_table
    assert [lp.attn_engine for lp in plan2.layers] == \
           [lp.attn_engine for lp in plan.layers]
    # an active plan turns dispatch into a table lookup (and overrides the
    # heuristic: the same geometry resolves "chunked" once we pin it)
    key = next(iter(plan.attn_table))
    attn = ops.AttnShape(seq_q=key[1], seq_kv=key[1], heads=key[2],
                         head_dim=key[3], causal=key[4],
                         window=key[5] or None, quantized=key[6])
    with plan2.activate():
        assert ops.select_attn_engine(attn, "cpu") == "flash"
        pinned = dataclasses.replace(plan2)
        pinned.attn_table = {key: "chunked"}
        with pinned.activate():
            assert ops.select_attn_engine(attn, "cpu") == "chunked"
        assert ops.select_attn_engine(attn, "cpu") == "flash"
    assert ops.select_attn_engine(attn, "cpu") == "flash"  # heuristic


def test_attention_fwd_flash_dispatch():
    """Layer-level integration: attention_fwd with the flash engine stays
    within quantization error of the full engine on the serve path."""
    from repro.configs import SINGLE
    from repro.models.layers import attention_fwd, init_attention

    cfg = _lm_cfg()
    p, _ = init_attention(jax.random.PRNGKey(0), cfg, SINGLE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    full, _ = attention_fwd(p, x, cfg, SINGLE, mode="train",
                            engine="full", qmode="serve")
    flash, _ = attention_fwd(p, x, cfg, SINGLE, mode="train",
                             engine="flash", qmode="serve")
    chunk, _ = attention_fwd(p, x, cfg, SINGLE, mode="train",
                             engine="chunked", qmode="serve")
    assert float(jnp.max(jnp.abs(flash - full))) < 0.35
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                               atol=2e-4, rtol=0)


def test_resolve_attn_engine_thresholds():
    from repro.models.layers import resolve_attn_engine

    cfg = _lm_cfg()
    kw = dict(heads=2, causal=True, window=None)
    r = resolve_attn_engine
    assert r(cfg, seq_q=64, seq_kv=64, **kw) == "full"
    assert r(cfg, seq_q=8192, seq_kv=8192, **kw) == "chunked"
    assert r(cfg, seq_q=8192, seq_kv=8192, qmode="serve", **kw) == "flash"
    # train numerics never change: flash requires the quantized serve path
    assert r(cfg, seq_q=8192, seq_kv=8192, qmode="train", **kw) == "chunked"
    fp = dataclasses.replace(cfg, quant=dataclasses.replace(
        cfg.quant, engine="fp"))
    assert r(fp, seq_q=8192, seq_kv=8192, qmode="serve", **kw) == "chunked"
    full = dataclasses.replace(cfg, full_attn_analysis=True)
    assert r(full, seq_q=8192, seq_kv=8192, qmode="serve", **kw) == "full"
