"""Request-level serving engine (launch/engine.py, DESIGN.md §7).

Headline contract: batching is invisible — a request's result is
bit-identical whether it ran alone (sequential per-request dispatch), in a
full bucket, in a ragged padded bucket, or sharded across devices, for
every conv engine the dispatcher can pick.  Plus the widen_cache
regression (structural sequence-axis identification) that the engine's LM
path depends on.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SINGLE, all_configs
from repro.core.quant import PAPER_CONFIGS, W1A4
from repro.launch.engine import (BucketBatcher, CNNRunner, LMRunner, QueueFull,
                                 Request, ServeEngine, run_offered_load)
from repro.models import transformer as T
from repro.core.prequant import prequantize_cnn_params
from repro.models.cnn import cnn_forward, init_cnn, svhn_cnn_spec


# ---------------------------------------------------------------------------
# BucketBatcher: pure queue/bucketing logic (no jax)
# ---------------------------------------------------------------------------

def _req(rid, payload="p", t=0.0):
    return Request(rid, payload, t)


def test_batcher_flushes_full_bucket():
    b = BucketBatcher(max_batch=3, flush_deadline_s=1.0)
    assert b.add(_req(0), "k", now=0.0) is None
    assert b.add(_req(1), "k", now=0.0) is None
    full = b.add(_req(2), "k", now=0.0)
    assert full is not None and [r.rid for r in full.requests] == [0, 1, 2]
    assert b.pending() == 0


def test_batcher_separates_shape_keys():
    b = BucketBatcher(max_batch=2, flush_deadline_s=1.0)
    assert b.add(_req(0), ("cnn", 40), now=0.0) is None
    assert b.add(_req(1), ("cnn", 32), now=0.0) is None
    full = b.add(_req(2), ("cnn", 40), now=0.0)
    assert full is not None and full.key == ("cnn", 40)
    assert b.pending() == 1  # the 32-key request still queued


def test_batcher_deadline_flush():
    b = BucketBatcher(max_batch=8, flush_deadline_s=0.010)
    b.add(_req(0), "k", now=0.0)
    assert b.take_expired(now=0.005) == []       # young bucket stays
    exp = b.take_expired(now=0.011)              # oldest waited past deadline
    assert len(exp) == 1 and exp[0].requests[0].rid == 0
    assert b.pending() == 0


def test_batcher_deadline_exact_boundary():
    """The deadline comparison is inclusive: a bucket whose oldest request
    has waited EXACTLY flush_deadline_s flushes now, not one poll later
    (pollers quantize time; an exclusive compare would add a full poll
    interval of tail latency)."""
    b = BucketBatcher(max_batch=8, flush_deadline_s=0.010)
    b.add(_req(0), "k", now=0.0)
    exp = b.take_expired(now=0.010)
    assert len(exp) == 1 and exp[0].requests[0].rid == 0
    assert b.pending() == 0


def test_batcher_take_all_drains_partials():
    b = BucketBatcher(max_batch=8, flush_deadline_s=1.0)
    b.add(_req(0), "a", now=0.0)
    b.add(_req(1), "b", now=0.0)
    assert sorted(bk.key for bk in b.take_all()) == ["a", "b"]
    assert b.pending() == 0


# ---------------------------------------------------------------------------
# CNN path: bit-identity across engines, bucket shapes, ragged tails
# ---------------------------------------------------------------------------

SPEC = svhn_cnn_spec(8)
_params, _ = init_cnn(jax.random.PRNGKey(0), SPEC)
SERVE_PARAMS = prequantize_cnn_params(_params, SPEC, W1A4)
IMGS = [np.random.RandomState(i).uniform(size=(16, 16, 3)).astype(np.float32)
        for i in range(6)]


def _cnn_engine(quant, max_batch):
    return ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, quant),
                       max_batch=max_batch)


@pytest.mark.parametrize("engine", ["auto", "implicit", "fused"])
def test_cnn_batched_bit_identical_to_sequential(engine):
    """Batched engine output == sequential per-request loop, per conv
    engine: auto dispatch, forced implicit (patch-free), forced fused
    (Pallas interpret)."""
    quant = dataclasses.replace(W1A4, engine=engine)
    n = 3 if engine == "fused" else len(IMGS)  # interpret mode is slow
    imgs = IMGS[:n]
    seq = _cnn_engine(quant, 1).serve(imgs)          # per-request dispatches
    bat = _cnn_engine(quant, 4).serve(imgs)          # incl. ragged tail
    for s, b in zip(seq, bat):
        np.testing.assert_array_equal(s.value, b.value)
    # and against the raw jitted batched forward, no engine machinery at all
    ref = np.asarray(jax.jit(
        lambda x: cnn_forward(SERVE_PARAMS, x, SPEC, quant, "serve"))(
            jnp.asarray(np.stack(imgs))))
    for i, b in enumerate(bat):
        np.testing.assert_array_equal(b.value, ref[i])


def test_cnn_ragged_buckets_and_padding_metadata():
    """Every split of 5 requests pads its final bucket; results must not
    see the padding (padded rows are copies of row 0, sliced off)."""
    ref = [r.value for r in _cnn_engine(W1A4, 1).serve(IMGS[:5])]
    for max_batch in (2, 3, 4, 8):
        res = _cnn_engine(W1A4, max_batch).serve(IMGS[:5])
        for i, r in enumerate(res):
            np.testing.assert_array_equal(r.value, ref[i])
            assert r.batch <= max_batch
            # pow2 growth capped at bucket capacity: a FULL bucket never
            # pads above max_batch (no dead rows on the steady-state path)
            assert r.batch <= r.padded <= max_batch
    # 5 reqs at max_batch=4 -> buckets of 4 and 1: the tail padded to 1
    res = _cnn_engine(W1A4, 4).serve(IMGS[:5])
    assert res[-1].batch == 1 and res[-1].padded == 1
    # non-pow2 capacity: full bucket of 3 dispatches at exactly 3
    res = _cnn_engine(W1A4, 3).serve(IMGS[:3])
    assert all(r.batch == 3 and r.padded == 3 for r in res)


def test_cnn_mixed_shape_buckets():
    """Different image shapes never share a dispatch; results match the
    per-shape references."""
    small = [np.random.RandomState(100 + i).uniform(size=(12, 12, 3))
             .astype(np.float32) for i in range(2)]
    eng = _cnn_engine(W1A4, 4)
    res = eng.serve([IMGS[0], small[0], IMGS[1], small[1]])
    assert eng.stats["dispatches"] == 2  # one per shape key
    ref16 = [r.value for r in _cnn_engine(W1A4, 1).serve(IMGS[:2])]
    ref12 = [r.value for r in _cnn_engine(W1A4, 1).serve(small)]
    np.testing.assert_array_equal(res[0].value, ref16[0])
    np.testing.assert_array_equal(res[2].value, ref16[1])
    np.testing.assert_array_equal(res[1].value, ref12[0])
    np.testing.assert_array_equal(res[3].value, ref12[1])


def test_engine_single_device_fallback_and_stats():
    """On one device the engine must take the plain-jit path (mesh None)."""
    from repro.launch.mesh import make_serve_mesh

    if len(jax.devices()) == 1:
        assert make_serve_mesh() is None
    eng = _cnn_engine(W1A4, 4)
    assert eng.mesh is None or eng._n_data == len(jax.devices())
    res = eng.serve(IMGS[:4])
    assert eng.stats == dict(dispatches=1, requests=4, padded_rows=0)
    assert all(r.latency_s >= 0 for r in res)


def test_queue_backpressure():
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=4,
                      max_pending=2)
    eng.submit(IMGS[0])
    eng.submit(IMGS[1])
    with pytest.raises(QueueFull):
        eng.submit(IMGS[2])
    assert len(eng.drain()) == 2  # queued work is never lost to QueueFull
    # max_pending counts REQUESTS even once buckets close: max_batch=1
    # turns every submit into a ready bucket, and the second must still
    # trip the bound (not slip through as "one bucket")
    eng2 = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=1,
                       max_pending=1)
    eng2.submit(IMGS[0])
    with pytest.raises(QueueFull):
        eng2.submit(IMGS[1])


def test_queue_drain_then_resubmit_roundtrip():
    """After QueueFull, drain() relieves the pressure and the SAME payloads
    resubmit cleanly; every rid maps to the result of its own payload
    across the drain boundary (rids never recycle)."""
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=2,
                      max_pending=2)
    ref = [r.value for r in _cnn_engine(W1A4, 1).serve(IMGS[:4])]
    rid_to_img = {eng.submit(IMGS[0]): 0, eng.submit(IMGS[1]): 1}
    with pytest.raises(QueueFull):
        eng.submit(IMGS[2])
    first = eng.drain()
    assert sorted(r.rid for r in first) == sorted(rid_to_img)
    rid_to_img.update({eng.submit(IMGS[2]): 2, eng.submit(IMGS[3]): 3})
    second = eng.drain()
    assert {r.rid for r in second}.isdisjoint({r.rid for r in first})
    for r in first + second:
        np.testing.assert_array_equal(r.value, ref[rid_to_img[r.rid]])


def test_submit_retry_backoff_until_admitted():
    """submit_retry turns QueueFull into bounded jittered backoff: with the
    queue full, retries pump (dispatching relieves the pressure) and the
    request is admitted — no sleep escapes into the test (injected fake)."""
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=2,
                      max_pending=2)
    eng.submit(IMGS[0])
    eng.submit(IMGS[1])     # full bucket -> _ready; queue at max_pending
    slept = []
    rid = eng.submit_retry(IMGS[2], attempts=3, base_s=0.001, max_s=0.004,
                           sleep=slept.append)
    assert rid == 2
    # first attempt hit QueueFull, pump() dispatched the ready bucket,
    # second attempt was admitted after exactly one jittered backoff
    assert len(slept) == 1 and 0.0005 <= slept[0] < 0.0015
    assert len(eng.drain()) == 3


def test_submit_retry_exhausts_and_reraises():
    """When nothing can relieve the pressure (all load in one open partial
    bucket below max_batch), submit_retry re-raises QueueFull after its
    attempt budget — overload surfaces, it doesn't block forever."""
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=8,
                      max_pending=1, flush_deadline_s=1e9)
    eng.submit(IMGS[0])     # partial bucket: pump() can't flush it
    slept = []
    with pytest.raises(QueueFull):
        eng.submit_retry(IMGS[1], attempts=4, base_s=0.001, max_s=0.002,
                         sleep=slept.append)
    # attempts-1 sleeps (no sleep after the final failure), delays
    # exponential then capped, each jittered in [0.5, 1.5) of nominal
    assert len(slept) == 3
    for d, nominal in zip(slept, (0.001, 0.002, 0.002)):
        assert 0.5 * nominal <= d < 1.5 * nominal
    assert len(eng.drain()) == 1  # the queued request was never lost


def test_serve_closed_loop_survives_tiny_max_pending():
    """serve() must complete (flushing partial buckets in place) even when
    max_pending is smaller than a bucket — closed loop never sheds."""
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=4,
                      max_pending=2)
    res = eng.serve(IMGS[:5])
    assert len(res) == 5
    ref = [r.value for r in _cnn_engine(W1A4, 1).serve(IMGS[:5])]
    for r, v in zip(res, ref):
        np.testing.assert_array_equal(r.value, v)


def test_flush_deadline_dispatches_partial_bucket():
    t = [0.0]
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=8,
                      flush_deadline_s=0.010, clock=lambda: t[0])
    eng.submit(IMGS[0])
    eng.pump()
    assert not eng._results            # deadline not reached: still queued
    t[0] = 0.011
    eng.pump()                         # expired -> dispatched alone
    assert 0 in eng._results and eng._results[0].batch == 1


def test_submit_retry_jitter_is_seeded_and_injectable():
    """Backoff jitter comes from an engine-owned seeded RNG: two engines
    built with the same retry_rng seed sleep the identical sequence, a
    different seed diverges, and a RandomState instance passes through —
    retry timing is reproducible, never ambient-global."""
    def delays(retry_rng):
        eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=8,
                          max_pending=1, flush_deadline_s=1e9,
                          retry_rng=retry_rng)
        eng.submit(IMGS[0])
        slept = []
        with pytest.raises(QueueFull):
            eng.submit_retry(IMGS[1], attempts=4, base_s=0.001, max_s=0.008,
                             sleep=slept.append)
        return slept

    assert delays(7) == delays(7)
    assert delays(7) != delays(8)
    assert delays(np.random.RandomState(7)) == delays(7)


def test_offered_load_closed_loop_counts():
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=4)
    row = run_offered_load(eng, IMGS, rate_rps=None)
    assert row["n_requests"] == len(IMGS)
    assert row["achieved_rps"] > 0 and row["p99_ms"] >= row["p50_ms"]


def test_offered_load_splits_queue_wait_from_service():
    """run_offered_load decomposes latency: queue-wait (submit -> dispatch)
    and service (dispatch -> done) are reported separately and their p50s
    compose to about the end-to-end p50 for a serial engine."""
    eng = ServeEngine(CNNRunner(SERVE_PARAMS, SPEC, W1A4), max_batch=2)
    row = run_offered_load(eng, IMGS, rate_rps=None)
    for k in ("queue_p50_ms", "queue_p99_ms", "service_p50_ms",
              "service_p99_ms"):
        assert k in row and np.isfinite(row[k]) and row[k] >= 0
    assert row["queue_p99_ms"] >= row["queue_p50_ms"]
    assert row["service_p99_ms"] >= row["service_p50_ms"]
    # components never exceed the end-to-end envelope
    assert row["queue_p50_ms"] <= row["p99_ms"]
    assert row["service_p50_ms"] <= row["p99_ms"]


# ---------------------------------------------------------------------------
# LM path: bucketing by prompt length, batched == sequential tokens
# ---------------------------------------------------------------------------

def _lm_setup():
    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    return cfg, params


def test_lm_engine_exact_vs_direct_forward_same_composition():
    """The engine layer adds NOTHING numerically: collate/pad/stage/split
    around a bucket reproduces a direct jitted call on the same padded
    batch bit-for-bit (full bucket of 4 and ragged padded tail of 1).

    Exact per-request-vs-batched token equality is a model-numerics
    property, not an engine property: on CPU, XLA's reduction strategy
    varies with the batch dimension and activation quantization amplifies
    those ulps into level flips (same reason bench_serve reports rather
    than asserts loop-vs-scan token match).  The integer-engine CNN path
    above carries the strict batched==sequential bit-identity contract.
    """
    cfg, params = _lm_setup()
    prompts = [np.random.RandomState(i).randint(0, cfg.vocab, size=(8,))
               .astype(np.int32) for i in range(5)]
    runner = LMRunner(params, cfg, new_tokens=6)
    eng = ServeEngine(runner, max_batch=4)
    res = eng.serve(prompts)  # buckets: [0..3] and padded [4]
    assert eng.stats["dispatches"] == 2
    fwd = jax.jit(runner.make_forward(runner.shape_key(prompts[0])))
    direct4 = np.asarray(fwd(params, jnp.asarray(np.stack(prompts[:4]))))
    direct1 = np.asarray(fwd(params, jnp.asarray(prompts[4])[None]))
    for i in range(4):
        np.testing.assert_array_equal(res[i].value, direct4[i])
    np.testing.assert_array_equal(res[4].value, direct1[0])
    assert all(r.value.shape == (6,) for r in res)
    # tokens come from the REAL vocab, never the padded unembed tail
    assert all(int(r.value.max()) < cfg.vocab for r in res)
    # engine dispatch is deterministic: a fresh engine reproduces exactly
    res2 = ServeEngine(LMRunner(params, cfg, new_tokens=6),
                       max_batch=4).serve(prompts)
    for a, b in zip(res, res2):
        np.testing.assert_array_equal(a.value, b.value)


def test_lm_engine_buckets_by_prompt_len():
    cfg, params = _lm_setup()
    p8 = [np.random.RandomState(i).randint(0, cfg.vocab, size=(8,))
          .astype(np.int32) for i in range(2)]
    p12 = [np.random.RandomState(9).randint(0, cfg.vocab, size=(12,))
           .astype(np.int32)]
    runner = LMRunner(params, cfg, new_tokens=4)
    eng = ServeEngine(runner, max_batch=4)
    res = eng.serve([p8[0], p12[0], p8[1]])
    assert eng.stats["dispatches"] == 2  # prompt lengths never co-batch
    # each bucket reproduces the direct forward at its own composition
    fwd8 = jax.jit(runner.make_forward(runner.shape_key(p8[0])))
    fwd12 = jax.jit(runner.make_forward(runner.shape_key(p12[0])))
    d8 = np.asarray(fwd8(params, jnp.asarray(np.stack(p8))))
    d12 = np.asarray(fwd12(params, jnp.asarray(p12[0])[None]))
    np.testing.assert_array_equal(res[0].value, d8[0])
    np.testing.assert_array_equal(res[2].value, d8[1])
    np.testing.assert_array_equal(res[1].value, d12[0])


# ---------------------------------------------------------------------------
# widen_cache regression: structural sequence axis, not size coincidence
# ---------------------------------------------------------------------------

def test_widen_cache_ignores_size_coincidences():
    """State tensors whose axis 2 merely EQUALS the prompt length (rec.h
    lru width, rec.conv taps, head_dim) must pass through untouched; only
    attention k/v/pos widen.  Pre-fix, widen_cache padded rec.h (and any
    other ndim>=3, shape[2]==prompt_len tensor), corrupting decode."""
    from repro.launch.serve import widen_cache

    S_p = 16
    cfg = all_configs()["recurrentgemma-9b"].smoke(
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128, vocab=64,
        head_dim=S_p,       # head_dim == prompt_len (the issue's coincidence)
        lru_width=S_p,      # rec.h axis 2 == prompt_len -> pre-fix corruption
        window=8)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S_p), 0, cfg.vocab)
    logits, cache = T.prefill(params, cfg, SINGLE, tokens=toks)
    assert cache["rec"]["h"].shape[2] == S_p  # the trap is armed
    with pytest.warns(DeprecationWarning, match="grow_cache"):
        w = widen_cache(cache, S_p, S_p + 8)
    # recurrent state: untouched
    assert w["rec"]["h"].shape == cache["rec"]["h"].shape
    assert w["rec"]["conv"].shape == cache["rec"]["conv"].shape
    # attention cache: widened along the slot axis, new pos slots empty
    assert w["attn_local"]["k"].shape[2] == S_p + 8
    assert w["attn_local"]["v"].shape[2] == S_p + 8
    assert bool((np.asarray(w["attn_local"]["pos"])[:, :, S_p:] == -1).all())
    # and the widened cache actually decodes
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    lg, _ = T.decode_step(params, w, tok, jnp.asarray(S_p, jnp.int32), cfg,
                          SINGLE)
    assert lg.shape[0] == 2 and bool(jnp.isfinite(lg).all())


def test_widen_cache_dense_head_dim_collision():
    """Dense attn cache with head_dim == kv_heads == prompt_len: every
    shape-coincidence at once; k/v widen exactly once, at axis 2."""
    from repro.launch.serve import widen_cache

    S_p = 4
    cfg = all_configs()["smollm-360m"].smoke(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=S_p, d_ff=128,
        vocab=64, head_dim=S_p)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S_p), 0, cfg.vocab)
    _, cache = T.prefill(params, cfg, SINGLE, tokens=toks)
    assert cache["attn"]["k"].shape[2:] == (S_p, S_p, S_p)
    with pytest.warns(DeprecationWarning, match="grow_cache"):
        w = widen_cache(cache, S_p, S_p + 3)
    assert w["attn"]["k"].shape == cache["attn"]["k"].shape[:2] + (S_p + 3,
                                                                   S_p, S_p)


# ---------------------------------------------------------------------------
# multi-device: shard_map data parallelism (8 forced host devices)
# ---------------------------------------------------------------------------

MD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core.quant import W1A4
from repro.distributed.sharding import batch_sharding, data_parallel
from repro.launch.engine import CNNRunner, ServeEngine
from repro.launch.mesh import make_serve_mesh
from repro.core.prequant import prequantize_cnn_params
from repro.models.cnn import cnn_forward, init_cnn, svhn_cnn_spec

spec = svhn_cnn_spec(8)
params, _ = init_cnn(jax.random.PRNGKey(0), spec)
sp = prequantize_cnn_params(params, spec, W1A4)
imgs = [np.random.RandomState(i).uniform(size=(16, 16, 3)).astype(np.float32)
        for i in range(19)]  # ragged: 16 + 3
mesh = make_serve_mesh()
assert mesh is not None and mesh.devices.size == 8, mesh
runner = CNNRunner(sp, spec, W1A4)
eng = ServeEngine(runner, max_batch=16, mesh=mesh)
res = eng.serve(imgs)
assert eng.stats["dispatches"] == 2, eng.stats
# ragged tail (3) padded up to the device count
assert res[-1].padded % 8 == 0 and res[-1].batch == 3, res[-1]
# 1) engine plumbing is exact: a direct shard_map call on the same padded
#    batch reproduces every served row bit-for-bit
fwd = jax.jit(data_parallel(runner.make_forward(runner.shape_key(imgs[0])), mesh))
full = jax.device_put(runner.collate(imgs[:16], 16), batch_sharding(mesh))
direct = np.asarray(fwd(sp, full))
for i in range(16):
    np.testing.assert_array_equal(res[i].value, direct[i])
# 2) semantics match the single-device per-request path (separate compiled
#    programs under a different device topology: fp layers drift at ulp ->
#    quant-level scale, so allclose + class equality, not bitwise)
f1 = jax.jit(lambda x: cnn_forward(sp, x, spec, W1A4, "serve"))
for i, r in enumerate(res):
    ref = np.asarray(f1(jnp.asarray(imgs[i])[None]))[0]
    np.testing.assert_allclose(r.value, ref, rtol=2e-2, atol=2e-2)
    assert r.value.argmax() == ref.argmax(), i
print("MULTIDEVICE OK")
"""


@pytest.mark.slow
def test_engine_multidevice_sharded_subprocess():
    """Data-parallel shard_map dispatch on 8 forced host devices is
    bit-identical to the single-device per-request path."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", MD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEVICE OK" in p.stdout, p.stdout + p.stderr
