"""Pallas kernel validation: shape/dtype sweeps vs ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

SHAPES = [(5, 70, 9), (17, 130, 33), (64, 64, 64), (3, 33, 5), (130, 600, 140),
          (1, 1, 1), (128, 512, 128)]
BITS = [(1, 1), (4, 1), (8, 2), (2, 2), (4, 3)]


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("ab,wb", BITS[:3])
def test_bitgemm_faithful_vs_ref(M, K, N, ab, wb):
    k1, k2 = jax.random.split(jax.random.PRNGKey(M * 1000 + K + N))
    a_lv = jax.random.randint(k1, (M, K), 0, 1 << ab).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (K, N), 0, 1 << wb).astype(jnp.int32)
    gold = np.asarray(ref.bitgemm_ref(a_lv, w_lv, ab, wb))
    out = np.asarray(ops.bitgemm_faithful(a_lv, w_lv, ab, wb, interpret=True))
    assert (out == gold).all()


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("ab,wb", BITS)
def test_bitgemm_mxu_vs_ref(M, K, N, ab, wb):
    k1, k2 = jax.random.split(jax.random.PRNGKey(M + K * 7 + N))
    a_lv = jax.random.randint(k1, (M, K), 0, 1 << ab).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (K, N), 0, 1 << wb).astype(jnp.int32)
    gold = np.asarray(ref.bitgemm_ref(a_lv, w_lv, ab, wb))
    out = np.asarray(ops.bitgemm_mxu(a_lv, w_lv, ab, wb, interpret=True))
    assert (out == gold).all()


def test_bitgemm_mxu_8bit_nibble_split():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a_lv = jax.random.randint(k1, (9, 96), 0, 256).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (96, 7), 0, 256).astype(jnp.int32)
    gold = np.asarray(a_lv) @ np.asarray(w_lv)
    out = np.asarray(ops.bitgemm_mxu(a_lv, w_lv, 8, 8, interpret=True))
    assert (out == gold).all()


@pytest.mark.parametrize("M,K", [(5, 70), (256, 512), (17, 31), (300, 1000)])
@pytest.mark.parametrize("bits", [1, 4, 8])
def test_quantize_pack_vs_ref(M, K, bits):
    a = jax.random.uniform(jax.random.PRNGKey(M + K), (M, K), minval=-0.5,
                           maxval=1.5)
    lv, pk = ops.quantize_pack(a, bits, interpret=True)
    lv_r, pk_r = ref.quantpack_ref(a, bits)
    assert (np.asarray(lv) == np.asarray(lv_r)).all()
    assert (np.asarray(pk) == np.asarray(pk_r)).all()


@given(st.integers(1, 40), st.integers(1, 120), st.integers(1, 20),
       st.integers(1, 4), st.integers(1, 2), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_bitgemm_property(M, K, N, ab, wb, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a_lv = jax.random.randint(k1, (M, K), 0, 1 << ab).astype(jnp.int32)
    w_lv = jax.random.randint(k2, (K, N), 0, 1 << wb).astype(jnp.int32)
    gold = np.asarray(a_lv) @ np.asarray(w_lv)
    assert (np.asarray(ops.bitgemm_mxu(a_lv, w_lv, ab, wb, interpret=True))
            == gold).all()
    assert (np.asarray(ops.bitgemm_faithful(a_lv, w_lv, ab, wb, interpret=True))
            == gold).all()


def test_quant_dense_kernel_end_to_end():
    from repro.core.and_accum import quant_dense_forward
    a = jax.random.uniform(jax.random.PRNGKey(0), (33, 100))
    w = jax.random.normal(jax.random.PRNGKey(1), (100, 17))
    for path in ("mxu", "faithful"):
        out = ops.quant_dense_kernel(a, w, 4, 2, path=path)
        exp = quant_dense_forward(a, w, 4, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)


def test_int8_matmul_dtypes():
    from repro.kernels.bitgemm_mxu import int8_matmul_pallas
    a = jax.random.randint(jax.random.PRNGKey(0), (37, 129), -128, 127,
                           dtype=jnp.int32).astype(jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (129, 65), -128, 127,
                           dtype=jnp.int32).astype(jnp.int8)
    out = np.asarray(int8_matmul_pallas(a, b, interpret=True))
    gold = np.asarray(a, np.int32) @ np.asarray(b, np.int32)
    assert (out == gold).all()
    assert out.dtype == np.int32
