"""End-to-end behaviour tests for the paper's system.

Headline properties: the bit-wise (AND-Accumulation) CNN *learns*; the LM
stack trains end-to-end through the distributed trainer (with compressed
gradients and checkpoint/resume); prefill+decode serving is consistent
with teacher forcing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SINGLE, all_configs
from repro.core.quant import FP32, W1A4, QuantConfig
from repro.data.synthetic import lm_batch, svhn_like
from repro.models.cnn import cnn_loss, init_cnn, svhn_cnn_spec
from repro.train.optimizer import OptConfig, apply_updates, init_opt_state


def _train_cnn(quant: QuantConfig, steps: int = 60, seed: int = 0):
    spec = svhn_cnn_spec(8)
    params, _ = init_cnn(jax.random.PRNGKey(seed), spec)
    ocfg = OptConfig(kind="adamw", lr=3e-3, warmup_steps=10, total_steps=steps)
    ost = init_opt_state(params, ocfg)

    @jax.jit
    def step(params, ost, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: cnn_loss(p, batch, spec, quant), has_aux=True)(params)
        params, ost, _ = apply_updates(params, g, ost, ocfg)
        return params, ost, m

    losses = []
    for i in range(steps):
        x, y = svhn_like(32, seed=1000 + i)
        params, ost, m = step(params, ost,
                              dict(image=jnp.asarray(x), label=jnp.asarray(y)))
        losses.append(float(m["loss"]))
    x, y = svhn_like(256, seed=99)
    from repro.models.cnn import cnn_forward
    logits = cnn_forward(params, jnp.asarray(x), spec, quant, "train")
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
    return losses, acc


@pytest.mark.slow
def test_bitwise_cnn_learns_w1a4():
    losses, acc = _train_cnn(W1A4)
    assert losses[-1] < losses[0] * 0.8, "loss did not decrease"
    assert acc > 0.3, f"quantized CNN failed to beat chance: {acc}"


@pytest.mark.slow
def test_fp32_baseline_learns():
    losses, acc = _train_cnn(FP32)
    assert acc > 0.5


def test_lm_trainer_end_to_end(tmp_path):
    """Distributed Trainer: loss decreases, checkpoint/restore resumes."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import TrainConfig, Trainer

    cfg = all_configs()["smollm-360m"].smoke(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab=64, head_dim=32)
    mesh = make_host_mesh()
    tr = Trainer(cfg, SINGLE, mesh, OptConfig(lr=3e-3, warmup_steps=5),
                 TrainConfig(steps=30, log_every=10, ckpt_every=10),
                 ckpt_dir=str(tmp_path))
    bf = lambda s, m: {k: jnp.asarray(v) for k, v in
                       lm_batch(s, m, batch=4, seq=16, vocab=64, seed=3).items()}
    hist = tr.run(bf, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
    tr2 = Trainer(cfg, SINGLE, mesh, OptConfig(lr=3e-3, warmup_steps=5),
                  TrainConfig(steps=30), ckpt_dir=str(tmp_path))
    assert tr2.restore() and tr2.step == 30


def test_compressed_training_reduces_loss():
    """int8+EF compressed gradients still reduce the loss."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import TrainConfig, Trainer

    cfg = all_configs()["smollm-360m"].smoke(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab=64, head_dim=32)
    mesh = make_host_mesh()
    tr = Trainer(cfg, SINGLE, mesh, OptConfig(lr=3e-3, warmup_steps=5),
                 TrainConfig(steps=25, log_every=24, compress_grads=True))
    bf = lambda s, m: {k: jnp.asarray(v) for k, v in
                       lm_batch(s, m, batch=4, seq=16, vocab=64, seed=4).items()}
    hist = tr.run(bf, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_serve_prefill_then_decode_consistency():
    """Prefill cache + decode continuation == teacher-forced forward."""
    from repro.models import transformer as T
    cfg = all_configs()["phi3-mini-3.8b"].smoke()
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)
    B, S_p, S_d = 2, 8, 4
    toks = jax.random.randint(key, (B, S_p + S_d), 0, cfg.vocab)
    logits_p, cache = T.prefill(params, cfg, SINGLE, tokens=toks[:, :S_p])
    from repro.launch.serve import grow_cache
    cache = grow_cache(cache, S_p, S_p + S_d)
    outs = []
    for t in range(S_d):
        lg, cache = T.decode_step(params, cache, toks[:, S_p + t: S_p + t + 1],
                                  S_p + t, cfg, SINGLE)
        outs.append(lg[:, 0])
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    fwd, _, _ = T.forward(params, cfg, SINGLE, tokens=toks, mode="train")
    np.testing.assert_allclose(dec, np.asarray(fwd[:, S_p:]), atol=2e-2,
                               rtol=1e-2)


def test_prequantized_serving_matches_runtime_quant():
    """Pre-quantized int8 weights == runtime quantization (serve path)."""
    from repro.core.quant import W1A8
    from repro.models import transformer as T
    from repro.models.layers import prequantize_params
    cfg = all_configs()["phi3-mini-3.8b"].smoke()
    cfg = dataclasses.replace(cfg, quant=W1A8)
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    ref, _, _ = T.forward(params, cfg, SINGLE, tokens=toks, mode="train",
                          qmode="serve")
    pq = prequantize_params(params, cfg)
    out, _, _ = T.forward(pq, cfg, SINGLE, tokens=toks, mode="train",
                          qmode="serve")
    # per-layer scales (prequant) vs whole-stack scales (runtime): small drift
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2.0,
                               rtol=0.5)
    assert pq["blocks"]["attn"]["attn"]["wq"]["q"].dtype == jnp.int8
