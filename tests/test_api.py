"""The public API surface (repro.api, DESIGN.md §9).

Pins the PR-5 contracts:

* **Target registry** — unknown targets error naming the available ones;
  legacy aliases resolve; the cpu/tpu targets' cost tables reproduce the
  PR-4 golden dispatch tables; `sot_mram` reproduces the Table II
  arithmetic bit-for-bit against the spec-walk reference.
* **Session round trip** — ``build(spec, quant).compile(target="cpu")``
  serves bit-identically to the PR-4 plan path, and ``.simulate`` on the
  SAME compiled plan reproduces the paper's headline vs-ReRAM ratios.
* **Mapper fixes** — pooled/stride spatial bookkeeping against the
  paper's Fig. 3 dims; ``accel_cost`` rejects empty works.
* **Deprecation policy** — importing ``repro.pim.accelsim`` emits exactly
  one DeprecationWarning; ``models/cnn.prepare_serve_params`` is gone.
"""
import dataclasses
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import api
from repro.core import plan as P
from repro.core.quant import QuantConfig, W1A4
from repro.kernels import ops
from repro.models.cnn import ConvSpec, init_cnn, svhn_cnn_spec


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    ops.clear_plan_state()
    yield
    ops.clear_plan_state()


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------

def test_unknown_target_names_available():
    with pytest.raises(ValueError) as e:
        api.get_target("tpu_v9000")
    msg = str(e.value)
    for name in ("cpu", "tpu", "sot_mram", "imce", "reram", "cmos_asic"):
        assert name in msg
    assert "tpu_v9000" in msg


def test_registry_contents_and_aliases():
    assert set(api.available_targets()) >= {
        "cpu", "tpu", "sot_mram", "imce", "reram", "cmos_asic"}
    # legacy accelsim/jax spellings resolve to the canonical targets
    assert api.get_target("proposed") is api.get_target("sot_mram")
    assert api.get_target("asic") is api.get_target("cmos_asic")
    assert api.target_for_backend("gpu") is api.get_target("cpu")
    # unknown backends fall back to conservative CPU dispatch (historical
    # non-TPU branch), while get_target stays strict
    assert api.target_for_backend("weird_pjrt") is api.get_target("cpu")
    kinds = {n: api.get_target(n).kind for n in api.available_targets()}
    assert kinds["cpu"] == kinds["tpu"] == "compute"
    assert kinds["sot_mram"] == kinds["reram"] == "pim"


def test_register_target_is_open():
    t = api.PIMTarget(name="_test_feFET", device=api.get_target("imce").device,
                      energy_scale=1.0, area_mm2=1.0)
    api.register_target(t)
    try:
        assert api.get_target("_test_feFET") is t
    finally:
        from repro.api import targets as targets_mod
        targets_mod._REGISTRY.pop("_test_feFET")


def test_cpu_tpu_targets_reproduce_golden_dispatch():
    """The targets' cost tables ARE the PR-4 crossover constants: the
    compile pass (which now dispatches through the targets) must still
    produce the golden CPU engine tables, and target.select_engine must
    agree with select_engine for every (layer, batch) cell."""
    from test_plan import GOLDEN_CPU
    from repro.configs.paper_cnn import ALEXNET_SPEC, SVHN_SPEC
    from repro.core.quant import W1A8

    cpu = api.get_target("cpu")
    tpu = api.get_target("tpu")
    for name, spec, img, quant in (("svhn", SVHN_SPEC, 40, W1A4),
                                   ("alexnet", ALEXNET_SPEC, 112, W1A8)):
        plan = P.compile_model(None, spec, quant, backend="cpu",
                               batch_hints=(1, 8), img_hw=img, model=name)
        assert {lp.name: dict(lp.engines) for lp in plan.layers} \
            == GOLDEN_CPU[name]
        for lp in plan.layers:
            if lp.fp:
                continue
            for b, eng in lp.engines:
                conv = ops.ConvShape(lp.in_h, lp.in_w, lp.kh, lp.kw,
                                     lp.stride, lp.padding, batch=b)
                m = b * lp.out_h * lp.out_w
                assert cpu.select_engine(m, lp.k, lp.cout, lp.a_bits,
                                         lp.w_bits, conv) == eng
                # the tpu table is exercised through the same interface
                assert tpu.select_engine(m, lp.k, lp.cout, lp.a_bits,
                                         lp.w_bits, conv) in (
                    "implicit", "fused", "faithful")


def test_sot_mram_svhn_bit_identical_to_spec_walk():
    """Table II arithmetic through the registry == the legacy spec-walk
    pipeline, bit-for-bit (same works, same accel_cost float order, same
    fitted energy scale) — for every design and dataset."""
    from repro.api import reports
    from repro.pim.energy import DESIGNS
    from repro.pim.mapper import accel_cost, model_work

    legacy_scale = dict(proposed=0.6602, imce=0.5586, reram=0.3662,
                        asic=0.661)
    for design in ("proposed", "imce", "reram", "asic"):
        for ds_name, ds in reports.DATASETS.items():
            works = model_work(ds["spec"](), ds["img"], 1, 1)
            ref = accel_cost(DESIGNS[design], works)
            got = reports.simulate(design, ds_name)
            assert got["energy_uj"] == ref["energy_uj"] * legacy_scale[design]
            assert got["latency_us"] == ref["latency_us"]
            assert got["macs"] == ref["macs"]
            assert got["row_ops"] == ref["row_ops"]


# ---------------------------------------------------------------------------
# Session round trip (the acceptance criterion)
# ---------------------------------------------------------------------------

def _setup(channels=8, img=16, quant=W1A4):
    spec = svhn_cnn_spec(channels)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    return spec, params


def test_api_roundtrip_serve_bit_identical_and_simulates_claims():
    """build -> compile(cpu) -> serve is bit-identical to the PR-4 plan
    path, and .simulate on the SAME compiled plan reproduces the paper's
    ~5.4x/9x vs-ReRAM headline (abstract / §III-C,D)."""
    from repro.launch.engine import CNNRunner, ServeEngine

    spec, params = _setup()
    imgs = [np.random.RandomState(i).uniform(size=(16, 16, 3))
            .astype(np.float32) for i in range(5)]
    model = api.build(spec, W1A4, params=params, img_hw=16, name="svhn_api")
    compiled = model.compile(target="cpu", batch_hints=(1, 4))

    dep = compiled.serve(max_batch=4)
    got = dep.predict(imgs)
    # PR-4 path: compile_model + ServeEngine(CNNRunner(plan=...))
    pr4_plan = P.compile_model(params, spec, W1A4, backend="cpu",
                               batch_hints=(1, 4), img_hw=16,
                               model="svhn_api")
    ref = ServeEngine(CNNRunner(None, spec, None, plan=pr4_plan),
                      max_batch=4).serve(imgs)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r.value)
    # and against the raw (jitted, like every engine dispatch) plan
    # executor, no engine machinery at all
    raw = np.asarray(jax.jit(lambda v: P.plan_forward(compiled.plan, v))(
        np.stack(imgs)[:4]))
    for i in range(4):
        np.testing.assert_array_equal(got[i], raw[i])

    # the SAME compiled plan prices the paper's accelerators
    proposed = compiled.simulate(target="sot_mram")
    reram = compiled.simulate(target="reram")
    ratios = proposed.vs(reram)
    assert ratios["energy"] == pytest.approx(5.4, rel=0.15)
    assert ratios["speed"] == pytest.approx(9.0, rel=0.15)
    imce = compiled.simulate(target="imce")
    assert proposed.vs(imce)["speed"] == pytest.approx(3.0, rel=0.15)
    # per-layer breakdown covers every layer and sums to the total order
    assert len(proposed.layers) == len(spec)
    assert proposed.area_mm2 == 2.60 and proposed.fps_per_mm2 > 0


def test_compile_rejects_pim_target_with_guidance():
    spec, params = _setup()
    with pytest.raises(P.PlanError, match="simulate"):
        api.build(spec, W1A4, params=params, img_hw=16).compile(
            target="sot_mram")


def test_session_cache_roundtrip(tmp_path):
    """compile(cache=...) saves; a second compile reloads (no requant) and
    serves bit-identically; api.load guards against config mismatch."""
    spec, params = _setup()
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3))
    model = api.build(spec, W1A4, params=params, img_hw=16, name="rt")
    base = str(tmp_path / "plan_api")
    c1 = model.compile(target="cpu", cache=base)
    assert not c1.reloaded and c1.cache_path.endswith(".json")
    ref = np.asarray(c1.forward(x))

    c2 = model.compile(target="cpu", cache=base)
    assert c2.reloaded
    assert c2.fingerprint() == c1.fingerprint()
    np.testing.assert_array_equal(np.asarray(c2.forward(x)), ref)

    loaded = api.load(base, quant=W1A4, model="rt")
    np.testing.assert_array_equal(np.asarray(loaded.forward(x)), ref)
    from repro.core.quant import W1A8
    with pytest.raises(P.PlanError, match="w1a8"):
        api.load(base, quant=W1A8)
    # an explicitly requested target must hold for the cached plan too: a
    # cpu plan is not a valid answer to compile(target="tpu")
    with pytest.raises(P.PlanError, match="backend"):
        model.compile(target="tpu", cache=base)


def test_plans_carry_per_layer_cost_estimates():
    """Compiled plans are annotated with the compile target's per-layer
    (energy_pj, cycles, bytes_moved) roofline estimate, and the estimates
    survive serialization."""
    spec, params = _setup()
    plan = P.compile_model(None, spec, W1A4, backend="cpu", img_hw=16)
    for lp in plan.layers:
        assert len(lp.cost) == 3 and all(c > 0 for c in lp.cost)
    # deeper layers move more bytes than the 10-class head
    assert plan.layers[1].cost[2] > plan.layers[-1].cost[2]
    import json
    meta = plan.meta()
    assert json.dumps(meta)  # serializable
    rt = P._layer_from_json(json.loads(json.dumps(
        P._layer_to_json(plan.layers[1]))))
    assert rt.cost == plan.layers[1].cost


def test_lm_session_serve_matches_direct_plan():
    from repro.configs import SINGLE, all_configs
    from repro.launch.engine import LMRunner, ServeEngine
    from repro.models import transformer as T

    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=dataclasses.replace(
            __import__("repro.core.quant", fromlist=["W1A8"]).W1A8,
            engine="auto"))
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    prompts = [np.random.RandomState(i).randint(0, cfg.vocab, size=(8,))
               .astype(np.int32) for i in range(3)]
    compiled = api.build(cfg, params=params).compile(batch_hints=(4,),
                                                     prompt_len=8)
    got = compiled.serve(max_batch=4, new_tokens=5).predict(prompts)
    direct_plan = P.compile_lm(params, cfg, batch_hints=(4,), prompt_len=8)
    ref = ServeEngine(LMRunner(None, cfg, new_tokens=5,
                               model_plan=direct_plan),
                      max_batch=4).serve(prompts)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r.value)
    with pytest.raises(P.PlanError, match="CNN"):
        compiled.simulate(target="sot_mram")


# ---------------------------------------------------------------------------
# Mapper fixes (satellite): Fig. 3 spatial bookkeeping + empty-works guard
# ---------------------------------------------------------------------------

def _walk_dims(spec, img):
    from repro.pim.mapper import layer_work

    hw, dims = img, []
    for s in spec:
        _, out = layer_work(s, hw, 1, 1)
        dims.append((hw, out))
        hw = out
    return dims


def test_layer_work_fig3_svhn_dims():
    """The paper's Fig. 3 SVHN walk: 40 -> 40 -> 40 ->(pool) 20 -> 20
    ->(pool) 10 -> 10 -> 10 -> 10 (FC-equivalent 1x1 tail)."""
    dims = _walk_dims(svhn_cnn_spec(8), 40)
    assert dims == [(40, 40), (40, 40), (40, 20), (20, 20), (20, 10),
                    (10, 10), (10, 10), (10, 10)]


def test_layer_work_stride_then_pool_order():
    """Pool halving applies AFTER the ceil-div stride output (stride-2
    conv on 9 -> ceil(9/2)=5 -> pool -> 2), floored at 1 for degenerate
    pooled maps, and a bad input extent is a loud error."""
    from repro.pim.mapper import layer_work

    w, out = layer_work(ConvSpec(4, 8, 3, stride=2, pool=True), 9, 1, 1)
    assert out == 2 and w.macs == 5 * 5 * 3 * 3 * 4 * 8
    # pooled 1x1 map floors at 1 instead of collapsing to 0 (LeNet's
    # pooled-FC stage) — downstream layers keep nonzero work
    _, out = layer_work(ConvSpec(4, 8, 5, pool=True, fc=True), 14, 1, 1)
    assert out == 1
    with pytest.raises(ValueError, match=">= 1"):
        layer_work(ConvSpec(4, 8, 3), 0, 1, 1)


def test_accel_cost_rejects_empty_works():
    from repro.pim.energy import DESIGNS
    from repro.pim.mapper import accel_cost

    with pytest.raises(ValueError, match="empty works"):
        accel_cost(DESIGNS["proposed"], [])


def test_works_from_layers_matches_model_work():
    """Plan-geometry works == spec-walk works for the paper models at
    every evaluated W:I config (the bit-for-bit bridge reports.simulate
    stands on)."""
    from repro.api.reports import DATASETS
    from repro.pim.mapper import model_work, works_from_layers

    for ds in DATASETS.values():
        spec = ds["spec"]()
        for (m_b, n_b) in ((1, 1), (8, 1), (2, 2)):
            plan = P.compile_model(
                None, spec, QuantConfig(w_bits=n_b, a_bits=m_b, g_bits=8),
                backend="cpu", img_hw=ds["img"])
            assert works_from_layers(plan.layers) == \
                model_work(spec, ds["img"], m_b, n_b)


# ---------------------------------------------------------------------------
# Deprecation policy
# ---------------------------------------------------------------------------

def test_accelsim_shim_warns_exactly_once():
    """Importing the legacy entry point emits one DeprecationWarning (and
    only one — re-import is free), and its numbers still match the api."""
    code = (
        "import warnings, sys\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    import repro.pim.accelsim as A1\n"
        "    import repro.pim.accelsim as A2\n"
        "dep = [x for x in w if issubclass(x.category, DeprecationWarning)\n"
        "       and 'accelsim' in str(x.message)]\n"
        "assert len(dep) == 1, [str(x.message) for x in dep]\n"
        "assert 'repro.api' in str(dep[0].message)\n"
        "import repro.api.reports as R\n"
        "assert A1.simulate('proposed', 'mnist') == "
        "R.simulate('sot_mram', 'mnist')\n"
        "print('OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=_src_env())
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def _src_env():
    import os

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env
