"""Per-arch smoke tests (brief deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SINGLE, all_configs
from repro.models import transformer as T

ARCHS = list(all_configs())


def _batch(cfg, key, B=2, S=16):
    b = {}
    if cfg.frame_input:
        b["frame_feats"] = jax.random.normal(key, (B, S, cfg.frame_dim))
    else:
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.n_patches:
        b["patch_embeds"] = jax.random.normal(key, (B, cfg.n_patches, cfg.vit_dim))
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = all_configs()[arch].smoke()
    key = jax.random.PRNGKey(0)
    params, axes = T.init_lm(key, cfg, SINGLE)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, _, aux = T.forward(
        params, cfg, SINGLE, tokens=batch.get("tokens"),
        patch_embeds=batch.get("patch_embeds"),
        frame_feats=batch.get("frame_feats"), mode="train")
    S_out = S + (cfg.n_patches or 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, batch, cfg, SINGLE), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "deepseek-moe-16b",
                                  "rwkv6-1.6b", "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    cfg = all_configs()[arch].smoke()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no-drop routing
    key = jax.random.PRNGKey(1)
    params, _ = T.init_lm(key, cfg, SINGLE)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    cache = T.init_cache(cfg, SINGLE, B, 32, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = T.decode_step(params, cache, toks[:, t:t + 1], t, cfg, SINGLE)
        outs.append(lg[:, 0])
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    fwd, _, _ = T.forward(params, cfg, SINGLE, tokens=toks, mode="train")
    np.testing.assert_allclose(dec, np.asarray(fwd), atol=2e-2, rtol=1e-2)


def test_head_padding_is_exact():
    """TP-padded Q heads must not change the math (zero-masked)."""
    import repro.configs.base as base
    cfg = all_configs()["smollm-360m"].smoke()  # 4 heads, kv=2
    plan_pad = base.ShardPlan(tp=16, rules=SINGLE.rules)  # pads 4 -> 16
    key = jax.random.PRNGKey(2)
    p1, _ = T.init_lm(key, cfg, SINGLE)
    p16, _ = T.init_lm(key, cfg, plan_pad)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    # copy the unpadded weights into the padded layout
    def graft(pp, pu):
        for kind in pp["blocks"]:
            a_p = pp["blocks"][kind]["attn"]
            a_u = pu["blocks"][kind]["attn"]
            H, hd = cfg.n_heads, cfg.hd
            a_p["wq"] = a_p["wq"].at[:, :, : H * hd].set(a_u["wq"])
            a_p["wq"] = a_p["wq"].at[:, :, H * hd:].set(
                jax.random.normal(key, a_p["wq"][:, :, H * hd:].shape))
            a_p["wo"] = a_p["wo"].at[:, : H * hd, :].set(a_u["wo"])
            a_p["wo"] = a_p["wo"].at[:, H * hd:, :].set(
                jax.random.normal(key, a_p["wo"][:, H * hd:, :].shape) * 10)
        for k in ("embed", "final_norm"):
            pp[k] = pu[k]
        for kind in pp["blocks"]:
            for sub in pp["blocks"][kind]:
                if sub == "attn":
                    for w in ("wk", "wv", "ln"):
                        pp["blocks"][kind]["attn"][w] = pu["blocks"][kind]["attn"][w]
                else:
                    pp["blocks"][kind][sub] = pu["blocks"][kind][sub]
        return pp
    p16 = graft(p16, p1)
    out1, _, _ = T.forward(p1, cfg, SINGLE, tokens=toks, mode="train")
    out16, _, _ = T.forward(p16, cfg, plan_pad, tokens=toks, mode="train")
    # padded heads carry RANDOM weights but are masked: outputs identical
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out16),
                               rtol=2e-5, atol=2e-5)


def test_layer_count_exact_for_pattern_remainder():
    cfg = all_configs()["recurrentgemma-9b"]
    pat = cfg.blocks_pattern
    assert len(pat) == 38
    assert pat.count("rec") == 26 and pat.count("attn_local") == 12
    assert pat[-2:] == ("rec", "rec")  # remainder handled, not dropped


def test_moe_capacity_drops_are_bounded():
    from repro.models.layers import init_moe, moe_fwd
    cfg = all_configs()["deepseek-moe-16b"].smoke()
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, SINGLE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_fwd(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and float(aux) >= 0


def test_paper_cnn_forward_shapes():
    from repro.core.quant import W1A4
    from repro.models.cnn import cnn_forward, init_cnn, svhn_cnn_spec
    spec = svhn_cnn_spec(8)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 40, 40, 3))
    for mode in ("train", "serve"):
        logits = cnn_forward(params, x, spec, W1A4, mode)
        assert logits.shape == (4, 10)
        assert bool(jnp.isfinite(logits).all())


def test_cnn_train_serve_agree():
    """Fake-quant train conv and integer-engine serve conv agree closely.

    Serve normalizes with per-sample statistics (batch-invariance contract
    for the request-batching engine, DESIGN.md §7) while train keeps batch
    statistics, so the tolerance covers that deliberate stats gap on top of
    the quantization-path gap; predicted classes must still match exactly.
    """
    from repro.core.quant import W1A4
    from repro.models.cnn import cnn_forward, init_cnn, svhn_cnn_spec
    spec = svhn_cnn_spec(8)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 40, 40, 3))
    lt = np.asarray(cnn_forward(params, x, spec, W1A4, "train"))
    ls = np.asarray(cnn_forward(params, x, spec, W1A4, "serve"))
    np.testing.assert_allclose(lt, ls, rtol=1e-1, atol=1e-1)
    np.testing.assert_array_equal(lt.argmax(-1), ls.argmax(-1))
