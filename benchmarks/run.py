"""Benchmark aggregator — one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _run(name, fn, *args, **kw):
    t0 = time.perf_counter()
    rows = fn(*args, **kw)
    us = (time.perf_counter() - t0) * 1e6
    return name, us, rows


def main() -> None:
    sys.path.insert(0, os.path.dirname(__file__))
    from paper_tables import (api_claims, fig8_storage, fig9_energy,
                              fig10_performance, intermittency_study,
                              kernel_bench, table1_accuracy,
                              table2_energy_area)

    def serve_fused(fast=False):
        # deferred so a bench_serve import failure stays one failing row
        from bench_serve import serve_rows
        return serve_rows(fast=fast)

    def conv_implicit(fast=False):
        from bench_conv import conv_rows
        return conv_rows(fast=fast)

    def attn_flash(fast=False):
        from bench_attn import attn_rows
        return attn_rows(fast=fast)

    def resilience(fast=False):
        from bench_resilience import resilience_rows
        return resilience_rows(fast=fast)

    def fleet_study(fast=False):
        from bench_fleet import fleet_rows
        return fleet_rows(fast=fast)

    fast = "--fast" in sys.argv
    strict = "--strict" in sys.argv  # exit nonzero if any job errors (CI)
    failed = []
    jobs = [
        ("table1_accuracy", table1_accuracy,
         dict(steps=20 if fast else 60, train=True)),
        ("fig8_storage", fig8_storage, {}),
        ("fig9_energy", fig9_energy, {}),
        ("fig10_performance", fig10_performance, {}),
        ("table2_energy_area", table2_energy_area, {}),
        ("api_claims", api_claims, {}),
        ("intermittency", intermittency_study, {}),
        ("kernels", kernel_bench, {}),
        ("conv_implicit", conv_implicit, dict(fast=fast)),
        ("attn_flash", attn_flash, dict(fast=fast)),
        ("serve_fused", serve_fused, dict(fast=fast)),
        ("resilience", resilience, dict(fast=fast)),
        ("fleet_study", fleet_study, dict(fast=fast)),
    ]
    print("name,us_per_call,derived")
    all_rows = {}
    for name, fn, kw in jobs:
        try:
            name, us, rows = _run(name, fn, **kw)
            all_rows[name] = rows
            derived = json.dumps(rows[:3] if isinstance(rows, list) else rows)
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:  # repro-lint: disable=RL003 — recorded in the failure list; --strict exits nonzero on it
            print(f"{name},0,ERROR:{e}")
            failed.append(f"{name} ({type(e).__name__}: {e})")
    # roofline table (if dry-run results exist)
    try:
        import roofline
        tag = ("16x16-analysis"
               if any("analysis" in f for f in os.listdir(roofline.RESULTS_DIR))
               else "16x16")
        rows = roofline.rows_csv(tag)
        if rows:
            ok = [r for r in rows if r.get("ok")]
            fr = sorted(ok, key=lambda r: -r["frac"])[:3]
            print(f"roofline,{len(rows)},{json.dumps([dict(arch=r['arch'], shape=r['shape'], frac=round(r['frac'], 3)) for r in fr])}")
    except Exception as e:  # repro-lint: disable=RL003 — optional table; the error is printed in the CSV row
        print(f"roofline,0,ERROR:{e}")
    out = "results/bench_rows.json"
    os.makedirs("results", exist_ok=True)
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    print(f"# full rows -> {out}", file=sys.stderr)
    if strict and failed:
        sys.exit(f"jobs failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
