"""Roofline aggregation: read results/dryrun/*.json -> per-cell table.

Run after ``python -m repro.launch.sweep --mesh single --analysis``.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load(mesh_tag: str = "16x16-analysis"):
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh_tag}.json"))):
        r = json.load(open(f))[0]
        if not r.get("ok"):
            rows.append(dict(arch=r["arch"], shape=r["shape"], ok=False))
            continue
        rl = r["roofline"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], ok=True,
            compute_s=rl["compute_s"], memory_s=rl["memory_s"],
            collective_s=rl["collective_s"], dominant=rl["dominant"],
            useful=rl["useful_flops_frac"], frac=rl["roofline_frac"],
            temp_gib=r["memory"].get("temp_size_in_bytes", 0) / 2**30,
            compile_s=r.get("compile_s", 0),
        ))
    return rows


def table(mesh_tag: str = "16x16-analysis") -> str:
    rows = load(mesh_tag)
    out = [f"{'arch':22s} {'shape':12s} {'compute_s':>11s} {'memory_s':>11s} "
           f"{'coll_s':>11s} {'dominant':>10s} {'useful':>7s} {'frac':>7s}"]
    for r in rows:
        if not r["ok"]:
            out.append(f"{r['arch']:22s} {r['shape']:12s}  FAILED")
            continue
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:11.3e} "
            f"{r['memory_s']:11.3e} {r['collective_s']:11.3e} "
            f"{r['dominant']:>10s} {r['useful']:6.1%} {r['frac']:6.1%}")
    return "\n".join(out)


def rows_csv(mesh_tag: str = "16x16-analysis"):
    return load(mesh_tag)


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "16x16-analysis"))
