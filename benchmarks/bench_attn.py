"""Attention engine benchmark: full vs chunked vs quantized flash.

Times the four attention realizations the serve path dispatches between
(``kernels.ops.ATTN_ENGINES``) over prefill lengths, causal and
sliding-window:

  ``full``       materialized S^2 logits (``attn_full``) — capped at
                 S <= 8192 (a 32k logits tensor is ~17 GB);
  ``chunked``    pure-JAX online-softmax scan, with and without the
                 masked-chunk skip (``skip_ratio`` is the causal ~2x win);
  ``flash``      quantized flash kernel (``kernels.attn_flash``):
                 nibble-split int8 level dots + rowsum zero-point
                 correction, online softmax in the epilogue.
                 ``flash_vs_chunked_noskip`` is the headline ratio vs the
                 pre-skip serve dataflow this PR replaced;
                 ``flash_vs_chunked`` tracks the (smaller) remaining edge
                 over this PR's own skip-enabled chunked scan.

Emits ``name,us_per_call,derived`` CSV plus ``results/bench_attn.json``::

    PYTHONPATH=src python benchmarks/bench_attn.py [--fast]

or via ``benchmarks/run.py`` (job name ``attn_flash``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

FULL_MAX_S = 8192  # beyond this the S^2 logits tensor stops fitting


def _timeit(fn, *args, n: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def _case_rows(S: int, *, heads: int, hd: int, window, n: int):
    from repro.models.layers import attn_chunked, attn_full
    from repro.kernels.attn_flash import attn_flash_xla

    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (1, S, heads, hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, heads, hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, heads, hd), jnp.float32)
    pos = jnp.arange(S)
    tag = f"S{S}" + (f"_w{window}" if window else "_causal")
    common = dict(causal=True, window=window, q_pos=pos, kv_pos=pos)

    full = jax.jit(lambda q, k, v: attn_full(q, k, v, **common))
    chunk = jax.jit(lambda q, k, v: attn_chunked(q, k, v, **common))
    dense = jax.jit(lambda q, k, v: attn_chunked(q, k, v, skip_masked=False,
                                                 **common))
    flash = jax.jit(lambda q, k, v: attn_flash_xla(q, k, v, causal=True,
                                                   window=window))

    row = dict(name=f"attn_{tag}", seq=S, heads=heads, head_dim=hd,
               window=window or 0)
    if S <= FULL_MAX_S:
        row["full_us"] = round(_timeit(full, q, k, v, n=n))
    chunk_us = _timeit(chunk, q, k, v, n=n)
    dense_us = _timeit(dense, q, k, v, n=n)
    flash_us = _timeit(flash, q, k, v, n=n)
    row.update(
        chunked_us=round(chunk_us), chunked_noskip_us=round(dense_us),
        flash_us=round(flash_us),
        skip_ratio=round(dense_us / chunk_us, 2),
        # vs this PR's skip-enabled chunked, and vs the pre-PR serve
        # dataflow (no masked-chunk skip) — the incumbent flash replaced
        flash_vs_chunked=round(chunk_us / flash_us, 2),
        flash_vs_chunked_noskip=round(dense_us / flash_us, 2))
    return row


def attn_rows(fast: bool = False):
    # smoke-model attention geometry (head_dim matches the smoke LMs).
    # The CPU flash win comes from interior kv blocks skipping the mask
    # arithmetic entirely (boundary blocks alone pay for it), so it is
    # largest where the S^2 mask/softmax chain is a big fraction of the
    # work — exactly the small-head smoke regime this gate runs in.  At
    # fatter heads the ratio compresses on CPU; the Pallas realization's
    # int8 MXU dots are the production (TPU) story.
    n = 2 if fast else 3
    lengths = (512, 2048) if fast else (512, 2048, 8192, 32768)
    rows = []
    for S in lengths:
        rows.append(_case_rows(S, heads=4, hd=32, window=None, n=n))
        rows.append(_case_rows(S, heads=4, hd=32, window=min(1024, S // 2),
                               n=n))
    os.makedirs("results", exist_ok=True)
    with open("results/bench_attn.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows


def main():
    import sys

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for r in attn_rows(fast=fast):
        extra = {k: v for k, v in r.items() if k != "name"}
        print(f"{r['name']},{r['flash_us']},{json.dumps(extra)}")
    print("# full rows -> results/bench_attn.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
