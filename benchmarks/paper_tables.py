"""Benchmarks reproducing the paper's tables/figures (one fn per artifact).

Each returns (rows, derived) where rows are CSV-able dicts; `benchmarks.run`
aggregates and prints ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / n * 1e6  # us


# --- Table I: accuracy & complexity vs bit-width ---------------------------

def table1_accuracy(steps: int = 120, train: bool = True):
    """Closed-form complexity columns (exact) + synthetic-SVHN accuracy
    ordering across the paper's W:I configs."""
    from repro.core.quant import PAPER_CONFIGS
    from repro.data.synthetic import svhn_like
    from repro.models.cnn import cnn_forward, cnn_loss, init_cnn, svhn_cnn_spec
    from repro.train.optimizer import OptConfig, apply_updates, init_opt_state

    rows = []
    spec = svhn_cnn_spec(8)
    for name, q in PAPER_CONFIGS.items():
        row = dict(config=name, w=q.w_bits, i=q.a_bits,
                   complexity_inference=q.inference_complexity
                   if q.w_bits < 32 else 0,
                   complexity_training=q.training_complexity
                   if q.w_bits < 32 else 0)
        if train:
            params, _ = init_cnn(jax.random.PRNGKey(0), spec)
            ocfg = OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
            ost = init_opt_state(params, ocfg)

            @jax.jit
            def step(params, ost, batch):
                (loss, m), g = jax.value_and_grad(
                    lambda p: cnn_loss(p, batch, spec, q),
                    has_aux=True)(params)
                params, ost, _ = apply_updates(params, g, ost, ocfg)
                return params, ost, m

            for i in range(steps):
                x, y = svhn_like(32, seed=1000 + i)
                params, ost, m = step(params, ost, dict(
                    image=jnp.asarray(x), label=jnp.asarray(y)))
            x, y = svhn_like(512, seed=77)
            logits = cnn_forward(params, jnp.asarray(x), spec, q, "train")
            row["test_error_pct"] = round(
                100 * (1 - float(jnp.mean(jnp.argmax(logits, -1) ==
                                          jnp.asarray(y)))), 2)
        rows.append(row)
    return rows


# --- Fig. 8: storage --------------------------------------------------------

def fig8_storage():
    from repro.core.quant import model_storage_bits
    from repro.models.cnn import (alexnet_spec, count_acts, count_params,
                                  svhn_cnn_spec)
    rows = []
    spec = svhn_cnn_spec(20)
    p, a = count_params(spec), count_acts(spec, 40)
    base = model_storage_bits(p, a, 32, 32)
    for (w, i) in [(32, 32), (1, 1), (1, 4), (1, 8), (2, 2)]:
        bits = model_storage_bits(p, a, w, i)
        rows.append(dict(model="svhn_cnn", w=w, i=i, mbytes=round(bits / 8e6, 2),
                         reduction_vs_fp32=round(base / bits, 1)))
    ap_, aa = count_params(alexnet_spec()), count_acts(alexnet_spec(), 224)
    for (w, i) in [(64, 64), (32, 32), (1, 1)]:
        bits = model_storage_bits(ap_, aa, w, i)
        rows.append(dict(model="alexnet", w=w, i=i, mbytes=round(bits / 8e6, 1),
                         reduction_vs_fp32=round(
                             model_storage_bits(ap_, aa, 32, 32) / bits, 1)))
    return rows


# --- Fig. 9 / Fig. 10 / Table II: energy & throughput ----------------------

def fig9_energy():
    from repro.api import reports as A
    out = []
    for (w, i) in [(1, 1), (1, 4), (1, 8), (2, 2)]:
        for design in ("proposed", "imce", "reram", "asic"):
            r = A.simulate(design, "imagenet", i, w)
            out.append(dict(design=design, w=w, i=i,
                            energy_uj=round(r["energy_uj"], 1),
                            gops_per_w=round(r["gops_per_w"], 1),
                            eff_per_mm2=round(r["eff_per_mm2"], 2)))
    return out


def fig10_performance():
    from repro.api import reports as A
    out = []
    for design in ("proposed", "imce", "reram", "asic"):
        r = A.simulate(design, "imagenet", 1, 1)
        out.append(dict(design=design, fps=round(r["fps"], 1),
                        fps_per_mm2=round(r["fps_per_mm2"], 2),
                        latency_us=round(r["latency_us"], 1)))
    return out


def table2_energy_area():
    from repro.api import reports as A
    t2 = A.table2()
    rows = []
    for d, cols in t2.items():
        for ds, v in cols.items():
            paper_e, paper_a = A.TABLE2[d][ds]
            rows.append(dict(design=d, dataset=ds,
                             energy_uj=round(v["energy_uj"], 2),
                             paper_energy_uj=paper_e,
                             area_mm2=v["area_mm2"], paper_area_mm2=paper_a))
    return rows


def api_claims():
    """Headline-claims report through the public repro.api surface: ONE
    compiled plan per dataset, priced on every PIM target, ratios next to
    the paper's abstract numbers (the PR-5 acceptance row)."""
    from repro.api import reports as A
    rows = []
    for ds in ("imagenet", "svhn"):
        rows += A.paper_claims(dataset=ds)
    return rows


# --- Intermittency (Fig. 7 story) -------------------------------------------

def intermittency_study():
    from repro.pim.intermittent import sweep_checkpoint_period
    rows = []
    for mtbf in (50.0, 500.0, 5000.0):
        res = sweep_checkpoint_period(mtbf_us=mtbf)
        for period, r in res.items():
            rows.append(dict(mtbf_us=mtbf, checkpoint_period=period,
                             completed=r["completed_frames"],
                             efficiency=round(r["efficiency"], 3),
                             failures=r["failures"]))
    return rows


# --- Kernel microbenchmarks (CPU interpret timings; structural only) --------

def kernel_bench():
    from repro.core.quant import activation_levels, weight_levels
    from repro.kernels import ops
    rows = []
    a = jax.random.uniform(jax.random.PRNGKey(0), (256, 1024))
    w = jax.random.normal(jax.random.PRNGKey(1), (1024, 256))
    al, _ = activation_levels(a, 4)
    wl, _, _ = weight_levels(w, 1)
    for name, fn in [
        ("bitgemm_mxu_w1a4", lambda: ops.bitgemm_mxu(al, wl, 4, 1)),
        ("bitgemm_faithful_w1a4", lambda: ops.bitgemm_faithful(al, wl, 4, 1)),
        ("quantize_pack_a4", lambda: ops.quantize_pack(a, 4)),
    ]:
        us = _time(lambda: jax.block_until_ready(fn()), n=3)
        rows.append(dict(kernel=name, us_per_call=round(us, 1)))
    return rows
