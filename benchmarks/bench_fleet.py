"""Fleet study: co-designed plans vs one-config-fits-all under harvest traces.

Simulates a heterogeneous fleet of energy-harvesting nodes (solar / RF /
thermal archetypes, ``repro.fleet.traces``) for one day each, prices every
node with its compiled plan's Table-II cost on its PIM target, and runs the
per-node co-design search (``repro.fleet.search``): pick each node's
(quant, target, checkpoint period) to maximize inferences/day subject to
its accuracy SLO.  Reported against the best single fleet-wide config.

Three CI gates (enforced in every mode; ``--fast`` shrinks the fleet):

  * determinism — the entire seeded study runs TWICE and the serialized
    aggregate reports must match bit-for-bit (same seed -> same bytes);
  * validation — one node's derived outage schedule replays through a REAL
    ``ResilientServeEngine`` and the simulator's engine-accounting mirror
    must agree: integer work counters exactly, float accounting within
    1e-6 (the DESIGN.md §14 contract);
  * co-design win — aggregate inferences/day must beat the baseline while
    every node meets its SLO.

Run standalone::

    PYTHONPATH=src python benchmarks/bench_fleet.py [--fast]

or via ``benchmarks/run.py`` (job name ``fleet_study``).  Full results ->
``results/bench_fleet.json``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

SEED = 0          # fleet trace seed
SLO_SEED = 1      # per-node accuracy-SLO draw
RESUME_US = 26_000.0   # post-outage plan reload (cf. plan_resume_study)

# smoke-LM replay geometry (matches bench_resilience's serving story)
N_REQUESTS = 8
NEW_TOKENS = 7
EPOCH_STEPS = 2
MAX_BATCH = 4
VALIDATE_OUTAGES = 6
TOL = 1e-6


def _study(n_nodes: int):
    """One full seeded study; pure function of (n_nodes, SEED, SLO_SEED)."""
    from repro.fleet import (assign_slos, codesign, fleet_report,
                             frame_cost_table, generate_fleet, make_trace)

    specs = generate_fleet(n_nodes, seed=SEED)
    traces = [make_trace(s) for s in specs]
    slos = assign_slos(n_nodes, seed=SLO_SEED)
    costs = frame_cost_table()
    out = codesign(traces, slos, costs=costs,
                   node_kw=dict(resume_us=RESUME_US))
    results = out.pop("results")
    fleet = fleet_report(results, specs)
    report = dict(
        config=dict(n_nodes=n_nodes, seed=SEED, slo_seed=SLO_SEED,
                    resume_us=RESUME_US),
        fleet=fleet,
        codesign=dict(
            inferences_per_day=out["inferences_per_day"],
            baseline=out["baseline"],
            win_vs_baseline=out["win_vs_baseline"],
            slo_violations=out["slo_violations"],
            pareto=out["pareto"],
            candidates=out["candidates"]),
    )
    return report, specs, traces, out["assignments"], results


def _validate(traces, assignments, results):
    """Replay the busiest node's outage schedule through the live engine."""
    from repro.fleet import (NodeConfig, epoch_schedule, frame_cost_table,
                             live_validation, rescale_outages, simulate_node)

    # the node with the most outages gives the densest replay schedule
    idx = max(range(len(results)), key=lambda i: results[i]["failures"])
    a = assignments[idx]
    e, lat = frame_cost_table(quants=(a["quant"],),
                              targets=(a["target"],))[(a["quant"],
                                                       a["target"])]
    cfg = NodeConfig(node_id=a["node_id"], quant=a["quant"],
                     target=a["target"], period=a["period"],
                     frame_energy_uj=e, frame_time_us=lat,
                     resume_us=RESUME_US)
    r = simulate_node(traces[idx], cfg, collect_outages=VALIDATE_OUTAGES)
    outages = r["outage_frames"]
    # compress the day-scale schedule onto ~80% of the replay's fault-free
    # work so the kills land mid-decode, not all at t=0
    engine_work = 0.8 * (-(-N_REQUESTS // MAX_BATCH)) * (
        0.25 + 1.0 + sum(epoch_schedule(NEW_TOKENS, EPOCH_STEPS)))
    sched = (rescale_outages(outages, outages[-1], engine_work)
             if outages else [])
    ckdir = tempfile.mkdtemp(prefix="fleet_val_")
    try:
        v = live_validation(sched, checkpoint_dir=ckdir,
                            n_requests=N_REQUESTS, new_tokens=NEW_TOKENS,
                            epoch_steps=EPOCH_STEPS, max_batch=MAX_BATCH,
                            tol=TOL)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    v["node_id"] = a["node_id"]
    v["replayed_outages"] = len(sched)
    return v


def fleet_rows(fast: bool = False):
    n_nodes = 64 if fast else 1000
    report, specs, traces, assignments, results = _study(n_nodes)

    # determinism gate: same seed -> bit-for-bit identical report bytes
    report2 = _study(n_nodes)[0]
    blob = json.dumps(report, sort_keys=True)
    deterministic = blob == json.dumps(report2, sort_keys=True)
    report["determinism"] = dict(ok=deterministic, runs_compared=2)

    validation = _validate(traces, assignments, results)
    report["validation"] = validation
    report["assignments"] = assignments

    os.makedirs("results", exist_ok=True)
    with open("results/bench_fleet.json", "w") as f:
        json.dump(report, f, indent=1, default=str)

    cd, fl = report["codesign"], report["fleet"]
    rows = [dict(name="fleet_aggregate", **fl_no_arch(fl)),
            *[dict(name=f"fleet_{k}", **v)
              for k, v in sorted(fl.get("archetypes", {}).items())],
            dict(name="fleet_codesign",
                 inferences_per_day=cd["inferences_per_day"],
                 baseline_inferences_per_day=cd["baseline"][
                     "inferences_per_day"],
                 baseline=f"{cd['baseline']['quant']}/"
                          f"{cd['baseline']['target']}/"
                          f"P{cd['baseline']['period']}",
                 win_vs_baseline=round(cd["win_vs_baseline"], 4),
                 slo_violations=cd["slo_violations"],
                 pareto_points=len(cd["pareto"])),
            dict(name="fleet_validation", ok=validation["ok"],
                 node_id=validation["node_id"],
                 replayed_outages=validation["replayed_outages"],
                 efficiency_predicted=validation["efficiency_predicted"],
                 efficiency_measured=validation["efficiency_measured"],
                 tol=validation["tol"]),
            dict(name="fleet_determinism", ok=deterministic,
                 runs_compared=2)]

    gates = dict(determinism=deterministic, validation=validation["ok"],
                 win=cd["win_vs_baseline"] > 1.0,
                 slo=cd["slo_violations"] == 0)
    if not all(gates.values()):
        raise SystemExit(f"fleet gate failed: {gates}")
    return rows


def fl_no_arch(fl: dict) -> dict:
    return {k: v for k, v in fl.items() if k != "archetypes"}


def main():
    import sys

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for r in fleet_rows(fast=fast):
        key = r.get("inferences_per_day", r.get("ok", 0))
        extra = {k: v for k, v in r.items() if k != "name"}
        print(f"{r['name']},{key},{json.dumps(extra)}")
    print("# full rows -> results/bench_fleet.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
