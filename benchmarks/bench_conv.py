"""Layer-level conv engine benchmark: implicit-GEMM vs patch-GEMM vs seed.

Three dataflows per quantized layer of the paper's CNNs:

  ``seed``      float weights re-quantized per call, f32 im2col patches,
                hardwired int8 GEMM — the seed serve path (frozen here as
                the baseline; ``core/conv_lowering.quant_conv2d`` keeps it
                runnable);
  ``gemm``      PR-1 fused pipeline: pre-quantized weights, integer
                ``im2col_sliced`` patches, backend-dispatched qGEMM —
                patches still materialize in HBM (kh*kw x read blowup);
  ``implicit``  this PR: in-register patch extraction, zero patch bytes
                (Pallas implicit-GEMM sweep on TPU, exact direct conv
                off-TPU).

Also reports the traffic accounting the §II-A sub-array mapping is about:
``patch_bytes_gemm`` (what im2col writes+rereads) vs ``input_bytes``
(what the implicit sweep reads once) — ``patch_byte_reduction`` is their
ratio, ~kh*kw for stride-1 convs.

Emits ``name,us_per_call,derived`` CSV plus ``results/bench_conv.json``::

    PYTHONPATH=src python benchmarks/bench_conv.py [--fast]

or via ``benchmarks/run.py`` (job name ``conv_implicit``).
"""
from __future__ import annotations

import json
import os
import time

import jax


def _timeit(fn, *args, n: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def _conv_oh(s, h: int) -> int:
    from repro.core.conv_lowering import _out_hw

    pad = "VALID" if (s.fc or s.k == 1) else "SAME"
    return max(_out_hw(h, h, s.k, s.k, s.stride, pad)[0], 1)


def layer_shapes(spec, img: int):
    """Replay cnn_forward's spatial bookkeeping: input (h, w) per layer."""
    h = img
    shapes = []
    for s in spec:
        if s.fc and s.k > 1 and h != s.k:
            h = s.k
        shapes.append(h)
        h = _conv_oh(s, h)
        if s.pool:
            h //= 2
    return shapes


def _layer_rows(name, spec, img: int, batch: int, quant, n: int):
    from repro.core.conv_lowering import quant_conv2d, quant_conv2d_pre
    from repro.core.prequant import is_fp_layer, level_dtype
    from repro.kernels.ops import ConvShape, select_engine
    from repro.core.prequant import prequantize_cnn_params
    from repro.models.cnn import init_cnn

    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    serve_params = prequantize_cnn_params(params, spec, quant)
    itemsize = jax.numpy.zeros((), level_dtype(quant.a_bits)).dtype.itemsize

    rows = []
    for i, (s, h) in enumerate(zip(spec, layer_shapes(spec, img))):
        if is_fp_layer(s, quant):
            continue
        pad = "VALID" if (s.fc or s.k == 1) else "SAME"
        xi = jax.random.uniform(jax.random.PRNGKey(i), (batch, h, h, s.cin))
        p, sp = params[i], serve_params[i]
        oh = _conv_oh(s, h)
        shape = ConvShape(h, h, s.k, s.k, s.stride, pad, batch=batch)
        kdim = s.k * s.k * s.cin
        gemm_engine = select_engine(batch * oh * oh, kdim, s.cout,
                                    quant.a_bits, quant.w_bits)  # no conv geo
        auto_engine = select_engine(batch * oh * oh, kdim, s.cout,
                                    quant.a_bits, quant.w_bits, conv=shape)
        common = dict(kh=s.k, kw=s.k, stride=s.stride, padding=pad,
                      a_bits=quant.a_bits, w_bits=quant.w_bits)
        seed_us = _timeit(
            lambda: quant_conv2d(xi, p["w"], stride=s.stride, padding=pad,
                                 a_bits=quant.a_bits, w_bits=quant.w_bits,
                                 engine="int8"), n=n)
        gemm_us = _timeit(
            lambda: quant_conv2d_pre(xi, sp["w_lv"], sp["s_w"], sp["z_w"],
                                     engine=gemm_engine, **common), n=n)
        row = dict(
            name=f"{name}_L{i}", kind="layer", shape=f"{h}x{h}x{s.cin}",
            k=s.k, stride=s.stride, cout=s.cout, engine=auto_engine,
            seed_us=round(seed_us), gemm_us=round(gemm_us),
            patch_bytes_gemm=batch * oh * oh * kdim * itemsize,
            input_bytes=batch * h * h * s.cin * itemsize)
        if auto_engine == "implicit" or (
                s.k > 1 and s.stride in (1, 2)):
            impl_us = _timeit(
                lambda: quant_conv2d_pre(xi, sp["w_lv"], sp["s_w"],
                                         sp["z_w"], engine="implicit",
                                         **common), n=n)
            row.update(
                implicit_us=round(impl_us),
                patch_bytes_implicit=0,
                patch_byte_reduction=round(
                    row["patch_bytes_gemm"] / row["input_bytes"], 1),
                speedup_vs_seed=round(seed_us / impl_us, 2),
                speedup_vs_gemm=round(gemm_us / impl_us, 2))
        rows.append(row)
    return rows


def crossover_rows(fast: bool = False):
    """B>1 crossover validation for the batch-aware dispatcher (PR 3).

    The serving engine dispatches co-batched buckets, so ``select_engine``
    sees ``ConvShape.batch > 1``; these rows measure implicit vs patch-GEMM
    at batch 1/2/8 on layers straddling the single-image threshold and
    record whether the batch-scaled bound picked the faster engine.
    """
    import jax

    from repro.core.conv_lowering import quant_conv2d_pre
    from repro.core.prequant import prequantize_conv_weight
    from repro.kernels.ops import ConvShape, select_engine

    n = 2 if fast else 5
    layers = [(10, 32, 64, 3), (5, 64, 64, 3)]
    if not fast:
        layers += [(20, 32, 32, 3)]
    rows = []
    for (h, cin, cout, k) in layers:
        w = jax.random.normal(jax.random.PRNGKey(0), (k, k, cin, cout))
        w_lv, s_w, z_w = prequantize_conv_weight(w, 1)
        for batch in (1, 2, 8):
            x = jax.random.uniform(jax.random.PRNGKey(1), (batch, h, h, cin))
            common = dict(kh=k, kw=k, stride=1, padding="SAME",
                          a_bits=4, w_bits=1)
            gemm_us = _timeit(lambda: quant_conv2d_pre(
                x, w_lv, s_w, z_w, engine="f32dot", **common), n=n)
            impl_us = _timeit(lambda: quant_conv2d_pre(
                x, w_lv, s_w, z_w, engine="implicit", **common), n=n)
            shape = ConvShape(h, h, k, k, 1, "SAME", batch=batch)
            pick = select_engine(shape.m, k * k * cin, cout, 4, 1, conv=shape)
            rows.append(dict(
                name=f"crossover_{h}x{h}x{cin}_B{batch}", kind="crossover",
                batch=batch, m_amp=round(shape.m * shape.read_amplification),
                gemm_us=round(gemm_us), implicit_us=round(impl_us),
                picked=pick,
                picked_faster=bool((impl_us < gemm_us)
                                   == (pick == "implicit"))))
    return rows


def conv_rows(fast: bool = False):
    from repro.core.quant import W1A4, W1A8
    from repro.models.cnn import alexnet_spec, svhn_cnn_spec

    n = 2 if fast else 5
    rows = _layer_rows("svhn_cnn", svhn_cnn_spec(32 if fast else 64), 40,
                       2, W1A4, n)
    if not fast:
        rows += _layer_rows("alexnet", alexnet_spec(), 112, 1, W1A8, n)
    rows += crossover_rows(fast=fast)
    os.makedirs("results", exist_ok=True)
    with open("results/bench_conv.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows


def main():
    import sys

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for r in conv_rows(fast=fast):
        us = r.get("implicit_us", r["gemm_us"])
        extra = {k: v for k, v in r.items() if k not in ("name",)}
        print(f"{r['name']},{us},{json.dumps(extra)}")
    print("# full rows -> results/bench_conv.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
