"""Chaos sweep: forward-progress efficiency on the REAL serving engine.

The analytic intermittency model (``pim/intermittent.forward_progress``,
paper Fig. 7) predicts how much useful work survives random power failures
as a function of MTBF and checkpoint period P.  This benchmark measures
the same quantity on the executing stack: a
:class:`repro.resilience.ResilientServeEngine` serving LM generate
requests under a seeded exponential :class:`~repro.resilience.FaultPlan`,
with the scanned decode segmented into K-step epochs committed through the
atomic checkpointer (K = P).  Both curves land side by side in
``results/bench_resilience.json``.

Units: the engine's fault clock counts **decode steps** ("frames"); one
bucket's sequence is ``new_tokens - 1`` frames.  Measured efficiency is
useful steps over total charged work (executed + wasted partial windows +
prefill/restore restarts + checkpoint writes priced in step units, from
the measured commit/step wall-time ratio); the analytic arm runs
``forward_progress`` on the identical (MTBF, P) grid with the same
measured ``nv_write`` cost, averaged over one seed per served bucket.

Hard assertions (the CI chaos gate, ``--fast``):
  * every completed request under chaos is bit-identical to the fault-free
    run at the same checkpoint period (same composition, same programs);
  * no dead letters anywhere in the sweep (retries are effectively
    unbounded there);
  * at the HIGHEST fault rate, a bounded-retry engine with a pre-compiled
    lower-bit fallback plan degrades instead of dead-lettering: the paper's
    accuracy-for-progress trade, executed.

Run standalone::

    PYTHONPATH=src python benchmarks/bench_resilience.py [--fast]

or via ``benchmarks/run.py`` (job name ``resilience``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time

import numpy as np

PROMPT_LEN = 8
NEW_TOKENS = 9            # 8 decode steps = 8 "frames" per bucket sequence
MAX_BATCH = 4


def _build(fast: bool):
    import jax

    from repro.configs import SINGLE, all_configs
    from repro.core.plan import compile_lm
    from repro.core.quant import PAPER_CONFIGS
    from repro.models import transformer as T

    cfg = dataclasses.replace(
        all_configs()["smollm-360m"].smoke(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab=64, head_dim=32),
        quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    plan8 = compile_lm(params, cfg, batch_hints=(1, MAX_BATCH),
                       prompt_len=PROMPT_LEN)
    cfg4 = dataclasses.replace(cfg, quant=PAPER_CONFIGS["w1a4"])
    plan4 = compile_lm(params, cfg4, batch_hints=(1, MAX_BATCH),
                       prompt_len=PROMPT_LEN)
    n_req = 8 if fast else 16
    prompts = [np.random.RandomState(i).randint(0, cfg.vocab,
                                                size=(PROMPT_LEN,))
               .astype(np.int32) for i in range(n_req)]
    return cfg, cfg4, plan8, plan4, prompts


def _engine(cfg, plan, k: int, ckdir, **kw):
    from repro.resilience import EpochLMRunner, ResilientServeEngine

    runner = EpochLMRunner(None, cfg, new_tokens=NEW_TOKENS,
                           epoch_steps=(k if k else 1), model_plan=plan)
    return ResilientServeEngine(runner, checkpoint_dir=ckdir,
                                max_batch=MAX_BATCH, **kw)


def _reset(eng, fault_plan) -> None:
    """Point one warmed engine (hot jit cache) at a fresh chaos run."""
    from repro.resilience import FaultPlan

    eng.faults = fault_plan if fault_plan is not None else FaultPlan(None)
    for key in eng.stats:
        eng.stats[key] = 0.0 if isinstance(eng.stats[key], float) else 0
    eng.dead_letters.clear()
    eng.result_runner.clear()
    eng._attempts.clear()
    eng._retry.clear()
    if eng._active:              # undo a previous run's degrade swap
        eng._active = 0
        eng._energy_scale = 1.0
        eng.runner = eng._runners[0]
        import jax

        eng._params = jax.device_put(eng.runner.params)
    if eng.policy is not None:
        eng.policy.reset()
    if eng.ckpt is not None:
        eng.ckpt.purge_all()


def _run(eng, prompts, fault_plan):
    _reset(eng, fault_plan)
    t0 = time.perf_counter()
    results = eng.serve(list(prompts))
    wall = time.perf_counter() - t0
    return results, wall


def _measured_efficiency(stats, nv_write_steps: float) -> float:
    """Useful frames over total charged work, in decode-step units.

    executed_steps already contains every re-executed (lost) epoch;
    wasted_steps adds the partial window each kill destroyed; commits
    charge the measured NV-write cost.  Restarts (extra prefills/restores
    beyond each completed bucket's one) charge one frame each — matching
    the analytic model's ``resume_us``, which is likewise only paid after
    a failure."""
    restarts = max(0.0, stats["prefills"] + stats["resumes"]
                   - stats["dispatches"])
    total = (stats["executed_steps"] + stats["wasted_steps"] + restarts
             + nv_write_steps * stats["commits"])
    return stats["useful_steps"] / total if total else 0.0


def resilience_rows(fast: bool = False) -> list:
    from repro.pim.intermittent import forward_progress
    from repro.resilience import DegradePolicy, FaultPlan

    cfg, cfg4, plan8, plan4, prompts = _build(fast)
    frames = NEW_TOKENS - 1
    n_buckets = len(prompts) // MAX_BATCH
    mtbfs = (16.0, 48.0) if fast else (8.0, 16.0, 32.0, 64.0)
    periods = (0, 2, 4) if fast else (0, 1, 2, 4)
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    rows = []
    mismatches = dead = 0
    try:
        # one engine per checkpoint period: different K = different scan
        # programs (its own jit cache, its own fault-free reference — bit
        # identity is a same-program property)
        step_us = nv_write_steps = None
        for k in periods:
            ckdir = os.path.join(root, f"k{k}") if k else None
            eng = _engine(cfg, plan8, k, ckdir, max_retries=10_000)
            _run(eng, prompts, None)                   # warm the jit cache
            ref_res, wall = _run(eng, prompts, None)   # fault-free reference
            # rids keep incrementing across runs of one engine: results come
            # back rid-sorted = submission-ordered, so compare by position
            ref = [r.value for r in ref_res]
            s = eng.stats
            if k and nv_write_steps is None:
                # price one NV commit in decode-step units, from the warmed
                # fault-free run (same numbers feed the analytic arm)
                step_us = ((wall - s["commit_s"]) * 1e6
                           / (s["executed_steps"] + s["prefills"]))
                commit_us = s["commit_s"] * 1e6 / s["commits"]
                nv_write_steps = commit_us / step_us
            for mtbf in mtbfs:
                res, _ = _run(eng, prompts, FaultPlan(mtbf, seed=17))
                got = [r.value for r in res]
                bit_identical = (len(got) == len(ref) and all(
                    np.array_equal(g, r) for g, r in zip(got, ref)))
                mismatches += not bit_identical
                dead += len(eng.dead_letters)
                measured = _measured_efficiency(eng.stats,
                                                nv_write_steps or 0.0)
                # the measured arm is ONE seeded realization over n_buckets
                # sequences; the analytic arm reports the model expectation
                # (32 seeds) on the same (MTBF, P, nv_write) point
                analytic = float(np.mean([
                    forward_progress(
                        n_frames=frames, frame_time_us=1.0, mtbf_us=mtbf,
                        checkpoint_period_frames=k,
                        nv_write_us=nv_write_steps or 0.0, resume_us=1.0,
                        seed=100 * i + 7)["efficiency"]
                    for i in range(32)]))
                rows.append(dict(
                    name=f"resilience_mtbf{mtbf:g}_k{k}", kind="chaos",
                    mtbf_steps=mtbf, checkpoint_period=k,
                    n_requests=len(prompts),
                    measured_efficiency=round(measured, 4),
                    analytic_efficiency=round(analytic, 4),
                    bit_identical=bit_identical,
                    dead_letters=len(eng.dead_letters),
                    faults=eng.stats["faults"],
                    retries=eng.stats["retries"],
                    resumes=eng.stats["resumes"],
                    commits=eng.stats["commits"],
                    executed_steps=eng.stats["executed_steps"],
                    useful_steps=eng.stats["useful_steps"],
                    wasted_steps=round(eng.stats["wasted_steps"], 2)))

        # degraded-plan fallback at the benchmark's highest fault rate
        # (harsher than any sweep cell): bounded retries would dead-letter
        # on the w1a8 plan alone; after the degrade swap the w1a4 fallback
        # sees a ~1.6x longer energy-MTBF per step and must keep serving
        # with NO dead letters (ISSUE acceptance criterion)
        worst = 4.0
        from repro.resilience import EpochLMRunner

        fb = EpochLMRunner(None, cfg4, new_tokens=NEW_TOKENS, epoch_steps=2,
                           model_plan=plan4)
        deg = _engine(cfg, plan8, 2, os.path.join(root, "deg"),
                      max_retries=5, fallbacks=(fb,),
                      degrade=DegradePolicy(fault_window=4,
                                            fault_threshold=2))
        _run(deg, prompts, None)                       # warm
        res, _ = _run(deg, prompts, FaultPlan(worst, seed=23))
        rows.append(dict(
            name="resilience_degrade", kind="degrade", mtbf_steps=worst,
            checkpoint_period=2, n_requests=len(prompts),
            completed=len(res), degrades=deg.stats["degrades"],
            faults=deg.stats["faults"],
            dead_letters=len(deg.dead_letters),
            served_by_fallback=sum(v == 1
                                   for v in deg.result_runner.values()),
            energy_pj=round(deg.stats["energy_pj"], 1)))
        degrade_ok = (len(res) == len(prompts) and not deg.dead_letters
                      and deg.stats["degrades"] >= 1)
        rows.append(dict(
            name="resilience_summary", kind="summary",
            step_us=round(step_us or 0.0, 2),
            nv_write_steps=round(nv_write_steps or 0.0, 4),
            bit_identity_mismatches=mismatches,
            sweep_dead_letters=dead, degrade_ok=degrade_ok))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    os.makedirs("results", exist_ok=True)
    with open("results/bench_resilience.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if fast and (mismatches or dead or not degrade_ok):
        raise SystemExit(
            f"chaos gate failed: {mismatches} bit-identity mismatches, "
            f"{dead} dead letters in sweep, degrade_ok={degrade_ok}")
    return rows


def main():
    import sys

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for r in resilience_rows(fast=fast):
        us = r.get("measured_efficiency", r.get("degrades", 0))
        extra = {k: v for k, v in r.items() if k != "name"}
        print(f"{r['name']},{us},{json.dumps(extra)}")
    print("# full rows -> results/bench_resilience.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
