"""Serve-path benchmark: end-to-end CNN forward + transformer decode.

CNN e2e compares three dataflows (layer-level numbers live in
``bench_conv.py``):

  ``base``      frozen replica of the seed serve forward — float weights
                re-quantized by ``weight_levels`` every call, f32 im2col
                patches, hardwired ``engine="int8"`` GEMM, separate
                rowsum/epilogue pass;
  ``gemm``      PR-1 pipeline: pre-quantized (``core/prequant``) weights,
                integer ``im2col_sliced`` patches, dispatched qGEMM
                (patches still materialize in HBM);
  ``fused``     this PR's auto dispatch — deep-K spatial convs route to
                the implicit-GEMM engine (no patch bytes), the rest to the
                PR-1 engines.

Transformer decode compares the seed per-token Python loop (one jitted
step re-dispatched from the host, argmax synced per token) against the
``lax.scan`` generate in ``repro.launch.serve`` — cold (incl. compile) and
warm reported separately.

Emits the repo's ``name,us_per_call,derived`` CSV plus
``results/bench_serve.json``.  Run standalone::

    PYTHONPATH=src python benchmarks/bench_serve.py [--fast]

or via ``benchmarks/run.py`` (job name ``serve_fused``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from bench_conv import _conv_oh, _timeit, layer_shapes


# ---------------------------------------------------------------------------
# CNN end-to-end
# ---------------------------------------------------------------------------

def _seed_forward(params, x, spec, quant):
    """The seed serve dataflow, frozen as the benchmark baseline: per-call
    ``weight_levels`` + f32 ``conv_general_dilated_patches`` im2col +
    ``engine="int8"`` GEMM (``quant_conv2d``), with the same norm/pool
    structure as ``cnn_forward``."""
    from repro.core.conv_lowering import conv2d_float, quant_conv2d
    from repro.core.prequant import is_fp_layer
    from repro.models.cnn import _norm_act

    h = x
    for i, (p, s) in enumerate(zip(params, spec)):
        pad = "VALID" if (s.fc or s.k == 1) else "SAME"
        if s.fc and s.k > 1 and h.shape[1] != s.k:
            h = jax.image.resize(h, (h.shape[0], s.k, s.k, h.shape[3]),
                                 "linear")
        if is_fp_layer(s, quant):
            h = conv2d_float(h, p["w"], stride=s.stride, padding=pad)
        else:
            h = quant_conv2d(h, p["w"], stride=s.stride, padding=pad,
                             a_bits=quant.a_bits, w_bits=quant.w_bits,
                             engine="int8")
        h = h + p["b"]
        if i < len(spec) - 1:
            h = _norm_act(h, p["g"], p["beta"], quant, s.role)
        if s.pool:
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    return jnp.mean(h, axis=(1, 2))


def _arch_rows(name, spec, img: int, batch: int, quant, n: int):
    from repro.core.plan import compile_model
    from repro.core.prequant import is_fp_layer, level_dtype, serve_weight_bytes
    from repro.models.cnn import cnn_forward, init_cnn

    auto_quant = dataclasses.replace(quant, engine="auto")
    # the PR-1 engine pick with the conv-aware (implicit) dispatch masked:
    # f32dot is what select_engine returns off-TPU for every layer here
    gemm_quant = dataclasses.replace(quant, engine="f32dot")
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    serve_params = compile_model(params, spec, auto_quant, img_hw=img,
                                 batch_hints=(batch,), model=name).params
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, img, img, 3))

    base_fwd = jax.jit(lambda x: _seed_forward(params, x, spec, quant))
    gemm_fwd = jax.jit(
        lambda x: cnn_forward(serve_params, x, spec, gemm_quant, "serve"))
    auto_fwd = jax.jit(
        lambda x: cnn_forward(serve_params, x, spec, auto_quant, "serve"))
    base_us = _timeit(base_fwd, x, n=n)
    gemm_us = _timeit(gemm_fwd, x, n=n)
    auto_us = _timeit(auto_fwd, x, n=n)

    lvl = jax.numpy.zeros((), level_dtype(quant.a_bits)).dtype.itemsize
    q_layers = [(s, h) for s, h in zip(spec, layer_shapes(spec, img))
                if not is_fp_layer(s, quant)]
    patch_elems = sum(batch * _conv_oh(s, h) ** 2 * s.k * s.k * s.cin
                      for s, h in q_layers)
    # patches that STILL materialize under auto dispatch: only layers the
    # dispatcher keeps on a GEMM engine contribute (implicit-routed layers
    # materialize zero patch bytes)
    from repro.kernels.ops import ConvShape, select_engine
    residual_patch_elems = sum(
        batch * _conv_oh(s, h) ** 2 * s.k * s.k * s.cin
        for s, h in q_layers
        if select_engine(
            batch * _conv_oh(s, h) ** 2, s.k * s.k * s.cin, s.cout,
            quant.a_bits, quant.w_bits,
            conv=ConvShape(h, h, s.k, s.k, s.stride,
                           "VALID" if (s.fc or s.k == 1) else "SAME",
                           batch=batch),
        ) != "implicit")
    return [dict(
        name=f"{name}_e2e", kind="e2e", batch=batch, img=img,
        quant=quant.tag(),
        base_us=round(base_us), gemm_us=round(gemm_us),
        fused_us=round(auto_us),
        speedup=round(base_us / auto_us, 2),
        speedup_vs_gemm=round(gemm_us / auto_us, 2),
        weight_bytes_fp32=serve_weight_bytes(params),
        weight_bytes_prequant=serve_weight_bytes(serve_params),
        # materialized patch traffic: f32 seed -> integer PR-1 -> residual
        # under auto dispatch (implicit-routed layers contribute zero)
        patch_bytes_f32=4 * patch_elems,
        patch_bytes_prequant=lvl * patch_elems,
        patch_bytes_auto_residual=lvl * residual_patch_elems,
        patch_byte_reduction=round(
            lvl * patch_elems / max(lvl * residual_patch_elems, 1), 1),
        hbm_passes_unfused=3, hbm_passes_fused=1)]


# ---------------------------------------------------------------------------
# Plan cache: cold compile+autotune vs warm plan-load (compile amortization)
# ---------------------------------------------------------------------------

def plan_rows(fast: bool = False):
    """Compile-once amortization row (ModelPlan, ``repro.core.plan``).

    ``cold`` = compile_model with measured autotune + first jitted
    dispatch; ``warm`` = load_plan from disk (requantization + autotune
    skipped — the restarted-node / intermittency-resume path) + first
    jitted dispatch in a fresh jit cache.  The plan JSON lands in
    ``results/plan_svhn_cnn.json`` so the trajectory captures both the
    artifact and the amortization, and the measured costs feed the paper's
    Fig.-7-style resume study (``pim/intermittent.plan_resume_study``).
    """
    import numpy as np

    from repro.core.plan import (compile_model, load_plan, plan_forward,
                                 save_plan)
    from repro.core.quant import W1A4
    from repro.kernels import ops
    from repro.models.cnn import init_cnn, svhn_cnn_spec
    from repro.pim.intermittent import plan_resume_study

    spec = svhn_cnn_spec(8 if fast else 20)
    batch, img = 4, 40
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, img, img, 3))
    os.makedirs("results", exist_ok=True)
    base = "results/plan_svhn_cnn"
    for ext in (".json", ".npz"):
        if os.path.exists(base + ext):
            os.remove(base + ext)
    ops.clear_plan_state()  # measure a genuinely cold compile

    t0 = time.perf_counter()
    plan = compile_model(params, spec, W1A4, batch_hints=(1, batch),
                         img_hw=img, autotune=True, model="svhn_cnn")
    compile_s = time.perf_counter() - t0
    save_plan(plan, base)
    t0 = time.perf_counter()
    cold_fwd = jax.jit(lambda v: plan_forward(plan, v))
    cold_out = np.asarray(cold_fwd(x))
    cold_dispatch_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    plan2 = load_plan(base)
    load_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm_fwd = jax.jit(lambda v: plan_forward(plan2, v))  # fresh jit cache
    warm_out = np.asarray(warm_fwd(x))
    warm_dispatch_s = time.perf_counter() - t0

    # resume study at an MTBF where replanning is *possible* but costly
    # (mtbf ~ 3x the compile cost), so both arms report a real efficiency
    study = plan_resume_study(compile_us=compile_s * 1e6,
                              plan_load_us=load_s * 1e6,
                              mtbf_us=3 * compile_s * 1e6,
                              frame_time_us=compile_s * 1e5)
    return [dict(
        name="plan_cache", kind="plan", batch=batch, img=img, quant="w1a4",
        plan_file=base + ".json", fingerprint=plan.fingerprint(),
        engines={lp.name: lp.engine for lp in plan.layers},
        compile_autotune_us=round(compile_s * 1e6),
        plan_load_us=round(load_s * 1e6),
        cold_e2e_us=round((compile_s + cold_dispatch_s) * 1e6),
        warm_e2e_us=round((load_s + warm_dispatch_s) * 1e6),
        amortization=round((compile_s + cold_dispatch_s)
                           / max(load_s + warm_dispatch_s, 1e-9), 1),
        reload_bit_identical=bool(np.array_equal(cold_out, warm_out)),
        resume_efficiency_recompile=round(study["recompile"]["efficiency"], 4),
        resume_efficiency_plan_reload=round(
            study["plan_reload"]["efficiency"], 4))]


# ---------------------------------------------------------------------------
# Transformer decode: python-loop (seed) vs lax.scan generate
# ---------------------------------------------------------------------------

def _loop_decode(params, cfg, plan, prompts, new_tokens: int, qmode: str,
                 prefill=None, step=None):
    """The seed decode: host loop re-dispatching one jitted step per token,
    with a device->host argmax sync in between.  Pass pre-built ``prefill``
    / ``step`` so the warm measurement reuses the jit cache (like a
    long-lived server would); the prefill is jitted the same way as the
    scan path's, so warm loop-vs-scan isolates the DECODE dispatch gap.
    The argmax uses the same real-vocab mask as the scan path (the row
    compares dispatch strategies; vocab policy must not differ)."""
    from repro.launch.serve import greedy_token, grow_cache, make_prefill
    from repro.models import transformer as T

    B, S_p = prompts.shape
    prefill = prefill or make_prefill(params, cfg, plan, qmode)
    step = step or jax.jit(
        lambda c, t, p: T.decode_step(params, c, t, p, cfg, plan,
                                      qmode=qmode))
    t0 = time.perf_counter()
    logits, cache = prefill(prompts)
    cache = grow_cache(cache, S_p, S_p + new_tokens)
    tok = greedy_token(logits, cfg.vocab)
    toks = [tok]
    for t in range(new_tokens - 1):
        lg, cache = step(cache, tok, S_p + t)
        tok = greedy_token(lg, cfg.vocab)
        toks.append(tok)
    gen = jnp.concatenate(toks, axis=1)
    jax.block_until_ready(gen)
    return gen, time.perf_counter() - t0, prefill, step


def decode_rows(fast: bool = False):
    from repro.configs import SINGLE, get_config
    from repro.core.quant import PAPER_CONFIGS
    from repro.data.synthetic import lm_batch
    from repro.launch.serve import make_generate, make_prefill, serve_once
    from repro.models import transformer as T

    cfg = dataclasses.replace(get_config("phi3-mini-3.8b").smoke(),
                              quant=PAPER_CONFIGS["w1a8"])
    qmode = "serve"
    B, S_p, S_d = 2, 8, 8 if fast else 16
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    loop_gen, loop_cold, pf, step = _loop_decode(params, cfg, SINGLE,
                                                 prompts, S_d, qmode)
    _, loop_warm, _, _ = _loop_decode(params, cfg, SINGLE, prompts, S_d,
                                      qmode, prefill=pf, step=step)

    prefill_fn = make_prefill(params, cfg, SINGLE, qmode)
    generate_fn = make_generate(params, cfg, SINGLE, qmode, S_p, S_d)
    scan_gen, scan_cold = serve_once(params, cfg, SINGLE, prompts, S_d,
                                     qmode, prefill_fn, generate_fn)
    _, scan_warm = serve_once(params, cfg, SINGLE, prompts, S_d, qmode,
                              prefill_fn, generate_fn)
    # the two paths are separately compiled float programs, so an argmax
    # near-tie can legitimately flip a token (ulp-level logit reordering);
    # report the comparison instead of asserting it so the --strict CI
    # gate cannot flake on it
    tokens_match = bool((jnp.asarray(scan_gen) == jnp.asarray(loop_gen)).all())
    return [dict(
        name="decode_scan", kind="decode", arch=cfg.name, batch=B,
        prompt_len=S_p, new_tokens=S_d, quant="w1a8",
        tokens_match_loop=tokens_match,
        loop_cold_us=round(loop_cold * 1e6),
        loop_warm_us=round(loop_warm * 1e6),
        scan_cold_us=round(scan_cold * 1e6),
        scan_warm_us=round(scan_warm * 1e6),
        tok_s_cold=round(B * S_d / scan_cold, 1),
        tok_s_warm=round(B * S_d / scan_warm, 1),
        warm_speedup=round(loop_warm / scan_warm, 2))]


# ---------------------------------------------------------------------------
# Request-level throughput: the serving engine under load (PR 3)
# ---------------------------------------------------------------------------



def throughput_rows(fast: bool = False):
    """Offered-load sweep through ``repro.launch.engine.ServeEngine``.

    Per workload (CNN serve forward, LM generate):
      * ``seq_rps``      closed-loop requests/s with ``max_batch=1`` — the
                         sequential per-request dispatch baseline;
      * ``batch8_rps``   closed-loop with ``max_batch=8`` (coalesced
                         dispatch; identical per-request outputs);
      * an offered-rate sweep at the batched setting, reporting achieved
        requests/s and p50/p99 latency (queueing included) per rate.
    """
    import numpy as np

    from repro.core.plan import compile_model
    from repro.core.quant import PAPER_CONFIGS, W1A4
    from repro.launch.engine import (CNNRunner, LMRunner, ServeEngine,
                                     run_offered_load)
    from repro.models import transformer as T
    from repro.models.cnn import init_cnn, svhn_cnn_spec

    n_req = 24 if fast else 48
    rows = []

    # CNN workload: 40x40 svhn images through the plan-compiled serve
    # forward (engines pinned per layer at compile time)
    spec = svhn_cnn_spec(8)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    cnn_plan = compile_model(params, spec, W1A4, img_hw=40,
                             batch_hints=(1, 8), model="svhn_throughput")
    imgs = [np.random.RandomState(i).uniform(size=(40, 40, 3))
            .astype(np.float32) for i in range(n_req)]

    # max_pending=16 keeps the queue bound real at over-subscribed rates:
    # the sweep's 2x/4x points actually hit QueueFull and go through
    # ServeEngine.submit_retry (bounded backoff) instead of a queue that
    # never fills at these request counts
    def cnn_engine(max_batch):
        return lambda: ServeEngine(CNNRunner(None, spec, None, plan=cnn_plan),
                                   max_batch=max_batch,
                                   flush_deadline_s=0.002, max_pending=16)

    # LM workload: prefill + scanned greedy decode per request, projection
    # engines resolved once into the plan's dense verdict table
    from repro.core.plan import compile_lm

    cfg = dataclasses.replace(get_smoke_lm(), quant=PAPER_CONFIGS["w1a8"])
    lparams, _ = T.init_lm(jax.random.PRNGKey(0), cfg, _single_plan())
    lm_plan = compile_lm(lparams, cfg, batch_hints=(1, 8), prompt_len=8)
    prompts = [np.random.RandomState(i).randint(0, cfg.vocab, size=(8,))
               .astype(np.int32) for i in range(n_req)]

    def lm_engine(max_batch):
        return lambda: ServeEngine(
            LMRunner(None, cfg, new_tokens=8, qmode="serve",
                     model_plan=lm_plan),
            max_batch=max_batch, flush_deadline_s=0.002, max_pending=16)

    from repro.launch.engine import warm_engine

    for name, payloads, mk in (("cnn_svhn", imgs, cnn_engine),
                               ("lm_decode", prompts, lm_engine)):
        seq = run_offered_load(warm_engine(mk(1)(), payloads), payloads,
                               rate_rps=None)
        bat_eng = warm_engine(mk(8)(), payloads)
        bat = run_offered_load(bat_eng, payloads, rate_rps=None)
        row = dict(name=f"throughput_{name}", kind="throughput",
                   n_requests=len(payloads),
                   seq_rps=seq["achieved_rps"], seq_p50_ms=seq["p50_ms"],
                   batch8_rps=bat["achieved_rps"],
                   batch8_p50_ms=bat["p50_ms"],
                   batch8_p99_ms=bat["p99_ms"],
                   mean_batch=bat["mean_batch"],
                   speedup_batch8=round(bat["achieved_rps"]
                                        / max(seq["achieved_rps"], 1e-9), 2))
        # offered-load sweep around the sequential capacity: under-, at-,
        # and over-subscribed (the engine's batching headroom shows up as
        # sustained rps above seq capacity with bounded p99).  One warmed
        # engine serves every rate — the jit cache is the server's.
        sweep = []
        for mult in ((0.5, 2.0) if fast else (0.5, 1.0, 2.0, 4.0)):
            sweep.append(run_offered_load(bat_eng, payloads,
                                          rate_rps=mult * seq["achieved_rps"]))
        row["offered_sweep"] = sweep
        rows.append(row)
    return rows


def continuous_rows(fast: bool = False):
    """Continuous batching vs bucket dispatch on a MIXED prompt/horizon mix.

    The bucket engine fragments a mixed-length workload into one closed
    bucket per (prompt-len, horizon) shape — short requests wait on long
    scans (head-of-line blocking) and ragged buckets pad.  The continuous
    engine admits at step granularity into a persistent paged-KV decode
    batch, so the headline comparison is p99 latency + achieved req/s on
    the same offered load.  Also gates (returned, asserted by the CI fast
    lane via ``--continuous``):

      * decode bit-identity: the batched continuous run's tokens equal a
        fresh continuous engine serving the same requests one at a time;
      * jit-program bounding: the whole replay compiles exactly three
        programs (prefill chunk, decode step, page reset);
      * PV108: the LM plan compiles with the paged geometry declared, so
        the prover has proven the page-table addressing feasible.
    """
    import numpy as np

    from repro.core.plan import compile_lm
    from repro.core.quant import PAPER_CONFIGS
    from repro.launch.engine import (ContinuousLMEngine, LMRunner,
                                     ServeEngine, run_offered_load,
                                     warm_engine)
    from repro.models import transformer as T

    cfg = dataclasses.replace(get_smoke_lm(), quant=PAPER_CONFIGS["w1a8"])
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, _single_plan())
    num_slots, page_size, max_seq = 4, 4, 32
    kv_pages = max_seq // page_size
    num_pages = 32 if fast else 64
    n_req = 16 if fast else 64
    lens = (4, 8) if fast else (4, 8, 16)
    gens = (4, 8) if fast else (4, 8, 16)
    # PV108 coverage: the plan declares the paged geometry, so compile-time
    # verification (verify=True default) proves the page-table bounds
    lm_plan = compile_lm(params, cfg, batch_hints=(1, num_slots),
                         prompt_len=max(lens), page_size=page_size,
                         kv_pages=kv_pages)

    rng = np.random.RandomState(0)
    payloads = [
        (rng.randint(0, cfg.vocab,
                     size=(int(rng.choice(lens)),)).astype(np.int32),
         int(rng.choice(gens)))
        for _ in range(n_req)]

    def mk_cont():
        return ContinuousLMEngine(
            params, cfg, num_slots=num_slots, page_size=page_size,
            num_pages=num_pages, max_seq=max_seq, new_tokens=max(gens),
            qmode="serve", model_plan=lm_plan, max_pending=max(16, n_req))

    def mk_bucket():
        return ServeEngine(
            LMRunner(None, cfg, new_tokens=max(gens), qmode="serve",
                     model_plan=lm_plan),
            max_batch=num_slots, flush_deadline_s=0.002,
            max_pending=max(16, n_req))

    # -- restart arm: a FRESH server meets the mixed mix (empty jit cache).
    # The bucket engine compiles one scan program per (prompt-len, horizon,
    # padded-batch) combination it dispatches — the mix's combinatorics land
    # in its p99 — where the continuous engine compiles its three programs
    # and is done.  This is the bounded-jit-cache claim measured, and the
    # arm a power-intermittent node actually lives in.
    restart_b = run_offered_load(mk_bucket(), payloads, rate_rps=None)
    restart_c = run_offered_load(mk_cont(), payloads, rate_rps=None)

    # -- warm steady-state arm: every program either engine can dispatch is
    # pre-compiled.  warm_engine only covers the first payload's shape key;
    # a mixed mix dispatches every (key, padded-size) combination, and any
    # cold compile inside a measured run would be charged to the bucket arm
    bucket = warm_engine(mk_bucket(), payloads)
    by_key = {}
    for p in payloads:
        by_key.setdefault(bucket.runner.shape_key(p), p)
    for p in by_key.values():
        n_pad = 1
        while n_pad <= num_slots:
            bucket.serve([p] * n_pad)
            n_pad *= 2
    cont = warm_engine(mk_cont(), payloads)
    rb = run_offered_load(bucket, payloads, rate_rps=None)
    rc = run_offered_load(cont, payloads, rate_rps=None)

    # decode bit-identity: batched continuous == one-request-at-a-time
    # continuous (same chunk schedule, per-slot-independent numerics)
    seq_eng = mk_cont()
    seq_vals = []
    for p in payloads:
        seq_vals.extend(r.value for r in seq_eng.serve([p]))
    batch_res = mk_cont().serve(list(payloads))
    bit_identical = (len(batch_res) == len(seq_vals) and all(
        np.array_equal(r.value, v) for r, v in zip(batch_res, seq_vals)))

    # mixed offered-load sweep at the same rates through both engines —
    # the headline p99/req/s comparison
    sweep = []
    for mult in ((0.5, 2.0) if fast else (0.5, 1.0, 2.0, 4.0)):
        rate = mult * rb["achieved_rps"]
        sweep.append(dict(
            bucket=run_offered_load(bucket, payloads, rate_rps=rate),
            continuous=run_offered_load(cont, payloads, rate_rps=rate)))

    return [dict(
        name="continuous_lm", kind="continuous", n_requests=n_req,
        prompt_lens=list(lens), horizons=list(gens), slots=num_slots,
        page_size=page_size, num_pages=num_pages,
        # headline: the restart arm — req/s and p99 while the jit cache
        # fills.  The bucket engine's per-(shape, padded-size) compile
        # storm is its p99; the continuous engine's three programs are
        # done after the first requests
        restart_bucket_rps=restart_b["achieved_rps"],
        restart_bucket_p99_ms=restart_b["p99_ms"],
        restart_continuous_rps=restart_c["achieved_rps"],
        restart_continuous_p99_ms=restart_c["p99_ms"],
        restart_speedup_rps=round(restart_c["achieved_rps"]
                                  / max(restart_b["achieved_rps"], 1e-9), 2),
        restart_p99_improvement=round(restart_b["p99_ms"]
                                      / max(restart_c["p99_ms"], 1e-9), 2),
        # warm steady state.  At smoke scale on CPU the bucket engine's
        # fused whole-generation scan amortizes host dispatch across the
        # horizon while the continuous engine pays one host sync per
        # decode step, so the warm crossover needs per-step compute large
        # enough to swamp dispatch (accelerator-scale models); the
        # structural wins that survive every scale are the bounded jit
        # cache (restart arm) and paged KV occupancy (pool stats)
        warm_bucket_rps=rb["achieved_rps"], warm_bucket_p50_ms=rb["p50_ms"],
        warm_bucket_p99_ms=rb["p99_ms"],
        warm_continuous_rps=rc["achieved_rps"],
        warm_continuous_p50_ms=rc["p50_ms"],
        warm_continuous_p99_ms=rc["p99_ms"],
        warm_continuous_queue_p99_ms=rc["queue_p99_ms"],
        warm_continuous_service_p99_ms=rc["service_p99_ms"],
        bit_identical_vs_sequential=bool(bit_identical),
        jit_programs=sorted(str(p) for p in cont.program_shapes),
        n_jit_programs=len(cont.program_shapes),
        pool=cont.pool.stats(),
        plan_fingerprint=lm_plan.fingerprint(),
        offered_sweep=sweep)]


def get_smoke_lm():
    from repro.configs import all_configs

    return all_configs()["smollm-360m"].smoke(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab=64, head_dim=32)


def _single_plan():
    from repro.configs import SINGLE

    return SINGLE


def serve_rows(fast: bool = False):
    from repro.core.quant import W1A4, W1A8
    from repro.models.cnn import alexnet_spec, svhn_cnn_spec

    # e2e latencies are tens of ms; n=8 keeps scheduler noise out of the
    # speedup ratios (n=3 flipped signs run-to-run on a busy host)
    n = 2 if fast else 8
    rows = _arch_rows("svhn_cnn", svhn_cnn_spec(32 if fast else 64), 40,
                      2, W1A4, n)
    if not fast:
        rows += _arch_rows("alexnet", alexnet_spec(), 112, 1, W1A8, n)
    rows += plan_rows(fast=fast)
    rows += decode_rows(fast=fast)
    rows += throughput_rows(fast=fast)
    rows += continuous_rows(fast=fast)
    os.makedirs("results", exist_ok=True)
    with open("results/bench_serve.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows


def main():
    import sys

    fast = "--fast" in sys.argv
    if "--continuous" in sys.argv:
        # CI fast lane: only the continuous-vs-bucket comparison, with the
        # decode bit-identity gate as the exit code (a mismatch means the
        # paged path's numerics drifted from the sequential reference)
        rows = continuous_rows(fast=fast)
        os.makedirs("results", exist_ok=True)
        with open("results/bench_serve_continuous.json", "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print("name,us_per_call,derived")
        for r in rows:
            extra = {k: v for k, v in r.items() if k not in ("name",)}
            print(f"{r['name']},{r['restart_speedup_rps']},{json.dumps(extra)}")
        print("# full rows -> results/bench_serve_continuous.json",
              file=sys.stderr)
        if not all(r["bit_identical_vs_sequential"] for r in rows):
            print("FAIL: continuous decode is not bit-identical to the "
                  "sequential reference", file=sys.stderr)
            sys.exit(1)
        return
    print("name,us_per_call,derived")
    for r in serve_rows(fast=fast):
        us = r.get("fused_us", r.get("scan_warm_us",
                                     r.get("warm_e2e_us",
                                           r.get("batch8_rps",
                                                 r.get("restart_speedup_rps")))))
        extra = {k: v for k, v in r.items() if k not in ("name",)}
        print(f"{r['name']},{us},{json.dumps(extra)}")
    print("# full rows -> results/bench_serve.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
