"""Serve-path benchmark: fused + pre-quantized pipeline vs the seed path.

Baseline is the seed ``cnn_forward(mode="serve")`` dataflow: float weights
re-quantized by ``weight_levels`` on every call, f32 im2col patches, the
hardwired ``engine="int8"`` GEMM, and a separate rowsum/epilogue pass.
The optimized path serves from ``prepare_serve_params`` (weights quantized
once at load) through the backend-dispatched engine
(``repro.kernels.ops.select_engine``; fused Pallas on TPU, exact f32 GEMM
on CPU).

Emits the repo's ``name,us_per_call,derived`` CSV plus
``results/bench_serve.json``.  Run standalone::

    PYTHONPATH=src python benchmarks/bench_serve.py [--fast]

or via ``benchmarks/run.py`` (job name ``serve_fused``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp


def _timeit(fn, *args, n: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def _conv_oh(s, h: int) -> int:
    from repro.core.conv_lowering import _out_hw

    pad = "VALID" if (s.fc or s.k == 1) else "SAME"
    return max(_out_hw(h, h, s.k, s.k, s.stride, pad)[0], 1)


def _layer_shapes(spec, img: int):
    """Replay cnn_forward's spatial bookkeeping: input (h, w) per layer."""
    h = img
    shapes = []
    for s in spec:
        if s.fc and s.k > 1 and h != s.k:
            h = s.k
        shapes.append(h)
        h = _conv_oh(s, h)
        if s.pool:
            h //= 2
    return shapes


def _arch_rows(name, spec, img: int, batch: int, quant, per_layer: bool, n: int):
    from repro.core.conv_lowering import quant_conv2d, quant_conv2d_pre
    from repro.core.prequant import is_fp_layer, serve_weight_bytes
    from repro.kernels.ops import select_engine
    from repro.models.cnn import cnn_forward, init_cnn, prepare_serve_params

    seed_quant = dataclasses.replace(quant, engine="int8")   # seed serve path
    auto_quant = dataclasses.replace(quant, engine="auto")
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)
    serve_params = prepare_serve_params(params, spec, auto_quant)
    x = jax.random.uniform(jax.random.PRNGKey(1), (batch, img, img, 3))

    rows = []
    if per_layer:
        for i, (s, h) in enumerate(zip(spec, _layer_shapes(spec, img))):
            if is_fp_layer(s, quant):
                continue
            pad = "VALID" if (s.fc or s.k == 1) else "SAME"
            xi = jax.random.uniform(jax.random.PRNGKey(i), (batch, h, h, s.cin))
            p, sp = params[i], serve_params[i]
            base_us = _timeit(
                lambda xi=xi, p=p, s=s, pad=pad: quant_conv2d(
                    xi, p["w"], stride=s.stride, padding=pad,
                    a_bits=quant.a_bits, w_bits=quant.w_bits, engine="int8"),
                n=n)
            pre_us = _timeit(
                lambda xi=xi, sp=sp, s=s, pad=pad: quant_conv2d_pre(
                    xi, sp["w_lv"], sp["s_w"], sp["z_w"], kh=s.k, kw=s.k,
                    stride=s.stride, padding=pad, a_bits=quant.a_bits,
                    w_bits=quant.w_bits),
                n=n)
            oh = _conv_oh(s, h)
            eng = select_engine(batch * oh * oh, s.k * s.k * s.cin, s.cout,
                                quant.a_bits, quant.w_bits)
            rows.append(dict(
                name=f"{name}_L{i}", kind="layer", shape=f"{h}x{h}x{s.cin}",
                k=s.k, cout=s.cout, engine=eng,
                base_us=round(base_us), fused_us=round(pre_us),
                speedup=round(base_us / pre_us, 2)))

    base_fwd = jax.jit(
        lambda x: cnn_forward(params, x, spec, seed_quant, "serve"))
    fused_fwd = jax.jit(
        lambda x: cnn_forward(serve_params, x, spec, auto_quant, "serve"))
    base_us = _timeit(base_fwd, x, n=n)
    fused_us = _timeit(fused_fwd, x, n=n)
    n_q = sum(0 if is_fp_layer(s, quant) else 1 for s in spec)
    f32_patch_bytes = sum(
        4 * batch * _conv_oh(s, h) ** 2 * s.k * s.k * s.cin
        for s, h in zip(spec, _layer_shapes(spec, img))
        if not is_fp_layer(s, quant))
    rows.append(dict(
        name=f"{name}_e2e", kind="e2e", batch=batch, img=img, quant=quant.tag(),
        base_us=round(base_us), fused_us=round(fused_us),
        speedup=round(base_us / fused_us, 2),
        # eliminated per-call work (the fusion accounting, DESIGN.md §2.3)
        weight_levels_calls_eliminated=n_q,
        weight_bytes_fp32=serve_weight_bytes(params),
        weight_bytes_prequant=serve_weight_bytes(serve_params),
        patch_bytes_f32=f32_patch_bytes,
        # int8 levels for a_bits <= 7; 8-bit activations stay int32-wide
        patch_bytes_prequant=(f32_patch_bytes // 4 if quant.a_bits <= 7
                              else f32_patch_bytes),
        # passes over the activation tile per layer: quantize(+pack), GEMM,
        # rowsum+epilogue unfused -> 1 fused pallas_call on TPU
        hbm_passes_unfused=3, hbm_passes_fused=1))
    return rows


def serve_rows(fast: bool = False, per_layer: bool = True):
    from repro.core.quant import W1A4, W1A8
    from repro.models.cnn import alexnet_spec, svhn_cnn_spec

    n = 2 if fast else 3
    rows = _arch_rows("svhn_cnn", svhn_cnn_spec(32 if fast else 64), 40,
                      2, W1A4, per_layer, n)
    if not fast:
        rows += _arch_rows("alexnet", alexnet_spec(), 112, 1, W1A8,
                           per_layer=False, n=n)
    os.makedirs("results", exist_ok=True)
    with open("results/bench_serve.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows


def main():
    import sys

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for r in serve_rows(fast=fast):
        extra = {k: v for k, v in r.items() if k not in ("name", "fused_us")}
        print(f"{r['name']},{r['fused_us']},{json.dumps(extra)}")
    print("# full rows -> results/bench_serve.json", file=sys.stderr)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
