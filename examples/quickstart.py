"""Quickstart: train a small LM with the paper's AND-Accumulation quantized
projections (W1A8) on synthetic data, CPU-runnable in ~a minute, then take
the trained checkpoint through the public facade — ``repro.api.build``,
``.compile()`` (weights pre-quantized once, engines pinned), ``.serve()``
— and decode a few tokens with it.

  PYTHONPATH=src python examples/quickstart.py [--steps 60] [--quant]
"""
import argparse
import dataclasses
import sys

import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.core.quant import W1A8
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--quant", action="store_true",
                    help="use the paper's W1A8 bit-wise projections")
    args = ap.parse_args()

    cfg = get_config("smollm-360m").smoke(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256, head_dim=32)
    if args.quant:
        cfg = dataclasses.replace(cfg, quant=W1A8)
    mesh = make_host_mesh()
    tr = Trainer(cfg, SINGLE, mesh,
                 OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
                 TrainConfig(steps=args.steps, log_every=10))
    bf = lambda s, m: {k: jnp.asarray(v) for k, v in
                       lm_batch(s, m, batch=8, seq=32, vocab=256,
                                seed=0).items()}
    hist = tr.run(bf)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")
    if args.quant:
        serve_with_plan(tr.params, cfg)
    return 0 if last < first else 1


def serve_with_plan(params, cfg):
    """Compile-once serving through the public facade (repro.api):
    build -> compile (projections quantized + engines resolved ONCE) ->
    serve (request-level engine on the compiled plan)."""
    import time

    import numpy as np

    from repro import api

    compiled = api.build(cfg, params=params).compile(batch_hints=(2,),
                                                     prompt_len=8)
    engine = compiled.serve(max_batch=2, new_tokens=8)
    prompts = [np.asarray(p) for p in
               lm_batch(0, 0, batch=2, seq=8, vocab=cfg.vocab)["tokens"]]
    t0 = time.perf_counter()
    gen = engine.predict(prompts)
    dt = time.perf_counter() - t0
    print(f"plan-served 2x8 tokens in {dt:.2f}s "
          f"(fingerprint {compiled.fingerprint()}): {list(map(int, gen[0]))}")


if __name__ == "__main__":
    sys.exit(main())
