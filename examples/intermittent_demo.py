"""Power-intermittency resilience demo (the paper's headline system story).

Trains a small model while injecting power failures mid-gradient-
accumulation; the NV-FA-style snapshot mechanism resumes mid-step and the
final weights are BIT-IDENTICAL to an uninterrupted run.

  PYTHONPATH=src python examples/intermittent_demo.py
"""
import shutil
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SINGLE, get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as T
from repro.train.checkpoint import Checkpointer
from repro.train.intermittent import (IntermittentConfig, IntermittentTrainer,
                                      run_with_failures)
from repro.train.optimizer import OptConfig

VOCAB = 64


def main():
    cfg = get_config("smollm-360m").smoke(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
        vocab=VOCAB, head_dim=32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg, SINGLE)
    loss_fn = lambda p, b: T.lm_loss(p, b, cfg, SINGLE)
    batch_fn = lambda s, m: {k: jnp.asarray(v) for k, v in
                             lm_batch(s, m, batch=4, seq=16, vocab=VOCAB,
                                      seed=7).items()}
    icfg = IntermittentConfig(accum_steps=4, snapshot_every=2, full_every=2)

    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        golden = IntermittentTrainer(loss_fn, params, OptConfig(lr=1e-3),
                                     batch_fn, Checkpointer(d1, async_save=False),
                                     icfg)
        golden.train(4)
        print("golden run: 4 steps, no failures")

        fails = {(1, 3), (2, 1), (3, 2)}
        print(f"  injecting power failures at {sorted(fails)}")

        def make():
            return IntermittentTrainer(loss_fn, params, OptConfig(lr=1e-3),
                                       batch_fn,
                                       Checkpointer(d2, async_save=False),
                                       icfg, fail_at=fails)

        trainer, _, restarts = run_with_failures(make, 4)
        print(f"chaotic run: 4 steps with {restarts} power failures + restarts")

        for a, b in zip(jax.tree.leaves(golden.params),
                        jax.tree.leaves(trainer.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("RESULT: final weights are bit-identical — forward progress "
              "maintained across power failures (paper §II-B3, TPU-adapted)")
        return 0
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
