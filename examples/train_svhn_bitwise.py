"""End-to-end driver: train the paper's bit-wise CNN on synthetic SVHN at a
chosen W:I bit configuration, with NV-FA-style intermittent checkpointing.

Reproduces the Table I experiment shape (accuracy vs bit-width) at
CPU-tractable scale:

  PYTHONPATH=src python examples/train_svhn_bitwise.py --config w1a4 --steps 150
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import svhn_like
from repro.models.cnn import cnn_forward, cnn_loss, init_cnn, svhn_cnn_spec
from repro.train.checkpoint import Checkpointer
from repro.train.intermittent import IntermittentConfig, IntermittentTrainer
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="w1a4", choices=list(PAPER_CONFIGS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--channels", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/svhn_bitwise_ckpt")
    args = ap.parse_args()

    quant = PAPER_CONFIGS[args.config]
    spec = svhn_cnn_spec(args.channels)
    params, _ = init_cnn(jax.random.PRNGKey(0), spec)

    def loss_fn(p, batch):
        return cnn_loss(p, batch, spec, quant)

    def batch_fn(step, micro):
        x, y = svhn_like(32, seed=step * 31 + micro)
        return dict(image=jnp.asarray(x), label=jnp.asarray(y))

    tr = IntermittentTrainer(
        loss_fn, params, OptConfig(lr=3e-3, warmup_steps=10,
                                   total_steps=args.steps),
        batch_fn, Checkpointer(args.ckpt_dir, async_save=False),
        IntermittentConfig(accum_steps=2, snapshot_every=1, full_every=25))
    tr.restore()  # resume if a checkpoint exists (power-failure resilience)
    print(f"training {args.config} from step {tr.step} ...")
    while tr.step < args.steps:
        m = tr._run_step()
        if tr.step % 25 == 0:
            print(f"  step {tr.step}: loss={m['loss']:.4f}")
            tr.save_full()

    x, y = svhn_like(512, seed=99)
    logits = cnn_forward(tr.params, jnp.asarray(x), spec, quant, "train")
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
    # serve-mode (integer AND-Accumulation engine) consistency check via the
    # public facade: compile the checkpoint into a plan and execute it
    from repro import api

    compiled = api.build(spec, quant, params=tr.params,
                         img_hw=x.shape[1]).compile()
    logits_s = compiled.forward(jnp.asarray(x[:64]))
    acc_s = float(jnp.mean(jnp.argmax(logits_s, -1) == jnp.asarray(y[:64])))
    print(f"{args.config}: test acc={acc:.3f} (error {100*(1-acc):.1f}%), "
          f"integer-engine acc={acc_s:.3f} "
          f"(plan {compiled.fingerprint()})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
