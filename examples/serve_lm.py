"""Batched serving example: prefill a batch of prompts, then decode with the
KV cache (greedy), optionally with the integer AND-Accumulation engine.

  PYTHONPATH=src python examples/serve_lm.py --new-tokens 16
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)
    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    # ---- prefill ----
    logits, cache = T.prefill(params, cfg, SINGLE, tokens=prompts)
    slots = S_p + S_d
    # widen the prefill cache to the decode horizon
    cache = jax.tree.map(
        lambda t: jnp.pad(t, [(0, 0), (0, 0), (0, slots - t.shape[2])]
                          + [(0, 0)] * (t.ndim - 3))
        if t.ndim >= 3 and t.shape[2] == S_p else t, cache)
    for kind in cache:
        if "pos" in cache[kind]:
            cache[kind]["pos"] = jnp.where(
                jnp.arange(slots)[None, None, :] < S_p,
                cache[kind]["pos"], -1)

    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    step = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg, SINGLE))

    out = [tok]
    for t in range(S_d - 1):
        lg, cache = step(cache, tok, S_p + t)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    for b in range(B):
        print(f"prompt[{b}]: {list(map(int, prompts[b][-8:]))} ... "
              f"generated: {list(map(int, gen[b]))}")
    assert gen.shape == (B, S_d)
    print("serve OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
