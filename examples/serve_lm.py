"""Batched serving example: prefill a batch of prompts, then decode with the
KV cache (greedy), optionally with the integer AND-Accumulation engine.

  PYTHONPATH=src python examples/serve_lm.py --new-tokens 16
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)
    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    # ---- prefill ----
    from repro.launch.serve import greedy_token, widen_cache

    logits, cache = T.prefill(params, cfg, SINGLE, tokens=prompts)
    # widen the prefill cache to the decode horizon (structural: only the
    # attention k/v/pos entries grow — see launch/serve.widen_cache)
    cache = widen_cache(cache, S_p, S_p + S_d)

    tok = greedy_token(logits, cfg.vocab)
    step = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg, SINGLE))

    out = [tok]
    for t in range(S_d - 1):
        lg, cache = step(cache, tok, S_p + t)
        tok = greedy_token(lg, cfg.vocab)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    for b in range(B):
        print(f"prompt[{b}]: {list(map(int, prompts[b][-8:]))} ... "
              f"generated: {list(map(int, gen[b]))}")
    assert gen.shape == (B, S_d)
    print("serve OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
