"""Batched serving example on the plan API: compile a ModelPlan once
(projection weights pre-quantized, engine verdicts pinned), optionally
persist it, then prefill + greedy decode with the KV cache.

  PYTHONPATH=src python examples/serve_lm.py --new-tokens 16 \
      [--quant w1a8] [--plan-cache /tmp/lmplan]

With ``--plan-cache``, a second run reloads the plan from disk and skips
requantization + engine resolution — the restarted-node fast path.
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import SINGLE, get_config
from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default="w1a8", choices=list(PAPER_CONFIGS))
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="persist/reload the compiled ModelPlan")
    args = ap.parse_args()

    import dataclasses

    cfg = dataclasses.replace(get_config(args.arch).smoke(),
                              quant=PAPER_CONFIGS[args.quant])
    qmode = "serve" if args.quant != "w32a32" else "train"
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)

    # ---- compile (or reload) the execution plan ----
    from repro.core.plan import (check_plan_matches, compile_lm, load_plan,
                                 plan_exists, save_plan)

    if args.plan_cache and plan_exists(args.plan_cache):
        plan = check_plan_matches(load_plan(args.plan_cache),
                                  quant=cfg.quant, model=cfg.name)
        print(f"plan: reloaded {args.plan_cache} "
              f"(fingerprint {plan.fingerprint()}) — no requantization")
    else:
        plan = compile_lm(params, cfg, batch_hints=(args.batch,),
                          prompt_len=args.prompt_len)
        if args.plan_cache:
            json_path = save_plan(plan, args.plan_cache)
            print(f"plan: compiled and saved -> {json_path}")
    params = plan.params
    plan.install()  # dense GEMM dispatch becomes a plan-table lookup

    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = jnp.asarray(
        lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"])

    # ---- prefill ----
    from repro.launch.serve import greedy_token, widen_cache

    logits, cache = T.prefill(params, cfg, SINGLE, tokens=prompts,
                              qmode=qmode)
    # widen the prefill cache to the decode horizon (structural: only the
    # attention k/v/pos entries grow — see launch/serve.widen_cache)
    cache = widen_cache(cache, S_p, S_p + S_d)

    tok = greedy_token(logits, cfg.vocab)
    step = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg,
                                                 SINGLE, qmode=qmode))

    out = [tok]
    for t in range(S_d - 1):
        lg, cache = step(cache, tok, S_p + t)
        tok = greedy_token(lg, cfg.vocab)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    for b in range(B):
        print(f"prompt[{b}]: {list(map(int, prompts[b][-8:]))} ... "
              f"generated: {list(map(int, gen[b]))}")
    assert gen.shape == (B, S_d)
    print("serve OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
