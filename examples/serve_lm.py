"""Batched serving example on the public facade (``repro.api``): build a
session, compile a ModelPlan once (projection weights pre-quantized,
engine verdicts pinned), optionally persist it, then serve batched greedy
decodes through the request-level engine.

  PYTHONPATH=src python examples/serve_lm.py --new-tokens 16 \
      [--quant w1a8] [--plan-cache /tmp/lmplan]

With ``--plan-cache``, a second run reloads the plan from disk and skips
requantization + engine resolution — the restarted-node fast path.
"""
import argparse
import dataclasses
import sys

import jax
import numpy as np

from repro import api
from repro.configs import SINGLE, get_config
from repro.core.quant import PAPER_CONFIGS
from repro.data.synthetic import lm_batch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--quant", default="w1a8", choices=list(PAPER_CONFIGS))
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="persist/reload the compiled ModelPlan")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).smoke(),
                              quant=PAPER_CONFIGS[args.quant])
    key = jax.random.PRNGKey(0)
    params, _ = T.init_lm(key, cfg, SINGLE)

    # ---- session: build -> compile (or reload) the execution plan ----
    compiled = api.build(cfg, params=params).compile(
        batch_hints=(args.batch,), prompt_len=args.prompt_len,
        cache=args.plan_cache)
    if compiled.reloaded:
        print(f"plan: reloaded {args.plan_cache} "
              f"(fingerprint {compiled.fingerprint()}) — no requantization")
    elif compiled.cache_path:
        print(f"plan: compiled and saved -> {compiled.cache_path}")
    compiled.plan.install()  # dense GEMM dispatch becomes a plan-table lookup

    # ---- serve: request-level engine over the compiled plan ----
    B, S_p, S_d = args.batch, args.prompt_len, args.new_tokens
    prompts = [np.asarray(p) for p in
               lm_batch(0, 0, batch=B, seq=S_p, vocab=cfg.vocab)["tokens"]]
    engine = compiled.serve(max_batch=B, new_tokens=S_d)
    gen = engine.predict(prompts)
    for b in range(B):
        print(f"prompt[{b}]: {list(map(int, prompts[b][-8:]))} ... "
              f"generated: {list(map(int, gen[b]))}")
    assert all(g.shape == (S_d,) for g in gen)
    print(f"serve OK ({engine.stats['dispatches']} dispatch(es), "
          f"{engine.stats['requests']} requests)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
